"""Streaming-session overhead: per-step cost of a live ``ProbeSession``
vs the plain jitted step, plus snapshot latency and offload volume.

The paper's headline claim is a lightweight always-on profiler; the
streaming analogue must hold that property *per step of a long loop*:
the instrumented executable is built once, every step reuses it, host
aggregation stays constant-memory, and the telemetry poll adds only a
tiny device read. Rows:

- ``streaming/plain_step``          — uninstrumented jitted baseline
- ``streaming/session_step``        — same step under a live session
- ``streaming/session_step_poll8``  — polling every 8 steps instead of 1
- ``streaming/snapshot``            — cost of one full snapshot
"""
import time

import jax

from benchmarks.common import emit, layered_workload
from repro.core import ProbeConfig, ProbeSession


def _per_step_us(step, args, n=32):
    step(*args)                                    # warm up / build
    t0 = time.perf_counter()
    for _ in range(n):
        out = step(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    fn, args = layered_workload(8, 48)
    base = _per_step_us(jax.jit(fn), args)
    emit("streaming/plain_step", base)

    cfg = ProbeConfig(inline="off_all", offload=1.0, buffer_depth=4)

    with ProbeSession(fn, cfg) as s:
        t = _per_step_us(s.step, args)
        emit("streaming/session_step", t,
             f"overhead_vs_plain={100 * (t - base) / base:+.1f}%;"
             f"probes={len(s.paths)};dram_bytes={s.sink.bytes_received}")
        t0 = time.perf_counter()
        snap = s.snapshot()
        emit("streaming/snapshot", (time.perf_counter() - t0) * 1e6,
             f"steps={snap.steps};state_bytes={snap.state_nbytes}")

    with ProbeSession(fn, cfg, poll_every=8) as s:
        t = _per_step_us(s.step, args)
        emit("streaming/session_step_poll8", t,
             f"overhead_vs_plain={100 * (t - base) / base:+.1f}%")


if __name__ == "__main__":
    run()
