"""Table III analogue (Fmax impact): step latency of the instrumented
program vs the original, per storage configuration. The paper's claim:
decoupled probing leaves kernel timing essentially unchanged."""
import jax

from benchmarks.common import emit, layered_workload, timeit
from repro.core import ProbeConfig, probe


def run():
    fn, args = layered_workload(10, 256)
    base = jax.jit(fn)
    t_base = timeit(base, *args)
    emit("latency/original", t_base, "")
    for name, cfg in [
        ("registers", ProbeConfig(buffer_depth=4)),
        ("bram", ProbeConfig(buffer_depth=64)),
        ("registers_deep_probe", ProbeConfig(buffer_depth=4,
                                             inline="off_all")),
    ]:
        pf = probe(fn, cfg)
        pf(*args)
        t = timeit(lambda *a: pf(*a)[0], *args)
        emit(f"latency/{name}", t,
             f"overhead={100 * (t - t_base) / t_base:+.1f}%;"
             f"probes={len(pf.probe_paths())}")


if __name__ == "__main__":
    run()
