"""Probe-path performance: packed SoA ProbeState vs the legacy layout.

Three claims, each measured against the retained ``layout="legacy"``
reference on the tiny transformer (the golden-record subject):

- **ops**: the packed layout's batched transition updates emit >= 2x
  fewer instrumented equations than the legacy per-event scalar path
  (deterministic jaxpr counts, gated in CI);
- **trace**: building the instrumented evaluator (trace + extract +
  instrument) is >= 30% faster (wall clock, asserted with margin here,
  not gated across machines);
- **decode**: host-side ring decode + aggregation runs as whole-array
  numpy (throughput reported; span count is the deterministic check).

Plus the incremental-instrumentation caches: identical sub-jaxprs are
walked once and re-bound (``sub_rebinds``), and re-probing the same
function hits the trace/extract memos (``extract_hits``).
"""
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import ProbeConfig, measure_overhead, probe
from repro.core.buffer import row_durations, state_bytes


def _transformer():
    from repro.configs.registry import smoke_config
    from repro.models import Model

    cfg = smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(k, (2, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.fold_in(k, 1),
                                          (2, 32), 0, cfg.vocab_size)}

    def mk_fn():
        # a FRESH closure per measurement so the cross-instance trace
        # memo cannot leak work between the legacy and packed runs
        def fn(params, batch):
            return model.loss_fn(params, batch)
        return fn

    return mk_fn, (params, batch)


def _build_seconds(fn, args, cfg, repeats=2):
    """Full instrumentation-trace cost: hierarchy trace + extract +
    evaluator build + tracing the instrumented program itself (jit is
    lazy, so the walk over the user jaxpr happens at this last step)."""
    best = float("inf")
    for _ in range(repeats):
        pf = probe(fn, cfg)
        t0 = time.perf_counter()
        pf.trace(*args)
        pf._build(*args)
        jax.make_jaxpr(lambda *a: pf._jitted.__wrapped__(*a))(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def run():
    mk_fn, args = _transformer()
    cfg = ProbeConfig(max_probes=24)

    # --- instrumented-op count: packed vs legacy (deterministic) -------
    ov = {}
    for layout in ("legacy", "packed"):
        ov[layout] = measure_overhead(mk_fn(), args,
                                      cfg.replace(layout=layout))
    packed_ops = ov["packed"]["extra_eqns"]
    legacy_ops = ov["legacy"]["extra_eqns"]
    reduction = legacy_ops / max(packed_ops, 1)
    assert reduction >= 2.0, \
        f"packed layout op reduction {reduction:.2f}x < 2x gate"
    emit("instrument/ops_transformer", 0.0,
         f"probe_ops={packed_ops};legacy_ops={legacy_ops};"
         f"reduction_x1000={int(reduction * 1000)}")

    # --- state footprint: one plane fewer, fewer carried leaves --------
    n, d = ov["packed"]["n_probes"], cfg.buffer_depth
    emit("instrument/state", 0.0,
         f"state_B={state_bytes(n, d)};"
         f"legacy_state_B={state_bytes(n, d, layout='legacy')};"
         f"leaves=5;legacy_leaves=7")

    # --- instrumentation build time (wall clock; asserted, not gated) --
    t_legacy = _build_seconds(mk_fn(), args, cfg.replace(layout="legacy"))
    t_packed = _build_seconds(mk_fn(), args, cfg)
    speedup = t_legacy / max(t_packed, 1e-12)
    assert speedup >= 1.3, \
        f"packed instrumentation build {speedup:.2f}x < 1.3x gate"
    emit("instrument/trace_transformer", t_packed * 1e6,
         f"legacy_us={t_legacy * 1e6:.0f};speedup_pct={speedup * 100:.0f}%")

    # --- memoized sub-jaxpr instrumentation ----------------------------
    # six calls of ONE jitted layer: the instrumented body is walked
    # once and re-bound five times (cache_hits is gated higher-better)
    import jax.numpy as jnp

    @jax.jit
    def layer(x, w):
        with jax.named_scope("layer"):
            return jnp.tanh(x @ w) + x

    def stacked(x, w):
        for _ in range(6):
            x = layer(x, w)
        with jax.named_scope("head"):
            return jnp.sum(x * x)

    x = jnp.ones((8, 16)) * 0.1
    w = jnp.full((16, 16), 0.05)
    pf = probe(stacked, ProbeConfig(inline="off_all"))
    pf(x, w)                  # build + run (jit traces lazily)
    interp = pf._instrumenter
    assert interp.sub_rebinds >= 4, \
        f"expected re-bound layer instrumentations, got " \
        f"{interp.sub_walks} walks / {interp.sub_rebinds} rebinds"
    emit("instrument/memo_layers", 0.0,
         f"sub_walks={interp.sub_walks};cache_hits={interp.sub_rebinds}")

    # --- trace/extract memo across probe() instances -------------------
    from repro.core import hierarchy as hmod
    fn_shared = mk_fn()
    probe(fn_shared, cfg).trace(*args)
    h0 = hmod.extract_hits
    probe(fn_shared, cfg).trace(*args)        # same fn + shapes: memo hit
    emit("instrument/extract_memo", 0.0,
         f"cache_hits={hmod.extract_hits - h0}")

    # --- host decode throughput (whole-array numpy path) ---------------
    from repro.core.counters import int_to_pair
    from repro.core.streaming import StreamAggregator
    depth, rows = 64, 512
    ring = np.zeros((rows, depth, 2, 2), np.uint32)
    for s in range(depth):
        ring[:, s, 0] = int_to_pair(1000 * s)
        ring[:, s, 1] = int_to_pair(1000 * s + 137)
    agg = StreamAggregator(1)
    t0 = time.perf_counter()
    spans = 0
    for r in range(rows):
        durs = row_durations(ring[r])
        agg.add(0, durs)
        spans += durs.size
    dt = time.perf_counter() - t0
    assert int(agg.count[0]) == rows * depth
    assert int(agg.total[0]) == rows * depth * 137
    emit("instrument/decode", dt * 1e6,
         f"spans={spans};spans_per_s={spans / max(dt, 1e-12):.0f}")


if __name__ == "__main__":
    run()
