"""Fig 13 analogue, two loops:

1) automated DSE over *profiling* configurations (storage class x dump
   ratio -> Pareto frontier of resource/DRAM-bandwidth/latency), and
2) the probe-guided *kernel* autotuner: DSEEngine on the flash-attention
   search space — cold run measures, warm run must be 100% cache hits,
   and the tuned config must beat the default's probed cycles/step.

The kernel-autotune rows carry deterministic model-clock metrics
(``cycles=``, ``measurements=``, ``speedup_x1000=``) so the CI
regression gate can compare them across machines.
"""
import tempfile

from benchmarks.common import emit, layered_workload
from repro.core import DSEEngine, EvalCache, ProbeConfig, run_dse
from repro.kernels.search_spaces import flash_attention_space


def run():
    fn, args = layered_workload(8, 48)
    res = run_dse(fn, args, ProbeConfig(inline="off_all"),
                  storages=("registers", "hybrid", "bram"),
                  offload_ratios=(0.0, 0.25, 0.5, 0.75), repeats=2)
    for p in res.points:
        tag = "PARETO" if p in res.pareto else ""
        emit(f"dse/{p.storage}_d{p.depth}_dump{int(p.offload_ratio * 100)}",
             p.latency_overhead * 1e6,
             f"state_B={p.state_bytes};dram_B={p.dram_bytes};"
             f"bw_Bps={p.dram_bandwidth_bps:.0f};{tag}")
    best = res.best()
    emit("dse/BEST", 0.0,
         f"{best.storage}_dump{int(best.offload_ratio * 100)}pct")

    # ---- probe-guided kernel autotuning (DSEEngine) -------------------
    def mk_engine(cache):
        space = flash_attention_space(B=1, H=2, S=256, D=32,
                                      blocks_q=(64, 128, 256),
                                      blocks_k=(64, 128, 256),
                                      pipelines=(1, 2))
        return DSEEngine(space, cache=cache, max_steps=4)

    cache = EvalCache(tempfile.mkdtemp(prefix="bench_dse_"))
    cold = mk_engine(cache).tune()
    warm = mk_engine(cache).tune()

    d, b = cold.default, cold.best
    cfg = ",".join(f"{k}={v}" for k, v in sorted(b.config.items()))
    emit("dse/tune/default", 0.0,
         f"cycles={d.cycles_per_step:.0f}")
    emit("dse/tune/best", 0.0,
         f"cycles={b.cycles_per_step:.0f};config={cfg}")
    emit("dse/tune/speedup", 0.0,
         f"speedup_x1000={cold.speedup * 1000:.0f}")
    emit("dse/tune/cold", cold.wall_s * 1e6,
         f"measurements={cold.n_measurements};"
         f"probed_steps={cold.measured_steps};"
         f"candidates={cold.n_candidates}")
    emit("dse/tune/warm", warm.wall_s * 1e6,
         f"measurements={warm.n_measurements};"
         f"cache_hits={warm.n_cache_hits}")
    assert b.cycles_per_step < d.cycles_per_step, \
        "autotuner failed to beat the default flash_attention config"
    assert warm.n_measurements == 0, \
        "warm-cache DSE re-measured despite identical kernels/configs"
    assert warm.best.config == cold.best.config

    # ---- grid-step calibration of the cost model's body term ----------
    # Measure per-tile cycles with intra-kernel grid-step probes on two
    # configs that actually tile the q axis (causal skips exist), learn
    # the measured/static ratio on ONE of them, and require the other's
    # per-tile residual to shrink under the calibrated model.
    from repro.core import costmodel as _cm
    eng = mk_engine(EvalCache(tempfile.mkdtemp(prefix="bench_calib_")))
    try:
        src_t = eng.analyze({"block_q": 64, "block_k": 64, "pipeline": 1})
        dst_t = eng.analyze({"block_q": 64, "block_k": 128, "pipeline": 1})
        eng.measure_tiles(src_t)
        eng.measure_tiles(dst_t)
        resid_uncal = abs(dst_t.tile_residual)
        scale = eng.calibrate([src_t])
        dst_cal = eng.analyze(dst_t.config)
        resid_cal = abs(dst_cal.resources.static_cycles /
                        dst_cal.resources.grid_steps - dst_t.tile_measured)
        emit("dse/calib/tiles", 0.0,
             f"tile_static={src_t.tile_static:.0f};"
             f"tile_measured={src_t.tile_measured:.0f};"
             f"scale_x1000={scale * 1000:.0f}")
        emit("dse/calib/residual", 0.0,
             f"uncal={resid_uncal:.0f};cal={resid_cal:.0f};"
             f"saving={100.0 * (1 - resid_cal / max(resid_uncal, 1e-9)):.0f}")
        assert resid_cal < resid_uncal, \
            "calibrated cost model did not shrink the per-tile residual"
    finally:
        _cm.clear_kernel_calibration()


if __name__ == "__main__":
    run()
