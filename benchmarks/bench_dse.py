"""Fig 13 analogue, two loops:

1) automated DSE over *profiling* configurations (storage class x dump
   ratio -> Pareto frontier of resource/DRAM-bandwidth/latency), and
2) the probe-guided *kernel* autotuner: DSEEngine on the flash-attention
   search space — cold run measures, warm run must be 100% cache hits,
   and the tuned config must beat the default's probed cycles/step.

The kernel-autotune rows carry deterministic model-clock metrics
(``cycles=``, ``measurements=``, ``speedup_x1000=``) so the CI
regression gate can compare them across machines.
"""
import tempfile

from benchmarks.common import emit, layered_workload
from repro.core import DSEEngine, EvalCache, ProbeConfig, run_dse
from repro.kernels.search_spaces import flash_attention_space


def run():
    fn, args = layered_workload(8, 48)
    res = run_dse(fn, args, ProbeConfig(inline="off_all"),
                  storages=("registers", "hybrid", "bram"),
                  offload_ratios=(0.0, 0.25, 0.5, 0.75), repeats=2)
    for p in res.points:
        tag = "PARETO" if p in res.pareto else ""
        emit(f"dse/{p.storage}_d{p.depth}_dump{int(p.offload_ratio * 100)}",
             p.latency_overhead * 1e6,
             f"state_B={p.state_bytes};dram_B={p.dram_bytes};"
             f"bw_Bps={p.dram_bandwidth_bps:.0f};{tag}")
    best = res.best()
    emit("dse/BEST", 0.0,
         f"{best.storage}_dump{int(best.offload_ratio * 100)}pct")

    # ---- probe-guided kernel autotuning (DSEEngine) -------------------
    def mk_engine(cache):
        space = flash_attention_space(B=1, H=2, S=256, D=32,
                                      blocks_q=(64, 128, 256),
                                      blocks_k=(64, 128, 256),
                                      pipelines=(1, 2))
        return DSEEngine(space, cache=cache, max_steps=4)

    cache = EvalCache(tempfile.mkdtemp(prefix="bench_dse_"))
    cold = mk_engine(cache).tune()
    warm = mk_engine(cache).tune()

    d, b = cold.default, cold.best
    cfg = ",".join(f"{k}={v}" for k, v in sorted(b.config.items()))
    emit("dse/tune/default", 0.0,
         f"cycles={d.cycles_per_step:.0f}")
    emit("dse/tune/best", 0.0,
         f"cycles={b.cycles_per_step:.0f};config={cfg}")
    emit("dse/tune/speedup", 0.0,
         f"speedup_x1000={cold.speedup * 1000:.0f}")
    emit("dse/tune/cold", cold.wall_s * 1e6,
         f"measurements={cold.n_measurements};"
         f"probed_steps={cold.measured_steps};"
         f"candidates={cold.n_candidates}")
    emit("dse/tune/warm", warm.wall_s * 1e6,
         f"measurements={warm.n_measurements};"
         f"cache_hits={warm.n_cache_hits}")
    assert b.cycles_per_step < d.cycles_per_step, \
        "autotuner failed to beat the default flash_attention config"
    assert warm.n_measurements == 0, \
        "warm-cache DSE re-measured despite identical kernels/configs"
    assert warm.best.config == cold.best.config


if __name__ == "__main__":
    run()
