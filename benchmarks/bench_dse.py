"""Fig 13 analogue: automated DSE over storage class x dump ratio ->
Pareto frontier of (resource, DRAM bandwidth, latency)."""
from benchmarks.common import emit, layered_workload
from repro.core import ProbeConfig, run_dse


def run():
    fn, args = layered_workload(8, 48)
    res = run_dse(fn, args, ProbeConfig(inline="off_all"),
                  storages=("registers", "hybrid", "bram"),
                  offload_ratios=(0.0, 0.25, 0.5, 0.75), repeats=2)
    for p in res.points:
        tag = "PARETO" if p in res.pareto else ""
        emit(f"dse/{p.storage}_d{p.depth}_dump{int(p.offload_ratio * 100)}",
             p.latency_overhead * 1e6,
             f"state_B={p.state_bytes};dram_B={p.dram_bytes};"
             f"bw_Bps={p.dram_bandwidth_bps:.0f};{tag}")
    best = res.best()
    emit("dse/BEST", 0.0,
         f"{best.storage}_dump{int(best.offload_ratio * 100)}pct")


if __name__ == "__main__":
    run()
