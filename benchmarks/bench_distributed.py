"""Mesh-aware probing: per-device overhead and skew metrics vs mesh size.

For each mesh size the bench forces an N-device host platform in a
subprocess (the dry-run isolation rule — the parent process keeps the
real backend) and runs the canonical skewed workload (a DP layer stack,
an all-reduce, and a device-index-dependent while loop) under
``mesh_probe``:

- ``span`` / ``mean_cycles`` / ``skew``: deterministic model-clock
  metrics per device — skew is the straggler signal (max−min total
  cycles of the dynamic scope across devices) and GROWS with the mesh
  because the last device loops longest;
- ``wire_B``: ring-model collective wire bytes of the program
  (mesh-size-sensitive through the cost model's collective term);
- ``state_B``: total on-device counter footprint (rows × devices);
- ``us_per_call``: wall-clock per probed step (not gated on CI).

All the model-clock metrics are gated by ``check_regression.py``
against the committed baselines.
"""
import json
import os
import subprocess
import sys

from benchmarks.common import emit

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import MeshProbeSession, ProbeConfig, mesh_probe
from repro.launch.mesh import make_mesh

D = jax.device_count()
mesh = make_mesh((D,), ("dev",))

def step(x, w):
    def body(c, _):
        with jax.named_scope("layer"):
            c = jnp.tanh(c @ w) + c
        return c, None
    with jax.named_scope("layers"):
        x, _ = jax.lax.scan(body, x, None, length=4)
    with jax.named_scope("sync"):
        g = jax.lax.pmean(jnp.sum(x * x), "dev")
    i = jax.lax.axis_index("dev")
    def cond(s):
        return s[1] < i + 1
    def grow(s):
        with jax.named_scope("grow"):
            return (s[0] * 1.1, s[1] + 1)
    with jax.named_scope("dynamic"):
        x, n = jax.lax.while_loop(cond, grow, (x, jnp.int32(0)))
    with jax.named_scope("head"):
        return jnp.sum(x * x) + g, n

x = jnp.arange(float(D * 8 * 4)).reshape(D * 8, 4) * 0.01
w = jnp.full((4, 4), 0.25)
cfg = ProbeConfig(inline="off_all")
mpf = mesh_probe(step, mesh, in_specs=(P("dev"), P()), out_specs=P(),
                 config=cfg)
out, state = mpf(x, w)
jax.block_until_ready(out)
rec = mpf.decode(state)
wire = sum(s.wire_bytes for s in mpf.collectives())

ref = mpf.unprobed()
jax.block_until_ready(ref(x, w))

def best_us(fn, reps=5):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, w))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6

probed_us = best_us(lambda *a: mpf(*a)[0])
base_us = best_us(ref)

with MeshProbeSession(mpf, window_steps=4) as s:
    for _ in range(8):
        s.step(x, w)
    snap = s.snapshot()

pid = rec.paths.index("dynamic")
print(json.dumps({
    "devices": D,
    "span": int(rec.cycle.max()),
    "mean_cycles": float(rec.reduce("mean").sum()),
    "skew": int(rec.skew()[pid]),
    "session_skew": int(snap.record.skew()[pid]),
    "wire_B": int(wire),
    "state_B": int(snap.state_nbytes),
    "probed_us": probed_us,
    "base_us": base_us,
}))
"""


def _run_child(n_devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=540)
    if out.returncode != 0:
        raise RuntimeError(f"mesh-{n_devices} child failed:\n"
                           f"{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run():
    print("# per-device probing vs mesh size (forced host devices)")
    for n in (2, 8):
        r = _run_child(n)
        overhead = (r["probed_us"] / r["base_us"] - 1) * 100 \
            if r["base_us"] else 0.0
        # session skew after 8 steps must telescope to 8x the one-shot
        # skew (deterministic model clock) — emit the check, gate the raw
        assert r["session_skew"] == 8 * r["skew"], r
        emit(f"distributed/mesh{n}", r["probed_us"],
             f"span={r['span']};mean_cycles={r['mean_cycles']:.0f};"
             f"skew={r['skew']};wire_B={r['wire_B']};"
             f"state_B={r['state_B']};overhead={overhead:.0f}%")
