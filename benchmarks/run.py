"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (+ a few rendered charts)."""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_accuracy, bench_discrepancy, bench_dse,
                            bench_incremental, bench_latency_impact,
                            bench_offload, bench_overhead, bench_roofline)
    benches = [
        ("Table II  (cycle accuracy, 28 designs)", bench_accuracy),
        ("Fig 8/9/10 (overhead + analytical model)", bench_overhead),
        ("Fig 7/11  (incremental synthesis)", bench_incremental),
        ("Table III (latency/Fmax impact)", bench_latency_impact),
        ("Fig 12    (DRAM dump ratio)", bench_offload),
        ("Fig 13    (DSE Pareto)", bench_dse),
        ("Fig 1/14 + Table IV (discrepancies)", bench_discrepancy),
        ("Roofline  (dry-run derived)", bench_roofline),
    ]
    failed = []
    for title, mod in benches:
        print(f"# === {title} ===", flush=True)
        try:
            mod.run()
        except Exception as e:
            failed.append(title)
            traceback.print_exc()
            print(f"{title},0.0,FAILED:{type(e).__name__}")
    if failed:
        print(f"# {len(failed)} bench(es) failed: {failed}")
        sys.exit(1)
    print("# all benches complete")


if __name__ == '__main__':
    main()
