"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (+ a few rendered charts)
and writes one ``BENCH_<name>.json`` artifact per bench into the
output directory (``--out-dir``, default CWD) — see docs/benchmarks.md
for how to read them.
"""
import argparse
import json
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<name>.json artifacts")
    ap.add_argument("--only", default=None,
                    help="run a single bench by short name (e.g. streaming)")
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_discrepancy, bench_dse,
                            bench_incremental, bench_latency_impact,
                            bench_offload, bench_overhead, bench_roofline,
                            bench_streaming, common)
    benches = [
        ("Table II  (cycle accuracy, 28 designs)", bench_accuracy),
        ("Fig 8/9/10 (overhead + analytical model)", bench_overhead),
        ("Fig 7/11  (incremental synthesis)", bench_incremental),
        ("Table III (latency/Fmax impact)", bench_latency_impact),
        ("Fig 12    (DRAM dump ratio)", bench_offload),
        ("Fig 13    (DSE Pareto)", bench_dse),
        ("Fig 1/14 + Table IV (discrepancies)", bench_discrepancy),
        ("Streaming (ProbeSession per-step overhead)", bench_streaming),
        ("Roofline  (dry-run derived)", bench_roofline),
    ]
    shorts = [m.__name__.split(".")[-1].replace("bench_", "")
              for _, m in benches]
    if args.only and args.only not in shorts:
        sys.exit(f"unknown bench {args.only!r}; choose from {shorts}")
    failed = []
    os.makedirs(args.out_dir, exist_ok=True)
    for title, mod in benches:
        short = mod.__name__.split(".")[-1].replace("bench_", "")
        if args.only and short != args.only:
            continue
        print(f"# === {title} ===", flush=True)
        common.reset_rows()
        err = None
        try:
            mod.run()
        except Exception as e:
            failed.append(title)
            traceback.print_exc()
            err = f"{type(e).__name__}: {e}"
            print(f"{title},0.0,FAILED:{type(e).__name__}")
        artifact = {"bench": short, "title": title,
                    "rows": common.collect_rows(), "error": err}
        path = os.path.join(args.out_dir, f"BENCH_{short}.json")
        with open(path, "w") as f:
            json.dump(artifact, f, indent=1)
    if failed:
        print(f"# {len(failed)} bench(es) failed: {failed}")
        sys.exit(1)
    print("# all benches complete")


if __name__ == '__main__':
    main()
