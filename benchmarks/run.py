"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (+ a few rendered charts)
and writes one ``BENCH_<name>.json`` artifact per bench into the
output directory (``--out-dir``, default CWD) — see docs/benchmarks.md
for how to read them.

Artifacts are deterministic where the underlying metric is: JSON is
key-sorted, rows keep emission order, the RNG seed is fixed, and no
timestamps are recorded — so committed baselines diff cleanly and the
CI regression gate (benchmarks/check_regression.py) can compare the
model-clock metrics exactly.
"""
import argparse
import json
import os
import sys
import traceback

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<name>.json artifacts")
    ap.add_argument("--only", default=None,
                    help="run a subset by short name, comma-separated "
                         "(e.g. 'accuracy,dse,streaming')")
    ap.add_argument("--seed", type=int, default=0,
                    help="global RNG seed (fixed for diffable artifacts)")
    args = ap.parse_args()

    import numpy as np
    np.random.seed(args.seed)
    import jax
    try:
        # pin the PRNG implementation so key-derived data (and thus the
        # deterministic metrics) match across jax versions
        jax.config.update("jax_threefry_partitionable", False)
    except AttributeError:
        pass

    from benchmarks import (bench_accuracy, bench_conformance,
                            bench_discrepancy, bench_distributed,
                            bench_dse, bench_engine, bench_incremental,
                            bench_instrument, bench_latency_impact,
                            bench_offload, bench_overhead, bench_roofline,
                            bench_streaming, bench_sweep, bench_telemetry,
                            common)
    benches = [
        ("Table II  (cycle accuracy, 28 designs)", bench_accuracy),
        ("Conformance (graphs verified / second)", bench_conformance),
        ("Fig 8/9/10 (overhead + analytical model)", bench_overhead),
        ("Instrument (packed SoA probe datapath)", bench_instrument),
        ("Fig 7/11  (incremental synthesis)", bench_incremental),
        ("Table III (latency/Fmax impact)", bench_latency_impact),
        ("Fig 12    (DRAM dump ratio)", bench_offload),
        ("Fig 13    (DSE Pareto + kernel autotune)", bench_dse),
        ("Sweep farm (trace-once simulator at scale)", bench_sweep),
        ("Fig 1/14 + Table IV (discrepancies)", bench_discrepancy),
        ("Streaming (ProbeSession per-step overhead)", bench_streaming),
        ("Engine    (paged continuous-batching serving)", bench_engine),
        ("Telemetry (bus publish + drift sentinel)", bench_telemetry),
        ("Distributed (mesh probe: skew vs mesh size)", bench_distributed),
        ("Roofline  (dry-run derived)", bench_roofline),
    ]
    shorts = [m.__name__.split(".")[-1].replace("bench_", "")
              for _, m in benches]
    only = None
    if args.only:
        only = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in only if s not in shorts]
        if unknown:
            sys.exit(f"unknown bench(es) {unknown}; choose from {shorts}")
    failed = []
    os.makedirs(args.out_dir, exist_ok=True)
    for title, mod in benches:
        short = mod.__name__.split(".")[-1].replace("bench_", "")
        if only is not None and short not in only:
            continue
        print(f"# === {title} ===", flush=True)
        common.reset_rows()
        err = None
        try:
            mod.run()
        except Exception as e:
            failed.append(title)
            traceback.print_exc()
            err = f"{type(e).__name__}: {e}"
            print(f"{title},0.0,FAILED:{type(e).__name__}")
        artifact = {"bench": short, "title": title, "seed": args.seed,
                    "rows": common.collect_rows(), "error": err}
        path = os.path.join(args.out_dir, f"BENCH_{short}.json")
        with open(path, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
            f.write("\n")
    if failed:
        print(f"# {len(failed)} bench(es) failed: {failed}")
        sys.exit(1)
    print("# all benches complete")


if __name__ == '__main__':
    main()
