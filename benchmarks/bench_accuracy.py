"""Table II analogue: cycle counts from the static estimate ("C-synth"),
the oracle interpreter ("Co-sim"), and the in-device counters
("RealProbe"), cross-verified for EXACT equality oracle==device on 28
workloads. Reports the static-vs-measured deviation per benchmark."""
import numpy as np

from benchmarks.common import emit, layered_workload, model_workloads, timeit
from repro.core import ProbeConfig, probe
from repro.core.instrument import decode_record


def run():
    workloads = {}
    # 24 synthetic layered designs of varying size (the Xilinx/Kastner
    # example-suite analogue) + 4 real model families
    for i, (n_layers, width) in enumerate(
            [(L, W) for L in (2, 4, 6, 8, 10, 12) for W in (16, 32, 48, 64)]):
        workloads[f"layered_L{n_layers}_W{width}"] = layered_workload(
            n_layers, width)
    for name, (fn, args) in model_workloads().items():
        workloads[f"model_{name}"] = (fn, args)

    exact = 0
    total = 0
    devs = []
    for name, (fn, args) in workloads.items():
        pf = probe(fn, ProbeConfig(max_probes=30))
        t0 = timeit(lambda *a: pf(*a)[0], *args, repeats=1)
        out, rec = pf(*args)
        oc = pf.oracle(*args)
        ok = True
        dec = decode_record(rec)
        for i, p in enumerate(pf.probe_paths()):
            ok &= int(dec["totals"][i]) == oc.totals[i]
            ok &= int(dec["calls"][i]) == oc.calls[i]
        span = dec["cycle"]
        ok &= span == oc.cycle
        exact += bool(ok)
        total += 1
        rep = pf.report(rec)
        # C-synth-style static total vs measured (top-level)
        stat = sum(r.static_cycles or 0 for r in rep.rows
                   if "/" not in r.path and not r.dynamic)
        meas = sum(r.total_cycles for r in rep.rows if "/" not in r.path)
        dev = abs(stat - meas) / max(meas, 1)
        devs.append(dev)
        emit(f"accuracy/{name}", t0,
             f"oracle_match={'EXACT' if ok else 'MISMATCH'};"
             f"static_dev={dev * 100:.1f}%;span={span}")
    emit("accuracy/SUMMARY", 0.0,
         f"exact={exact}/{total};mean_static_dev="
         f"{np.mean(devs) * 100:.1f}%")
    assert exact == total, "RealProbe != oracle somewhere!"


if __name__ == "__main__":
    run()
