"""Sweep farm (trace-once cycle simulator at scale): a 1000+-candidate
flash-attention config x shape pool is captured once as ``KernelTrace``
artifacts by worker processes, priced through the simulator in
microseconds per config, and only the per-shape finalists (<=32 across
the whole sweep) ever touch the device.

Deterministic rows (``sweep_configs=``, ``device_measurements=``,
``cycles=``, ``speedup_x1000=``) gate the funnel shape and the
model-clock outcomes exactly; ``sim_us_per_config`` is wall-clock-ish
and gates against a generous budget baseline.
"""
import tempfile

from benchmarks.common import emit
from repro.core import EvalCache
from repro.core.dse import run_sweep

SHAPES = [{"S": s, "H": h, "D": 32}
          for s in (128, 256, 512, 1024) for h in (2, 4, 8)]


def run():
    cache = EvalCache(tempfile.mkdtemp(prefix="bench_sweep_"))
    res = run_sweep("flash_attention", SHAPES, workers=4, top_k=24,
                    steps=2, cache=cache, calibrate=False)

    emit("sweep/funnel", res.wall_s * 1e6,
         f"sweep_configs={res.n_candidates};captured={res.n_captured};"
         f"pruned={res.n_pruned};finalists={res.n_finalists};"
         f"device_measurements={res.n_measured}")
    emit("sweep/simulate", res.sim_wall_s * 1e6,
         f"sim_us_per_config={res.sim_us_per_config:.1f};"
         f"priced={res.n_priced}")
    emit("sweep/capture", res.price_wall_s * 1e6,
         f"workers={res.workers}")
    emit("sweep/measure", res.measure_wall_s * 1e6,
         f"device_measurements={res.n_measured}")
    for sh in res.shapes:
        tag = "x".join(str(v) for _, v in sorted(sh.shape.items()))
        cfg = ",".join(f"{k}={v}" for k, v in sorted(sh.best_config.items()))
        emit(f"sweep/shape/{tag}", 0.0,
             f"cycles={sh.best_cycles:.0f};default={sh.default_cycles:.0f};"
             f"speedup_x1000={sh.speedup * 1000:.0f};config={cfg}")

    assert res.n_candidates >= 1000, \
        f"sweep pool shrank to {res.n_candidates} candidates"
    assert res.n_measured <= 32, \
        f"{res.n_measured} device measurements; the funnel must keep <=32"
    for sh in res.shapes:
        assert sh.best_cycles <= sh.default_cycles, \
            f"sweep winner lost to the default at {sh.shape}"

    # warm rerun: artifacts + eval cache make the whole sweep device-free
    res2 = run_sweep("flash_attention", SHAPES, workers=4, top_k=24,
                     steps=2, cache=EvalCache(cache.root), calibrate=False)
    emit("sweep/warm", res2.wall_s * 1e6,
         f"device_measurements={res2.n_measured};captured={res2.n_captured};"
         f"cache_hits={res2.n_cache_hits}")
    assert res2.n_measured == 0 and res2.n_captured == 0, \
        "warm sweep re-did work despite unchanged kernels/configs"
    assert [s.best_config for s in res2.shapes] == \
        [s.best_config for s in res.shapes]


if __name__ == "__main__":
    run()
