"""Conformance-sweep throughput: graphs verified per second.

The seeded random-graph harness (``repro.testing``) is itself on the
hot path of CI: the tier-1 corpus and the nightly 200-graph sweep both
pay `build -> probe -> six invariants` per graph, so a slowdown in the
probe pipeline (tracing, instrumentation, oracle replay, packed
decode) shows up here first as verification throughput. Metrics:

- ``graphs``       — graphs fully verified (deterministic, gated)
- ``invariants``   — invariant checks executed across the corpus
- ``probes``       — probe slots exercised across the corpus
- ``us_per_call``  — wall-clock per graph (timing, gated only with
  ``--include-timing`` on quiet machines)

The seed window is fixed, so graph structures — and therefore the
deterministic metrics — are identical on every machine.
"""
import time

from benchmarks.common import emit

# a small fixed window keeps the bench under a minute while still
# spanning kernel and non-kernel graphs (seeds 0-3: 2 of each)
SEEDS = (0, 1, 2, 3)


def run():
    from repro.testing import INVARIANTS, random_spec, run_conformance

    graphs = 0
    invariants = 0
    probes = 0
    t0 = time.perf_counter()
    for seed in SEEDS:
        stats = run_conformance(random_spec(seed))
        graphs += 1
        invariants += len(stats["invariants"])
        probes += stats["n_probes"]
    elapsed = time.perf_counter() - t0
    us_per_graph = elapsed / graphs * 1e6
    gps_x1000 = graphs / elapsed * 1000.0
    emit("conformance/sweep", us_per_graph,
         f"graphs={graphs};invariants={invariants};probes={probes};"
         f"gps_x1000={gps_x1000:.0f}")
    assert invariants == graphs * len(INVARIANTS), "skipped invariants"


if __name__ == "__main__":
    run()
