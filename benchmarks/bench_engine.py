"""Serving-engine benchmark: per-phase cycle attribution at steady state.

A fixed-seed mixed request trace (shared prefixes, varied prompt and
decode lengths) is served through the continuous-batching engine with
probing on. All gated metrics come from the deterministic model clock
and the engine's exact bookkeeping, so they are machine-independent:

- ``cycles``       — model-clock cycles per phase (prefill / cache /
                     decode) and in total
- ``probed_steps`` — step-function invocations per phase (scheduling
                     drift changes these before it changes wall time)
- ``retraces``     — compile-cache growth beyond one trace per step
                     (must stay 0: the zero-retrace contract)
- ``pages_peak``   — page-pool high-water occupancy
- ``hit_x1000``    — prefix-cache hit rate x1000
"""
import time

import numpy as np

from benchmarks.common import emit


def _trace(vocab: int, seed: int = 23):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, 16).tolist()
    reqs = []
    for i in range(8):
        base = prefix if i % 2 == 0 else []
        tail = rng.integers(0, vocab, int(rng.integers(3, 14))).tolist()
        reqs.append((base + tail, int(rng.integers(2, 7))))
    return reqs


def run():
    import jax

    from repro.configs.registry import smoke_config
    from repro.engine import EngineConfig, InferenceEngine
    from repro.models import Model

    cfg = smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, pool_pages=32, max_pages=3, buckets=(1, 2, 4),
        probe=True, interpret=True))
    reqs = _trace(cfg.vocab_size)
    t0 = time.perf_counter()
    for prompt, max_new in reqs:
        eng.submit(prompt, max_new)
    done = eng.run()
    elapsed = time.perf_counter() - t0
    st = eng.stats()
    assert len(done) == len(reqs)
    assert all(len(r.out_tokens) == m for r, (_, m) in zip(done, reqs))

    total = sum(v["cycles"] for v in st["phases"].values())
    emit("engine/serve", elapsed / len(reqs) * 1e6,
         f"cycles={total};retraces={st['retraces']};"
         f"pages_peak={st['pages_peak']};"
         f"hit_x1000={st['prefix_hit_rate'] * 1000:.0f}")
    for phase, v in st["phases"].items():
        emit(f"engine/{phase}", 0.0,
             f"cycles={v['cycles']};probed_steps={v['steps']}")
    eng.drain()
    assert eng.table.balanced(), "page accounting out of balance"
    eng.close()


if __name__ == "__main__":
    run()
