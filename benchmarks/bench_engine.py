"""Serving-engine benchmark: per-phase cycle attribution at steady state.

A fixed-seed mixed request trace (shared prefixes, varied prompt and
decode lengths) is served through the continuous-batching engine with
probing on. All gated metrics come from the deterministic model clock
and the engine's exact bookkeeping, so they are machine-independent:

- ``cycles``            — model-clock cycles per phase (prefill /
                          cache / decode) and in total
- ``probed_steps``      — step-function invocations per phase
                          (scheduling drift changes these before it
                          changes wall time)
- ``retraces``          — compile-cache growth beyond one trace per
                          step (must stay 0: the zero-retrace contract)
- ``pages_peak``        — page-pool high-water occupancy
- ``hit_x1000``         — prefix-cache hit rate x1000
- ``evictions``         — prefix-cache pages reclaimed under pressure
- ``hol_blocked_steps`` — decode rounds displaced by whole-prompt
                          prefills beyond one chunk quantum
- ``tok_per_step_x1000``— emitted tokens per engine step x1000 (the
                          scheduler's throughput shape)

Two A/B workloads lock in the throughput-overhaul wins:

- ``engine/serve_hol_{whole,chunked}`` — the same long-prompt/decode
  mix served whole-prompt vs chunked; chunking must pin
  ``hol_blocked_steps`` at 0 while the whole-prompt run pays > 0.
- ``engine/evict_{lru,clear}`` — the same pressure trace (pool smaller
  than the prefix working set) under LRU vs all-or-nothing eviction;
  LRU must keep a strictly higher prefix hit rate.
"""
import time

import numpy as np

from benchmarks.common import emit


def _trace(vocab: int, seed: int = 23):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, 16).tolist()
    reqs = []
    for i in range(8):
        base = prefix if i % 2 == 0 else []
        tail = rng.integers(0, vocab, int(rng.integers(3, 14))).tolist()
        reqs.append((base + tail, int(rng.integers(2, 7))))
    return reqs


def _serve_stats(model, params, reqs, **cfg_overrides):
    """Serve one trace on a fresh engine; returns its stats()."""
    from repro.engine import EngineConfig, InferenceEngine

    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, probe=True, interpret=True, **cfg_overrides))
    for prompt, max_new in reqs:
        eng.submit(prompt, max_new)
    done = eng.run()
    assert len(done) == len(reqs)
    st = eng.stats()
    eng.drain()
    assert eng.table.balanced(), "page accounting out of balance"
    eng.close()
    return st


def _hol_trace(vocab: int, seed: int = 31):
    """One decode-heavy request followed by long prompts that, served
    whole, head-of-line-block its decode rounds."""
    rng = np.random.default_rng(seed)
    reqs = [(rng.integers(0, vocab, 5).tolist(), 10)]
    for _ in range(2):
        reqs.append((rng.integers(0, vocab, 40).tolist(), 2))
    return reqs


def _pressure_trace(vocab: int, seed: int = 47):
    """Two hot shared prefixes interleaved with one-off cold prompts:
    the prefix working set exceeds the pool, so every admission beyond
    the first few must reclaim tree pages."""
    rng = np.random.default_rng(seed)
    hot = [rng.integers(0, vocab, 16).tolist() for _ in range(2)]
    reqs = []
    for i in range(15):
        if i % 3 == 2:
            base = rng.integers(0, vocab, 16).tolist()     # cold
        else:
            base = hot[i % 3]
        tail = rng.integers(0, vocab, 5).tolist()
        reqs.append((base + tail, 3))
    return reqs


def run():
    import jax

    from repro.configs.registry import smoke_config
    from repro.engine import EngineConfig, InferenceEngine
    from repro.models import Model

    cfg = smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, pool_pages=32, max_pages=3, buckets=(1, 2, 4),
        probe=True, interpret=True))
    reqs = _trace(cfg.vocab_size)
    t0 = time.perf_counter()
    for prompt, max_new in reqs:
        eng.submit(prompt, max_new)
    done = eng.run()
    elapsed = time.perf_counter() - t0
    st = eng.stats()
    assert len(done) == len(reqs)
    assert all(len(r.out_tokens) == m for r, (_, m) in zip(done, reqs))

    total = sum(v["cycles"] for v in st["phases"].values())
    steps = sum(v["steps"] for v in st["phases"].values())
    emit("engine/serve", elapsed / len(reqs) * 1e6,
         f"cycles={total};retraces={st['retraces']};"
         f"pages_peak={st['pages_peak']};"
         f"hit_x1000={st['prefix_hit_rate'] * 1000:.0f};"
         f"evictions={st['evictions']};"
         f"hol_blocked_steps={st['hol_blocked_steps']};"
         f"tok_per_step_x1000={st['tokens_out'] * 1000 // steps}")
    for phase, v in st["phases"].items():
        emit(f"engine/{phase}", 0.0,
             f"cycles={v['cycles']};probed_steps={v['steps']}")
    eng.drain()
    assert eng.table.balanced(), "page accounting out of balance"
    eng.close()

    # -- chunked prefill vs whole-prompt: head-of-line displacement ----
    hol_reqs = _hol_trace(cfg.vocab_size)
    variants = {"whole": 0, "chunked": 1}
    hol_stats = {}
    for name, chunk in variants.items():
        s = _serve_stats(model, params, hol_reqs, pool_pages=32,
                         max_pages=3, buckets=(1, 2),
                         prefill_chunk_pages=chunk)
        hol_stats[name] = s
        steps = sum(v["steps"] for v in s["phases"].values())
        emit(f"engine/serve_hol_{name}", 0.0,
             f"hol_blocked_steps={s['hol_blocked_steps']};"
             f"retraces={s['retraces']};"
             f"tok_per_step_x1000={s['tokens_out'] * 1000 // steps}")
    assert hol_stats["whole"]["hol_blocked_steps"] > 0, \
        "HoL workload no longer blocks the whole-prompt scheduler"
    assert hol_stats["chunked"]["hol_blocked_steps"] == 0, \
        "chunked prefill must never head-of-line-block decode"

    # -- LRU vs clear() eviction under pool pressure -------------------
    press_reqs = _pressure_trace(cfg.vocab_size)
    evict_stats = {}
    for policy in ("lru", "clear"):
        s = _serve_stats(model, params, press_reqs, pool_pages=7,
                         max_pages=2, buckets=(1,),
                         evict_policy=policy)
        evict_stats[policy] = s
        emit(f"engine/evict_{policy}", 0.0,
             f"hit_x1000={s['prefix_hit_rate'] * 1000:.0f};"
             f"evictions={s['evictions']};retraces={s['retraces']}")
    assert evict_stats["lru"]["evictions"] > 0, \
        "pressure trace did not trigger LRU eviction"
    assert evict_stats["clear"]["evictions"] > 0, \
        "pressure trace did not trigger clear() eviction"
    assert (evict_stats["lru"]["prefix_hit_rate"]
            > evict_stats["clear"]["prefix_hit_rate"]), \
        "LRU eviction must strictly beat clear() on prefix hit rate"


if __name__ == "__main__":
    run()
