"""Fig 1 / Fig 14 / Table IV analogue: estimate-vs-measured discrepancy.

- model mode: static ("C-synth") vs oracle ("Co-sim") vs device counters
  (exact) — dynamic control flow makes the static column wrong.
- wallclock mode: REAL host-time measurements diverge from all model
  estimates (runtime dynamics — the Fig 1 board-vs-sim gap).
- Table IV: the discrepancy under different configurations (sizes,
  buffer depths) — bottleneck RANKINGS shift between stages (Fig 14).
"""

from benchmarks.common import emit, layered_workload
from repro.core import ProbeConfig, probe
from repro.core.report import bump_chart


def run():
    for tag, (L, W) in {"small": (4, 32), "large": (10, 64)}.items():
        fn, args = layered_workload(L, W)
        pf = probe(fn, ProbeConfig(inline="off_all"))
        out, rec = pf(*args)
        rep = pf.report(rec)
        top = [r for r in rep.rows if "/" not in r.path]
        for r in top:
            static = "?" if r.dynamic else str(r.static_cycles)
            dev = ("n/a" if r.dynamic else
                   f"{100 * (r.static_cycles - r.total_cycles) / max(r.total_cycles, 1):+.1f}%")
            emit(f"discrepancy/{tag}/{r.path}", 0.0,
                 f"static={static};measured={r.total_cycles};dev={dev}")

        # wallclock mode on the same program
        pfw = probe(fn, ProbeConfig(inline="off_all",
                                    cycle_source="wallclock"))
        _, recw = pfw(*args)
        repw = pfw.report(recw)

        # Fig 14 bump chart: bottleneck ranking per stage
        def ranking(rep):
            rs = [r for r in rep.rows if r.path.count("/") >= 1]
            rs.sort(key=lambda r: -r.total_cycles)
            return [r.path for r in rs[:3]]

        def static_ranking(rep):
            rs = [r for r in rep.rows
                  if r.path.count("/") >= 1 and not r.dynamic]
            rs.sort(key=lambda r: -(r.static_cycles or 0))
            return [r.path for r in rs[:3]]

        chart = bump_chart({
            "C-synth(static)": static_ranking(rep),
            "model(oracle)": ranking(rep),
            "wallclock(board)": ranking(repw),
        }, width=28)
        print(chart)
        same = ranking(rep)[0] == ranking(repw)[0]
        emit(f"discrepancy/{tag}/bottleneck_shift", 0.0,
             f"model_top={ranking(rep)[0]};wall_top={ranking(repw)[0]};"
             f"{'same' if same else 'SHIFTED'}")


if __name__ == "__main__":
    run()
