"""Fig 7/11 analogue: cold setup (trace+extract+instrument+compile) vs
incremental retarget (trace/hierarchy reused), and the untouched base
executable (decoupling)."""
from benchmarks.common import emit, layered_workload
from repro.core import ProbeConfig, measure_incremental


def run():
    fn, args = layered_workload(8, 48)
    t = measure_incremental(
        fn, args,
        ProbeConfig(targets=("layers",), inline="off_all"),
        ProbeConfig(targets=("layers/scan#0/layer/mlp",), inline="off_all"))
    emit("incremental/cold_setup", t.cold_total_s * 1e6, "")
    emit("incremental/retarget", t.retarget_total_s * 1e6,
         f"pct_of_cold={100 * t.retarget_total_s / t.cold_total_s:.1f}%")
    emit("incremental/base_executable", 0.0,
         "reused" if t.base_compile_reused else "RECOMPILED")
    emit("incremental/artifact_reuse", 0.0,
         f"{t.reuse_fraction * 100:.1f}%")


if __name__ == "__main__":
    run()
