"""Fig 8/9/10 analogues:
- Fig 8: edge-triggered, segment-coalesced counters vs naive per-eqn
  instrumentation (the LUT-optimization analogue),
- Fig 9: analytical overhead model predictions vs measured,
- Fig 10: RealProbe probes vs full-trace ("ILA") instrumentation."""
import jax

from benchmarks.common import emit, layered_workload
from repro.core import OverheadModel, ProbeConfig, measure_overhead
from repro.core.hierarchy import extract


def _total_eqns(fn, args):
    closed = jax.make_jaxpr(fn)(*args)

    def count(jaxpr):
        n = len(jaxpr.eqns)
        import repro.core.costmodel as cm
        for eqn in jaxpr.eqns:
            for sub in cm._sub_jaxprs(eqn):
                n += count(cm._as_jaxpr(sub))
        return n
    return count(closed.jaxpr)


def run():
    fn, args = layered_workload(8, 48)

    # Fig 8 analogue: edge-triggered counters fire at SCOPE BOUNDARIES
    # only; a naive design samples the clock at every equation (2 events
    # per eqn). Compare event sites at equal per-event cost.
    ov = measure_overhead(fn, args, ProbeConfig(inline="off_all"))
    n_eqns = _total_eqns(fn, args)
    naive_sites = 2 * n_eqns
    our_sites = ov["event_sites"]
    ops_per_event = ov["extra_eqns"] / max(our_sites, 1)
    naive_ops = naive_sites * ops_per_event
    saving = 1.0 - ov["extra_eqns"] / max(naive_ops, 1)
    emit("overhead/edge_triggered_vs_naive", 0.0,
         f"probe_sites={our_sites};naive_sites={naive_sites};"
         f"probe_ops={ov['extra_eqns']};naive_ops={naive_ops:.0f};"
         f"saving={saving * 100:.1f}%")

    # Fig 9: analytical model vs measured (fit on 7 configs, held-out
    # test on the control-flow-heavy 8th — the config the seed model
    # mispriced by 28% before the cf_sites feature). The max_probes-
    # capped variants break the n_probes ~ 2*event_sites collinearity
    # of the targeted configs so the per-probe coefficient identifies.
    cfgs = [ProbeConfig(targets=("",), buffer_depth=4, inline="off_all"),
            ProbeConfig(targets=("layers",), buffer_depth=8,
                        inline="off_all"),
            ProbeConfig(targets=("head",), buffer_depth=4,
                        inline="off_all"),
            ProbeConfig(targets=("layers/scan#0/layer",), buffer_depth=16,
                        inline="off_all"),
            ProbeConfig(targets=("layers", "head"), buffer_depth=4,
                        inline="off_all"),
            ProbeConfig(buffer_depth=4, inline="off_all", max_probes=4),
            ProbeConfig(buffer_depth=4, inline="off_all", max_probes=7),
            ProbeConfig(targets=("dynamic",), buffer_depth=4,
                        inline="off_all")]
    samples = [measure_overhead(fn, args, c) for c in cfgs]
    model = OverheadModel.fit(samples[:7])
    worst = 0.0
    for i, s in enumerate(samples):
        pred = model.predict_eqns(s)
        err = abs(pred - s["extra_eqns"]) / max(s["extra_eqns"], 1) * 100
        worst = max(worst, err)
        emit(f"overhead/model_cfg{i}", 0.0,
             f"pred={pred:.0f};actual={s['extra_eqns']};"
             f"state_bytes={s['state_bytes']};err={err:.1f}%")
    # hard gate: every config (including the held-out one) within 10%
    assert worst <= 10.0, \
        f"overhead model err {worst:.1f}% exceeds the 10% gate"

    # Fig 10: probes (boundary counters) vs ILA-style full tracing
    # (recording EVERY equation's output checksum — signal-level capture)
    def ila_style(fn):
        def wrapped(*a):
            jax.make_jaxpr(fn)(*a)
            # cost of materializing a trace entry per eqn
            return None
        return wrapped
    probe_state = ov["state_bytes"]
    h = extract(jax.make_jaxpr(fn)(*args))
    total_eqns = sum(n.n_eqns for n in h.root.walk())
    ila_state = total_eqns * 8 * 2 * 131072 // 1024   # ILA: 128k samples/signal
    emit("overhead/probe_vs_ila_state", 0.0,
         f"probe_bytes={probe_state};ila_bytes~={ila_state};"
         f"ratio={ila_state / max(probe_state, 1):.0f}x")


if __name__ == "__main__":
    run()
