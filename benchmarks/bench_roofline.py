"""Framework roofline bench: summarize the dry-run table (per §Roofline)
as CSV rows; full table via `python -m repro.launch.roofline`."""
from benchmarks.common import emit
from repro.launch import roofline


def run():
    try:
        cells = roofline.load_cells("16x16")
    except Exception:
        cells = []
    if not cells:
        emit("roofline/NOT_RUN", 0.0, "run python -m repro.launch.dryrun")
        return
    for rec in cells:
        name = f"roofline/{rec['arch']}__{rec['shape']}"
        if rec.get("skipped"):
            emit(name, 0.0, "SKIP")
            continue
        if rec.get("error"):
            emit(name, 0.0, "ERROR")
            continue
        t = roofline.cell_terms(rec, 256)
        emit(name, t["bound_step_s"] * 1e6,
             f"dominant={t['dominant']};useful={t['useful_ratio']:.3f};"
             f"mem_GiB={rec['memory']['peak_estimate_bytes'] / 2**30:.2f}")


if __name__ == "__main__":
    run()
