"""Telemetry control-plane overhead + sentinel detection quality.

Publishing to the :class:`~repro.telemetry.TelemetryBus` happens only
decode-side (streaming-sink worker, window boundaries, engine phase
steps) — never inside the jitted step — so the cost that matters is
host nanoseconds per published ring row, and it must stay near zero
relative to the decode work it rides on.  Rows:

- ``telemetry/bus_publish``    — ``stream.add`` cost per 64-duration
  ring row, gated as ``bus_ns_per_row``.  NOTE: the committed baseline
  is a *budget* (generous multiple of the measured value on the
  baseline machine), not a point estimate — the gate exists to catch
  order-of-magnitude blowups (an accidental O(n) scan per row, a lock
  convoy), not scheduler noise on shared CI runners.
- ``telemetry/window_roll``    — window close + sentinel judgement.
- ``telemetry/sentinel_sweep`` — the seeded fault sweep from
  tests/test_telemetry.py as a metric: ``alerts`` (planted faults
  detected, HIGHER_BETTER) and ``false_positives`` (alerts on
  stationary traffic, LOWER_BETTER) are exact deterministic integers.
- ``telemetry/status_doc``     — /status + /probes + /metrics render.
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.telemetry import (DriftSentinel, TelemetryBus, render_metrics)
from repro.telemetry.server import _probes_doc, render_json
from repro.testing.faults import (FaultDriver, RampFault, StepFault,
                                  StragglerFault)


def run():
    rng = np.random.default_rng(0)

    # -- publish cost per ring row (64 durations, the sink's row shape)
    bus = TelemetryBus()
    stream = bus.stream("bench", tuple(f"p{i}" for i in range(8)))
    rows = [rng.integers(100, 100_000, 64) for _ in range(64)]
    for r in rows:                                  # warm caches
        stream.add(0, r)
    n = 2000
    t0 = time.perf_counter()
    for i in range(n):
        stream.add(i % 8, rows[i % len(rows)])
    dt = time.perf_counter() - t0
    ns_row = dt / n * 1e9
    emit("telemetry/bus_publish", dt / n * 1e6,
         f"bus_ns_per_row={ns_row:.0f};durations_per_row=64")

    # -- window roll + sentinel judgement (subscriber attached)
    DriftSentinel(bus)
    m = 500
    t0 = time.perf_counter()
    for i in range(m):
        stream.add(i % 8, rows[i % len(rows)])
        stream.roll(i, i + 1)
    dt = time.perf_counter() - t0
    emit("telemetry/window_roll", dt / m * 1e6,
         f"windows={stream.windows}")

    # -- detection quality: exact deterministic integers
    planted, detected, false_positives = 0, 0, 0
    scenarios = [
        [StepFault("attn", at_window=8)],
        [RampFault("mlp", start_window=8)],
        [StragglerFault(device=2, at_window=8)],
    ]
    for seed in range(3):
        for faults in scenarios:
            mesh = any(isinstance(f, StragglerFault) for f in faults)
            b = TelemetryBus()
            s = DriftSentinel(b)
            FaultDriver(b, seed=seed, n_devices=4 if mesh else 1,
                        faults=faults).run(20)
            planted += 1
            detected += bool(s.tripped())
        b = TelemetryBus()
        s = DriftSentinel(b)
        FaultDriver(b, seed=seed, n_devices=4).run(20)
        false_positives += len(s.tripped())
    emit("telemetry/sentinel_sweep", 0.0,
         f"alerts={detected};planted={planted};"
         f"false_positives={false_positives}")

    # -- serving-side render cost (what one HTTP poll computes)
    t0 = time.perf_counter()
    k = 50
    for _ in range(k):
        body = render_json(bus.status())
        body += render_json(_probes_doc(bus))
        body += render_metrics(bus).encode()
    emit("telemetry/status_doc", (time.perf_counter() - t0) / k * 1e6,
         f"resp_bytes={len(body)}")
