"""Fig 12 analogue: runtime vs DRAM dump ratio (0/25/50/100% of probes
spilling their rings to the host sink)."""
import jax

from benchmarks.common import emit, layered_workload, timeit
from repro.core import ProbeConfig, probe


def run():
    fn, args = layered_workload(10, 48)
    base = timeit(jax.jit(fn), *args)
    for ratio in (0.0, 0.25, 0.5, 1.0):
        pf = probe(fn, ProbeConfig(inline="off_all", buffer_depth=2,
                                   offload=ratio))
        pf.sink.reset()
        pf(*args)
        t = timeit(lambda *a: pf(*a)[0], *args, repeats=2)
        emit(f"offload/dump_{int(ratio * 100)}pct", t,
             f"dram_bytes={pf.sink.bytes_received};"
             f"overhead_vs_plain={100 * (t - base) / base:+.1f}%")


if __name__ == "__main__":
    run()
