"""CI benchmark-regression gate: compare fresh BENCH_*.json artifacts
against committed baselines and fail on any metric that regresses more
than the tolerance (default 15%).

    PYTHONPATH=src python benchmarks/run.py --only accuracy,overhead,dse \
        --out-dir /tmp/bench
    python benchmarks/check_regression.py \
        --baseline benchmarks/baselines --current /tmp/bench

What gets compared
------------------
Each row's ``derived`` string carries ``key=value`` pairs. Keys listed
in ``LOWER_BETTER`` / ``HIGHER_BETTER`` are deterministic model-clock or
resource metrics (cycles, state bytes, extra equations, ...) and are
gated at the tolerance on every machine. Wall-clock ``us_per_call``
values are only gated with ``--include-timing`` (meaningful on a quiet,
baseline-matched machine — not on shared CI runners).

Rows present in the baseline but missing from the current run fail the
gate (a silently dropped benchmark is a regression); new rows pass with
a note so adding metrics never blocks.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys
from typing import Dict, Iterator, List, Optional, Tuple

# deterministic metrics, gated by default
LOWER_BETTER = (
    "cycles", "span", "state_B", "state_bytes", "dram_B", "extra_eqns",
    "probe_ops", "probe_bytes", "measurements", "probed_steps",
    "mean_cycles", "skew", "wire_B", "err", "sub_walks",
    "retraces", "pages_peak", "bus_ns_per_row", "false_positives",
    "sim_us_per_config", "device_measurements", "evictions",
    "hol_blocked_steps",
)
HIGHER_BETTER = ("speedup_x1000", "saving", "exact", "cache_hits",
                 "reduction_x1000", "graphs", "invariants", "hit_x1000",
                 "alerts", "sweep_configs", "tok_per_step_x1000")

_NUM = re.compile(r"^(-?\d+(?:\.\d+)?)(?:[%x]?)$")


def parse_derived(derived: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        key, _, val = part.partition("=")
        m = _NUM.match(val.strip().split("/")[0])
        if m:
            out[key.strip()] = float(m.group(1))
    return out


def load_rows(path: str) -> Dict[str, dict]:
    with open(path) as f:
        art = json.load(f)
    if art.get("error"):
        raise SystemExit(f"{path}: bench recorded an error: {art['error']}")
    return {r["name"]: r for r in art.get("rows", [])}


def iter_metrics(row: dict, include_timing: bool
                 ) -> Iterator[Tuple[str, float, bool]]:
    """Yields (metric name, value, lower_is_better)."""
    for key, val in parse_derived(row.get("derived", "")).items():
        if key in LOWER_BETTER:
            yield key, val, True
        elif key in HIGHER_BETTER:
            yield key, val, False
    if include_timing and row.get("us_per_call", 0) > 0:
        yield "us_per_call", float(row["us_per_call"]), True


def compare(baseline_dir: str, current_dir: str, *, tolerance: float = 0.15,
            include_timing: bool = False, min_value: float = 1.0
            ) -> Tuple[List[str], List[str]]:
    """Returns (failures, notes)."""
    failures: List[str] = []
    notes: List[str] = []
    base_files = sorted(glob.glob(os.path.join(baseline_dir,
                                               "BENCH_*.json")))
    if not base_files:
        failures.append(f"no BENCH_*.json baselines in {baseline_dir}")
        return failures, notes
    for bf in base_files:
        name = os.path.basename(bf)
        cf = os.path.join(current_dir, name)
        if not os.path.exists(cf):
            failures.append(f"{name}: missing from current run")
            continue
        base_rows = load_rows(bf)
        cur_rows = load_rows(cf)
        for row_name, brow in base_rows.items():
            crow = cur_rows.get(row_name)
            if crow is None:
                failures.append(f"{name}:{row_name}: row disappeared")
                continue
            cur_metrics = dict((k, v) for k, v, _ in
                               iter_metrics(crow, include_timing))
            for metric, bval, lower in iter_metrics(brow, include_timing):
                if metric not in cur_metrics:
                    failures.append(
                        f"{name}:{row_name}.{metric}: metric disappeared")
                    continue
                cval = cur_metrics[metric]
                if abs(bval) < min_value and abs(cval) < min_value:
                    continue          # noise floor
                if lower:
                    worse = cval > bval * (1 + tolerance)
                else:
                    worse = cval < bval * (1 - tolerance)
                if worse:
                    direction = "up" if lower else "down"
                    failures.append(
                        f"{name}:{row_name}.{metric}: {bval:g} -> {cval:g} "
                        f"({direction} {abs(cval - bval) / max(abs(bval), 1e-12) * 100:.1f}%"
                        f" > {tolerance * 100:.0f}% tolerance)")
        extra = set(cur_rows) - set(base_rows)
        if extra:
            notes.append(f"{name}: {len(extra)} new row(s) not in baseline "
                         f"(ok): {sorted(extra)[:5]}")
    return failures, notes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if benchmark metrics regress vs baselines")
    ap.add_argument("--baseline", default="benchmarks/baselines",
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--current", required=True,
                    help="directory of freshly produced BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed relative regression (0.15 = 15%%)")
    ap.add_argument("--include-timing", action="store_true",
                    help="also gate wall-clock us_per_call values")
    ap.add_argument("--min-value", type=float, default=1.0,
                    help="ignore metrics below this absolute value")
    args = ap.parse_args(argv)

    failures, notes = compare(args.baseline, args.current,
                              tolerance=args.tolerance,
                              include_timing=args.include_timing,
                              min_value=args.min_value)
    for n in notes:
        print(f"NOTE  {n}")
    if failures:
        for f in failures:
            print(f"FAIL  {f}")
        print(f"# {len(failures)} regression(s) beyond "
              f"{args.tolerance * 100:.0f}%")
        return 1
    print("# benchmark regression gate: all metrics within "
          f"{args.tolerance * 100:.0f}% of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
