"""Shared benchmark workloads + CSV/JSON emission."""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.models import Model

# rows emitted since the last reset_rows() — run.py drains this into the
# per-bench BENCH_<name>.json artifacts (see docs/benchmarks.md)
_ROWS = []


def emit(name, us_per_call, derived=""):
    print(f"{name},{us_per_call:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(us_per_call, 1),
                  "derived": derived})


def reset_rows():
    _ROWS.clear()


def collect_rows():
    return list(_ROWS)


def timeit(fn, *args, repeats=3):
    fn(*args)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def layered_workload(n_layers=6, width=48):
    """The 28-test-case stand-in family: layered matmul programs with
    nested scopes, a loop, and a data-dependent while."""
    def fn(x, w):
        def body(c, _):
            with jax.named_scope("layer"):
                with jax.named_scope("attn"):
                    c = jnp.tanh(c @ w) @ w.T + c
                with jax.named_scope("mlp"):
                    c = jax.nn.silu(c @ w) @ w.T + c
            return c, None
        with jax.named_scope("layers"):
            x, _ = jax.lax.scan(body, x, None, length=n_layers)
        def cond(s):
            return jnp.sum(jnp.abs(s[0])) < 1e4
        def wbody(s):
            with jax.named_scope("grow"):
                return (s[0] * 1.3 + 0.1, s[1] + 1)
        with jax.named_scope("dynamic"):
            x, n = jax.lax.while_loop(cond, wbody, (x, jnp.int32(0)))
        with jax.named_scope("head"):
            return jnp.sum(x * x), n
    x = jnp.ones((max(8, width // 4), width)) * 0.02
    w = jnp.full((width, width), 1.0 / width)
    return fn, (x, w)


def model_workloads():
    """Real-model probe subjects across families."""
    out = {}
    for arch in ("tinyllama-1.1b", "granite-moe-1b-a400m", "mamba2-370m",
                 "zamba2-2.7b"):
        cfg = smoke_config(arch)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.zeros((2, 64), jnp.int32),
                 "labels": jnp.ones((2, 64), jnp.int32)}
        if cfg.frontend != "none":
            continue

        def mk(m):
            def step(params, batch):
                (loss, _), g = jax.value_and_grad(m.loss_fn, has_aux=True)(
                    params, batch)
                return loss
            return step

        out[arch] = (mk(m), (params, batch))
    return out
