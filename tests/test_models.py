"""Per-arch smoke tests (deliverable f): every assigned architecture as a
reduced config runs a real forward/train step on CPU with correct output
shapes and no NaNs; serving paths are consistent with training math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import (all_cells, get_config, list_archs,
                                    smoke_config)
from repro.models import Model
from tests.conftest import tiny_batch

ARCHS = list_archs()


def test_registry_complete():
    assert len(ARCHS) == 10
    assert len(all_cells()) == 40
    skips = [c for c in all_cells() if c[2]]
    # long_500k skipped exactly for the 8 non-sub-quadratic archs
    assert len(skips) == 8
    assert all(s[1] == "long_500k" for s in skips)
    for a in ("mamba2-370m", "zamba2-2.7b"):
        assert not any(c[0] == a for c in skips)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dims(arch):
    cfg = get_config(arch)
    if cfg.num_heads:
        assert cfg.num_heads % cfg.num_kv_heads == 0
        assert cfg.resolved_padded_heads >= cfg.num_heads
    assert cfg.padded_vocab_size >= cfg.vocab_size
    assert cfg.padded_vocab_size % 256 == 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, key):
    cfg = smoke_config(arch)
    m = Model(cfg)
    params = m.init(key)
    B, S = 2, 64
    batch = tiny_batch(cfg, B, S)
    loss, metrics = jax.jit(m.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    grads = jax.jit(jax.grad(lambda p, b: m.loss_fn(p, b)[0]))(params, batch)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, key):
    cfg = smoke_config(arch)
    m = Model(cfg)
    params = m.init(key)
    B = 2
    shape = ShapeConfig("t", seq_len=64, global_batch=B, kind="decode")
    cache = m.init_cache(shape)
    if cfg.frontend != "none":
        from repro.models.frontends import synth_frontend_batch
        fb = synth_frontend_batch(cfg, B, 1, jnp.bfloat16, key)
        batch = {"embeds": fb["embeds"], "pos": jnp.int32(3)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32), "pos": jnp.int32(3)}
    logits, cache2, nxt = jax.jit(m.decode_step)(params, cache, batch)
    assert logits.shape == (B, cfg.padded_vocab_size)
    assert np.isfinite(np.asarray(logits[:, :cfg.vocab_size])).all()
    assert nxt.shape == (B,)
    assert int(nxt.max()) < cfg.vocab_size      # pad logits masked


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-3-2b",
                                  "mamba2-370m", "zamba2-2.7b",
                                  "granite-moe-1b-a400m", "minicpm-2b"])
def test_prefill_decode_consistency(arch, key):
    import dataclasses
    cfg = smoke_config(arch).replace(compute_dtype="float32",
                                     kv_cache_dtype="float32")
    if cfg.moe is not None:
        # no token dropping for the exactness check (capacity is a
        # throughput/quality trade, not a correctness one)
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    m = Model(cfg)
    params = m.init(key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    _, pcache = m.prefill(params, {"tokens": toks[:, :S]}, 64)
    dl, _, _ = m.decode_step(params, pcache,
                             {"tokens": toks[:, S:S + 1],
                              "pos": jnp.int32(S)})
    pl2, _ = m.prefill(params, {"tokens": toks[:, :S + 1]}, 64)
    V = cfg.vocab_size           # pad columns are -inf by design
    dl, pl2 = dl[:, :V], pl2[:, :V]
    err = float(jnp.abs(dl - pl2).max() / (jnp.abs(pl2).max() + 1e-9))
    assert err < 5e-3, err


def test_vocab_padding_exact_loss(key):
    """Pad-vocab logits are -inf-masked: poisoning the pad columns of the
    unembedding with huge weights must not change the loss at all."""
    cfg = smoke_config("musicgen-large").replace(compute_dtype="float32")
    assert cfg.padded_vocab_size > cfg.vocab_size
    m = Model(cfg)
    params = m.init(key)
    batch = tiny_batch(cfg, 2, 32)
    l0, _ = m.loss_fn(params, batch)
    poisoned = jax.tree_util.tree_map(lambda a: a, params)
    poisoned["unembed"] = params["unembed"].at[:, cfg.vocab_size:].set(1e4)
    l1, _ = m.loss_fn(poisoned, batch)
    assert float(jnp.abs(l0 - l1)) < 1e-5


def test_head_padding_exact(key):
    """Padded q heads are hard-masked: same loss as unpadded weights."""
    cfg0 = smoke_config("granite-3-2b").replace(compute_dtype="float32")
    m0 = Model(cfg0)
    p0 = m0.init(key)
    cfg1 = cfg0.replace(padded_heads=6)
    m1 = Model(cfg1)
    p1 = m1.init(key)
    # copy real-head weights into the padded model
    def inject(dst, src):
        dst = jax.tree_util.tree_map(lambda a: a, dst)
        a0 = p0["stack"]["layers"]["attn"]
        a1 = p1["stack"]["layers"]["attn"]
        a1["wq"] = a1["wq"].at[:, :, :4].set(a0["wq"])
        a1["wo"] = a1["wo"].at[:, :4].set(a0["wo"])
        for k in ("wk", "wv"):
            a1[k] = a0[k]
        for k in set(p0) - {"stack"}:
            p1[k] = p0[k]
        for k in set(p0["stack"]) - {"layers"}:
            p1["stack"][k] = p0["stack"][k]
        for k in set(p0["stack"]["layers"]) - {"attn"}:
            p1["stack"]["layers"][k] = p0["stack"]["layers"][k]
    inject(p1, p0)
    batch = tiny_batch(cfg0, 2, 32)
    l0, _ = m0.loss_fn(p0, batch)
    l1, _ = m1.loss_fn(p1, batch)
    assert abs(float(l0) - float(l1)) < 1e-4


def test_moe_capacity_matches_ragged(key):
    import dataclasses
    from repro.models.moe import _moe_local
    cfg = smoke_config("granite-moe-1b-a400m")
    hi = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                             impl="capacity"))
    rg = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="ragged"))
    m = Model(hi)
    params = m.init(key)
    lp = jax.tree_util.tree_map(lambda a: a[0],
                                params["stack"]["layers"]["moe"])
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.1
    out_c, _ = _moe_local(x, lp["router"], lp["wi"], lp["wg"], lp["wo"], hi)
    out_r, _ = _moe_local(x, lp["router"], lp["wi"], lp["wg"], lp["wo"], rg)
    err = float(jnp.abs(out_c - out_r).max() / (jnp.abs(out_r).max() + 1e-9))
    assert err < 1e-5


def test_attention_matches_naive(key):
    from repro.models.attention import causal_flash_xla
    B, S, H, HD = 2, 128, 4, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, HD))
    k = jax.random.normal(ks[1], (B, S, H, HD))
    v = jax.random.normal(ks[2], (B, S, H, HD))
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(HD)
    mask = jnp.tril(jnp.ones((S, S), bool))
    p = jax.nn.softmax(jnp.where(mask[None, None], s, -jnp.inf), axis=-1)
    o_ref = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    o = causal_flash_xla(q, k, v, 32, 32)
    assert float(jnp.abs(o - o_ref).max()) < 2e-2


def test_flash_custom_vjp_grads(key):
    from repro.models.attention import causal_flash_xla
    B, S, H, HD = 2, 64, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, HD))
    k = jax.random.normal(ks[1], (B, S, H, HD))
    v = jax.random.normal(ks[2], (B, S, H, HD))

    def naive(q, k, v):
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(HD)
        mask = jnp.tril(jnp.ones((S, S), bool))
        p = jax.nn.softmax(jnp.where(mask[None, None], s, -jnp.inf), -1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    g1 = jax.grad(lambda *a: (causal_flash_xla(*a, 32, 32) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (naive(*a) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        rel = float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))
        assert rel < 3e-2


def test_ssd_chunked_matches_sequential(key):
    from repro.models.ssm import ssd_chunked_xla
    from repro.kernels.ref import ssd_ref
    B, L, H, P, G, N = 2, 64, 4, 8, 2, 16
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (B, L, H))) * 0.3
    b = jax.random.normal(ks[2], (B, L, G, N)) * 0.5
    c = jax.random.normal(ks[3], (B, L, G, N)) * 0.5
    y, fstate = ssd_chunked_xla(x, a, b, c, chunk=16, h_per_g=H // G,
                                return_final_state=True)
    y_ref, f_ref = ssd_ref(x.transpose(0, 2, 1, 3), a.transpose(0, 2, 1),
                           b.transpose(0, 2, 1, 3), c.transpose(0, 2, 1, 3))
    err = float(jnp.abs(y.transpose(0, 2, 1, 3) - y_ref).max())
    assert err < 1e-4
    f = fstate.reshape(B, H, P, N)
    assert float(jnp.abs(f - f_ref).max()) < 1e-4


def test_chunked_prefill_matches_plain(key):
    """Batch-chunked prefill (the 32k-prompt HBM lever) is exact."""
    from repro.configs.base import ShapeConfig
    from repro.distributed.steps import build_prefill_step
    cfg = smoke_config("tinyllama-1.1b").replace(compute_dtype="float32",
                                                 kv_cache_dtype="float32")
    m = Model(cfg)
    params = m.init(key)
    B, S = 4, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    shape = ShapeConfig("p", 64, B, "prefill")
    l1, c1 = jax.jit(build_prefill_step(m, shape))(params, {"tokens": toks})
    m2 = Model(cfg.replace(prefill_microbatches=2))
    l2, c2 = jax.jit(build_prefill_step(m2, shape))(params, {"tokens": toks})
    V = cfg.vocab_size
    assert float(jnp.abs(l1[:, :V] - l2[:, :V]).max()) < 1e-5
    for k2 in c1:
        assert jnp.array_equal(c1[k2], c2[k2]), k2
