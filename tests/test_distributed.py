"""Multi-device behavior on a small CPU mesh (subprocesses set
XLA_FLAGS=8 devices before jax init — the main test process stays at the
real device count, per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow   # subprocess-spawned 8-device meshes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PREAMBLE = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import smoke_config
from repro.configs.base import TrainConfig, ShapeConfig
from repro.models.model import Model
from repro.distributed import sharding as shd
from repro.distributed.steps import build_train_step, build_decode_step
from repro.launch.mesh import make_mesh
from repro.distributed.compat import mesh_context
from repro.optim import adamw
"""


def test_sharded_train_step_matches_single_device():
    """The distributed train step must compute the same loss as the
    single-device one (GSPMD is an implementation detail)."""
    code = PREAMBLE + """
cfg = smoke_config("tinyllama-1.1b").replace(compute_dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, S = 8, 64
k = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
tcfg = TrainConfig(total_steps=10, warmup_steps=1)
step = build_train_step(model, tcfg)
opt = adamw.init(params, cfg.moment_dtype)

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# 2x4 mesh
mesh = make_mesh((2, 4), ("data", "model"))
rules = shd.filter_rules(shd.TRAIN_RULES, mesh)
pspecs = shd.schema_pspecs(model.schema(), rules, mesh)
psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
bsh = {kk: NamedSharding(mesh, P("data")) for kk in batch}
osh = adamw.AdamWState(step=NamedSharding(mesh, P()), mu=psh, nu=psh)
with mesh_context(mesh), shd.axis_rules(rules, mesh):
    p2, o2, m2 = jax.jit(step, in_shardings=(psh, osh, bsh))(params, opt, batch)
print(json.dumps({"l1": float(m1["loss"]), "l2": float(m2["loss"])}))
"""
    out = json.loads(run_sub(code).strip().splitlines()[-1])
    assert abs(out["l1"] - out["l2"]) / abs(out["l1"]) < 2e-3, out


def test_sharded_decode_step_matches_single_device():
    code = PREAMBLE + """
cfg = smoke_config("granite-3-2b").replace(compute_dtype="float32",
                                           kv_cache_dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
B = 8
shape = ShapeConfig("t", seq_len=64, global_batch=B, kind="decode")
cache = model.init_cache(shape)
batch = {"tokens": jnp.zeros((B, 1), jnp.int32), "pos": jnp.int32(5)}
step = build_decode_step(model)
l1, _, _ = jax.jit(step)(params, cache, batch)
mesh = make_mesh((2, 4), ("data", "model"))
rules = shd.filter_rules(shd.SERVE_RULES, mesh)
with mesh_context(mesh), shd.axis_rules(rules, mesh):
    l2, _, _ = jax.jit(step)(params, model.init_cache(shape), batch)
V = cfg.vocab_size   # pad columns are -inf by design
l1, l2 = l1[:, :V], l2[:, :V]
err = float(jnp.abs(l1 - l2).max() / (jnp.abs(l1).max() + 1e-9))
print(json.dumps({"err": err}))
"""
    out = json.loads(run_sub(code).strip().splitlines()[-1])
    assert out["err"] < 2e-3, out


def test_moe_shard_map_matches_local():
    code = PREAMBLE + """
cfg = smoke_config("granite-moe-1b-a400m").replace(compute_dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, S = 8, 32
k = jax.random.PRNGKey(2)
batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
l1, _ = jax.jit(model.loss_fn)(params, batch)
mesh = make_mesh((2, 4), ("data", "model"))
rules = shd.filter_rules(shd.TRAIN_RULES, mesh)
with mesh_context(mesh), shd.axis_rules(rules, mesh):
    l2, _ = jax.jit(model.loss_fn)(params, batch)
print(json.dumps({"l1": float(l1), "l2": float(l2)}))
"""
    out = json.loads(run_sub(code).strip().splitlines()[-1])
    # routing is data-parallel-local: sort order within shard differs, but
    # at high capacity nothing drops -> losses must match closely
    assert abs(out["l1"] - out["l2"]) / abs(out["l1"]) < 5e-3, out


def test_int8_ef_grad_compression_pod_axis():
    """Compressed cross-pod exchange: loss finite, params update, and
    the result stays close to the uncompressed step. On jax 0.4.x this
    exercises compat.shard_map's full-manual fallback (partial-manual
    regions abort the old SPMD partitioner)."""
    code = PREAMBLE + """
from repro.optim import compression
cfg = smoke_config("tinyllama-1.1b").replace(compute_dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, S = 8, 32
k = jax.random.PRNGKey(3)
batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
tcfg0 = TrainConfig(total_steps=10, warmup_steps=1)
tcfg1 = TrainConfig(total_steps=10, warmup_steps=1,
                    grad_compression="int8_ef")
opt = adamw.init(params, cfg.moment_dtype)
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = shd.filter_rules(shd.TRAIN_RULES, mesh)
res = compression.init_residual(params)
with mesh_context(mesh), shd.axis_rules(rules, mesh):
    step0 = build_train_step(model, tcfg0)
    p0, _, m0 = jax.jit(step0)(params, opt, batch)
    step1 = build_train_step(model, tcfg1)
    p1, _, r1, m1 = jax.jit(step1)(params, opt, batch, res)
d0 = jax.tree_util.tree_leaves(p0)
d1 = jax.tree_util.tree_leaves(p1)
# one AdamW step moves params by <= ~lr; int8-EF quantization error is
# bounded by the same scale (deadzoned small grads recover via the
# residual over subsequent steps)
abs_diff = max(float(jnp.abs(a - b).max()) for a, b in zip(d0, d1))
print(json.dumps({"l0": float(m0["loss"]), "l1": float(m1["loss"]),
                  "abs_diff": abs_diff}))
"""
    out = json.loads(run_sub(code).strip().splitlines()[-1])
    assert abs(out["l0"] - out["l1"]) / abs(out["l0"]) < 2e-3
    # bounded by ~2 optimizer steps' worth of movement (lr=3e-4)
    assert out["abs_diff"] < 2 * 3e-4 + 1e-6, out


def test_microbatched_grads_match_full_batch():
    code = PREAMBLE + """
cfg = smoke_config("tinyllama-1.1b").replace(compute_dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, S = 8, 32
k = jax.random.PRNGKey(4)
batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
opt = adamw.init(params, cfg.moment_dtype)
p1, _, m1 = jax.jit(build_train_step(model, TrainConfig()))(params, opt, batch)
p4, _, m4 = jax.jit(build_train_step(model, TrainConfig(microbatches=4)))(
    params, opt, batch)
rel = max(float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
          for a, b in zip(jax.tree_util.tree_leaves(p1),
                          jax.tree_util.tree_leaves(p4)))
print(json.dumps({"rel": rel, "l1": float(m1["loss"]), "l4": float(m4["loss"])}))
"""
    out = json.loads(run_sub(code).strip().splitlines()[-1])
    assert abs(out["l1"] - out["l4"]) / abs(out["l1"]) < 1e-3
    assert out["rel"] < 5e-3, out
