"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py),
all in interpret mode (the kernel body executes as traced JAX ops)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import flash_attention as fa
from repro.kernels import ssd_scan as ssdk


def _rel(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-9))


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 4, 4, 128, 32),      # MHA
    (2, 8, 2, 256, 64),      # GQA 4:1
    (1, 4, 1, 128, 64),      # MQA
    (2, 2, 2, 64, 16),       # tiny
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, S, D, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B * H + S), 3)
    q = jax.random.normal(k1, (B, H, S, D)).astype(dtype)
    k = jax.random.normal(k2, (B, Hkv, S, D)).astype(dtype)
    v = jax.random.normal(k3, (B, Hkv, S, D)).astype(dtype)
    o = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert _rel(o.astype(jnp.float32), o_ref.astype(jnp.float32)) < tol


@pytest.mark.parametrize("bq,bk,pp", [
    (64, 64, 2),       # pipelined kv groups
    (64, 64, 4),
    (128, 64, 1),      # unequal blocks: diagonal spans >1 kv block per q
    (64, 32, 4),       #   block (regression: finalize/skip used the q
    (256, 64, 4),      #   block's FIRST row instead of its last)
    (32, 64, 2),
])
def test_flash_attention_blocks_pipeline(bq, bk, pp):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (2, 4, 256, 32))
    k = jax.random.normal(k2, (2, 4, 256, 32))
    v = jax.random.normal(k3, (2, 4, 256, 32))
    for causal in (True, False):
        o = fa.flash_attention(q, k, v, causal=causal, block_q=bq,
                               block_k=bk, pipeline=pp, interpret=True)
        o_ref = ref.flash_attention_ref(q, k, v, causal=causal)
        assert _rel(o, o_ref) < 2e-5, (bq, bk, pp, causal)


def test_ssd_scan_pipeline():
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    B, H, G, L, P, N = 1, 4, 2, 128, 16, 32
    x = jax.random.normal(ks[0], (B, H, L, P)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (B, H, L))) * 0.3
    b = jax.random.normal(ks[2], (B, G, L, N)) * 0.5
    c = jax.random.normal(ks[3], (B, G, L, N)) * 0.5
    y_ref, _ = ref.ssd_ref(x, a, b, c)
    for chunk, pp in [(64, 2), (64, 4), (128, 4), (32, 2)]:
        y = ssdk.ssd_scan(x, a, b, c, chunk=chunk, pipeline=pp,
                          interpret=True)
        assert _rel(y, y_ref) < 2e-5, (chunk, pp)


def test_flash_attention_noncausal():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (1, 2, 128, 32))
    k = jax.random.normal(k2, (1, 2, 128, 32))
    v = jax.random.normal(k3, (1, 2, 128, 32))
    o = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    o_ref = ref.flash_attention_ref(q, k, v, causal=False)
    assert _rel(o, o_ref) < 2e-5


def test_flash_probe_decoupled():
    """The RealProbe in-kernel counters must not change the datapath."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (2, 4, 256, 32))
    k = jax.random.normal(k2, (2, 4, 256, 32))
    v = jax.random.normal(k3, (2, 4, 256, 32))
    o0 = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    o1, probe = ops.flash_attention(q, k, v, causal=True, block_q=64,
                                    block_k=64, with_probe=True)
    assert jnp.array_equal(o0, o1)
    nq = 256 // 64
    probe = np.asarray(probe)
    # visited = all kv blocks; computed = causal prefix only
    assert (probe[..., 0] == nq).all()
    assert (probe[0, 0, :, 1] == np.arange(nq) + 1).all()


@pytest.mark.parametrize("B,H,G,L,P,N,chunk", [
    (1, 4, 1, 128, 16, 32, 32),
    (2, 4, 2, 64, 8, 16, 16),
    (1, 2, 2, 96, 16, 64, 32),
])
def test_ssd_scan_sweep(B, H, G, L, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(L + P), 4)
    x = jax.random.normal(ks[0], (B, H, L, P)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (B, H, L))) * 0.3
    b = jax.random.normal(ks[2], (B, G, L, N)) * 0.5
    c = jax.random.normal(ks[3], (B, G, L, N)) * 0.5
    y = ssdk.ssd_scan(x, a, b, c, chunk=chunk, interpret=True)
    y_ref, _ = ref.ssd_ref(x, a, b, c)
    assert _rel(y, y_ref) < 2e-5


def test_ssd_model_adapter_matches_xla_path():
    from repro.models.ssm import ssd_chunked_xla
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    B, L, H, P, G, N = 2, 64, 4, 8, 2, 16
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (B, L, H))) * 0.3
    b = jax.random.normal(ks[2], (B, L, G, N)) * 0.5
    c = jax.random.normal(ks[3], (B, L, G, N)) * 0.5
    y_xla, _ = ssd_chunked_xla(x, a, b, c, chunk=16, h_per_g=H // G,
                               return_final_state=True)
    y_pl = ops.ssd_scan(x, a, b, c, chunk=16)
    assert _rel(y_pl, y_xla) < 2e-5


def test_flash_gqa_adapter_matches_model_path():
    from repro.models.attention import causal_flash_xla
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, H, HD = 2, 128, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, HD))
    k = jax.random.normal(ks[1], (B, S, H, HD))
    v = jax.random.normal(ks[2], (B, S, H, HD))
    o_xla = causal_flash_xla(q, k, v, 64, 64)
    o_pl = ops.flash_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               causal=True).transpose(0, 2, 1, 3)
    assert _rel(o_pl, o_xla) < 5e-3   # model path uses bf16 dots
