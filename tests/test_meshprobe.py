"""Mesh-aware probing invariants.

Fast tests run in-process (1-device meshes and pure decoding/reduction
logic need no multi-device backend). The end-to-end 8-device
guarantees — per-device records integer-equal to per-shard oracle
replays, bit-identical outputs under shard_map, session aggregation
exact vs one-shot, deterministic skew — run in a subprocess that forces
an 8-device host platform before jax initializes (the dry-run isolation
rule, like tests/test_distributed.py)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CycleRecord, ProbeConfig, StreamAggregator, mesh_probe
from repro.launch.mesh import make_mesh, parse_mesh_arg, probe_axis_names

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------ fast part

def test_make_mesh_raises_with_factorizations():
    dc = jax.device_count()
    bad = dc * 2 + 1                       # never divides the device count
    with pytest.raises(ValueError) as e:
        make_mesh((bad,), ("dev",))
    msg = str(e.value)
    assert str(bad) in msg and "factorization" in msg and f"({dc},)" in msg
    with pytest.raises(ValueError):
        make_mesh((1, bad), ("a", "b"))
    with pytest.raises(ValueError):        # shape/axes arity mismatch
        make_mesh((1, 1), ("a",))
    assert make_mesh((1,), ("dev",)).devices.size == 1


def test_parse_mesh_arg():
    assert parse_mesh_arg(None) == ()
    assert parse_mesh_arg("") == ()
    assert parse_mesh_arg("8") == (8,)
    assert parse_mesh_arg("2x4") == (2, 4)
    assert parse_mesh_arg("2,4") == (2, 4)
    with pytest.raises(ValueError):
        parse_mesh_arg("2xbanana")
    assert probe_axis_names((8,)) == ("dev",)
    assert probe_axis_names((2, 4)) == ("dev0", "dev1")


def _record(totals, mesh_shape=(4,), paths=("a", "b")):
    totals = np.asarray(totals, np.int64)
    D, n = totals.shape
    return CycleRecord(
        mesh_axes=tuple(f"d{i}" for i in range(len(mesh_shape))),
        mesh_shape=tuple(mesh_shape), paths=tuple(paths),
        cycle=totals.sum(axis=1), starts=np.zeros_like(totals),
        ends=totals, totals=totals,
        calls=np.ones_like(totals),
        ring=np.zeros((D, n, 2, 2), np.int64))


def test_cycle_record_reductions_and_skew():
    rec = _record([[10, 1], [20, 1], [30, 1], [40, 5]])
    assert np.array_equal(rec.reduce("max"), [40, 5])
    assert np.array_equal(rec.reduce("mean"), [25.0, 2.0])
    assert rec.reduce("per-device").shape == (4, 2)
    assert np.array_equal(rec.skew(), [30, 4])
    assert rec.straggler() == (3, "a")
    assert rec.coords(3) == (3,)
    assert rec.row("a", device=2) == 30
    dev = rec.device(1)
    assert dev["cycle"] == 21 and list(dev["totals"]) == [20, 1]
    with pytest.raises(ValueError):
        rec.reduce("median")


def test_zero_probe_record_renders_without_crash():
    """Unknown targets select zero probes — every view must degrade
    gracefully (the single-device invariant, kept under a mesh)."""
    from repro.core.report import (mesh_device_table, mesh_heat,
                                   mesh_session_table)
    rec = _record(np.zeros((4, 0), np.int64), paths=())
    assert rec.straggler() == (0, "")
    assert rec.skew().shape == (0,)
    assert mesh_heat(rec) == "(no probes selected)"
    assert "mesh" in mesh_device_table(rec)

    class Snap:
        record, steps, state_nbytes = rec, 3, 0
    assert "mesh session" in mesh_session_table(Snap())


def test_stream_aggregator_cross_device_modes():
    # device-major rows: (device, probe) for D=3, n=2
    agg = StreamAggregator(6)
    for row, total in enumerate([5, 1, 7, 2, 9, 6]):
        agg.add(row, np.array([total]))
    assert np.array_equal(agg.reduce("max", n_devices=3), [9, 6])
    assert np.array_equal(agg.reduce("mean", n_devices=3), [7.0, 3.0])
    assert agg.reduce("per-device", n_devices=3).shape == (3, 2)
    assert np.array_equal(agg.skew(3), [4, 5])
    with pytest.raises(ValueError):
        agg.reduce("min", n_devices=3)


def _workload():
    def step(x, w):
        def body(c, _):
            with jax.named_scope("layer"):
                c = jnp.tanh(c @ w) + c
            return c, None
        with jax.named_scope("layers"):
            x, _ = jax.lax.scan(body, x, None, length=3)
        with jax.named_scope("sync"):
            g = jax.lax.pmean(jnp.sum(x * x), "dev")
        with jax.named_scope("head"):
            return jnp.sum(x * x) + g
    return step


def test_mesh_probe_single_device_mesh(tiny_mesh):
    """The full pipeline on a 1-device mesh: exact oracle equality,
    bit-identical outputs, collective attribution, report rendering."""
    mesh = tiny_mesh
    step = _workload()
    x = jnp.arange(16.0).reshape(4, 4) * 0.1
    w = jnp.full((4, 4), 0.25)
    from jax.sharding import PartitionSpec as P
    mpf = mesh_probe(step, mesh, in_specs=(P("dev"), P()), out_specs=P(),
                     config=ProbeConfig(inline="off_all"))
    out, state = mpf(x, w)
    rec = mpf.decode(state)
    assert rec.n_devices == 1 and rec.totals.shape[0] == 1
    # bit-identity vs the uninstrumented shard_map
    ref = mpf.unprobed()(x, w)
    assert np.array_equal(np.asarray(out), np.asarray(ref))
    # oracle equality (the ILA check), device 0
    oc = mpf.oracle(x, w, device=0)
    assert list(rec.device(0)["totals"]) == oc.totals
    assert list(rec.device(0)["calls"]) == list(oc.calls)
    assert rec.device(0)["cycle"] == oc.cycle
    # collective attribution: the pmean lives under "sync"
    sites = mpf.collectives()
    assert any(s.path == "sync" and s.kind == "all-reduce" for s in sites)
    rep = mpf.report(state)
    assert "sync" in rep.comm_table()
    assert "dev0" in rep.device_table()
    assert "skew" in rep.device_table()
    assert "heat" in rep.heat("layers")
    # stateful threading accumulates (session substrate)
    st = mpf.init_state()
    for _ in range(3):
        _, st = mpf.stateful_call(st, x, w)
    rec3 = mpf.decode(st)
    assert np.array_equal(rec3.totals, 3 * rec.totals)


def test_mesh_probe_rejects_wallclock(tiny_mesh):
    with pytest.raises(ValueError):
        mesh_probe(lambda x: x, tiny_mesh, None, None,
                   ProbeConfig(cycle_source="wallclock"))


def test_shard_oracle_resolves_axis_index():
    """ShardOracle replays a device-dependent loop exactly for each
    mesh coordinate — without any multi-device backend."""
    from repro.core.hierarchy import extract
    from repro.core.instrument import ProbeAssignment
    from repro.core.meshprobe import ShardOracle
    from repro.distributed import compat

    def fn(x):
        i = jax.lax.axis_index("dev")
        def cond(s):
            return s[1] < i + 1
        def body(s):
            with jax.named_scope("grow"):
                return (s[0] * 1.5, s[1] + 1)
        with jax.named_scope("dynamic"):
            x, n = jax.lax.while_loop(cond, body, (x, jnp.int32(0)))
        return jnp.sum(x), n

    with compat.extend_axis_env({"dev": 4}):
        closed = jax.make_jaxpr(fn)(jnp.ones((4,)))
    h = extract(closed)
    asg = ProbeAssignment(paths=("dynamic",), depth=4, spill=(False,))
    totals = []
    for d in range(4):
        oc = ShardOracle(h, asg, {"dev": d}).run(closed,
                                                 [np.ones(4, np.float32)])
        assert oc.calls[0] == 1
        totals.append(oc.totals[0])
    # trip count == device index + 1 -> strictly increasing cycle totals
    assert totals == sorted(totals) and len(set(totals)) == 4


# ------------------------------------------------- 8-device subprocess

def run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_mesh_probe_8_devices_end_to_end():
    """Acceptance criteria on a forced 8-device mesh, one subprocess:
    (1) per-device cycle records integer-equal to per-shard oracle
    replays on every device, (2) bit-identical model outputs with
    probes on/off under shard_map, (3) session reduction modes exact vs
    one-shot, (4) deterministic nonzero skew from a device-dependent
    loop, (5) per-device + heat report views render."""
    code = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from repro.core import MeshProbeSession, ProbeConfig, mesh_probe
from repro.launch.mesh import make_mesh

mesh = make_mesh((8,), ("dev",))

def step(x, w):
    def body(c, _):
        with jax.named_scope("layer"):
            c = jnp.tanh(c @ w) + c
        return c, None
    with jax.named_scope("layers"):
        x, _ = jax.lax.scan(body, x, None, length=4)
    with jax.named_scope("sync"):
        g = jax.lax.pmean(jnp.sum(x * x), "dev")
    i = jax.lax.axis_index("dev")
    def cond(s): return s[1] < i + 1
    def grow(s):
        with jax.named_scope("grow"):
            return (s[0] * 1.1, s[1] + 1)
    with jax.named_scope("dynamic"):
        x, n = jax.lax.while_loop(cond, grow, (x, jnp.int32(0)))
    with jax.named_scope("head"):
        return jnp.sum(x * x) + g, n

x = jnp.arange(64.0).reshape(16, 4) * 0.01
w = jnp.full((4, 4), 0.25)
cfg = ProbeConfig(inline="off_all")
fn_traces = [0]
def counted_step(x, w):
    fn_traces[0] += 1
    return step(x, w)
mpf = mesh_probe(step, mesh, in_specs=(P("dev"), P()), out_specs=P(),
                 config=cfg)
(out, n), state = mpf(x, w)
rec = mpf.decode(state)

# (1) oracle equality for EVERY device
oracle_ok = True
for d in range(8):
    oc = mpf.oracle(x, w, device=d)
    dev = rec.device(d)
    oracle_ok &= (list(dev["totals"]) == oc.totals and
                  list(dev["calls"]) == list(oc.calls) and
                  list(dev["starts"]) == oc.starts and
                  list(dev["ends"]) == oc.ends and
                  dev["cycle"] == oc.cycle)

# (2) bit identity probes on/off
ref_out, ref_n = mpf.unprobed()(x, w)
bit_ok = (np.array_equal(np.asarray(out), np.asarray(ref_out)) and
          np.array_equal(np.asarray(n), np.asarray(ref_n)))

# (3) session: K steps, totals and reductions exact vs one-shot
K = 5
with MeshProbeSession(mesh_probe(counted_step, mesh, (P("dev"), P()), P(),
                                 cfg), window_steps=2) as s:
    sizes = []
    for _ in range(K):
        s.step(x, w)
        sizes.append(getattr(s.mpf._jitted_stateful, "_cache_size",
                             lambda: None)())
    snap = s.snapshot()
    # zero retraces: the user function is traced ONCE for the whole
    # session, and the executable cache is steady from step 2 on (the
    # 0.4.x C++ fastpath adds one signature entry without re-lowering)
    steady = (sizes[0] is None or len(set(sizes[1:])) == 1)
    traces = fn_traces[0] if steady else -1
sess_ok = (np.array_equal(snap.record.totals, K * rec.totals) and
           np.array_equal(snap.record.reduce("max"), K * rec.reduce("max")) and
           np.array_equal(snap.record.skew(), K * rec.skew()) and
           np.array_equal(snap.stats.reduce("per-device", 8),
                          snap.record.totals) and
           np.array_equal(snap.stats.skew(8), snap.record.skew()))

# (4) deterministic skew from the device-dependent while loop
pid = rec.paths.index("dynamic")
skew = int(rec.skew()[pid])
per_dev = rec.totals[:, pid]
mono = bool(np.all(np.diff(per_dev) > 0))

# (5) report views render
rep = mpf.report(state)
views_ok = ("dev7" in rep.device_table() and "heat" in rep.heat() and
            "sync" in rep.comm_table() and "mesh session" in snap.table())

print(json.dumps({"oracle_ok": bool(oracle_ok), "bit_ok": bool(bit_ok),
                  "sess_ok": bool(sess_ok), "skew": skew, "mono": mono,
                  "views_ok": bool(views_ok), "traces": traces}))
"""
    out = json.loads(run_sub(code).strip().splitlines()[-1])
    assert out["oracle_ok"], out
    assert out["bit_ok"], out
    assert out["sess_ok"], out
    assert out["skew"] > 0 and out["mono"], out
    assert out["views_ok"], out
    assert out["traces"] in (None, 1), out


@pytest.mark.slow
def test_dp_train_step_probed_on_mesh_matches_unprobed():
    """The data-parallel train step builder is probeable per device and
    non-intrusive: params after a probed step are bit-identical to the
    unprobed shard_map step, and per-device grad_exchange cycles carry
    the collective term."""
    code = """
import jax, jax.numpy as jnp, numpy as np, json
from jax.sharding import PartitionSpec as P
from repro.configs.registry import smoke_config
from repro.configs.base import TrainConfig
from repro.core import ProbeConfig, mesh_probe
from repro.distributed.steps import build_dp_train_step
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.optim import adamw

cfg = smoke_config("tinyllama-1.1b").replace(compute_dtype="float32")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw.init(params, cfg.moment_dtype)
B, S = 8, 32
k = jax.random.PRNGKey(1)
batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
mesh = make_mesh((8,), ("dev",))
step = build_dp_train_step(model, TrainConfig(total_steps=10,
                                              warmup_steps=1), axis="dev")
mpf = mesh_probe(step, mesh,
                 in_specs=(P(), P(), P("dev")), out_specs=(P(), P(), P()),
                 config=ProbeConfig(targets=("grad_exchange", "optimizer")))
(p1, o1, m1), state = mpf(params, opt, batch)
p2, o2, m2 = mpf.unprobed()(params, opt, batch)
bit_ok = all(np.array_equal(np.asarray(a), np.asarray(b))
             for a, b in zip(jax.tree_util.tree_leaves((p1, o1, m1)),
                             jax.tree_util.tree_leaves((p2, o2, m2))))
rec = mpf.decode(state)
ge = rec.totals[:, rec.paths.index("grad_exchange")]
comm = [s for s in mpf.collectives() if s.path.startswith("grad_exchange")]
print(json.dumps({"bit_ok": bool(bit_ok),
                  "ge_min": int(ge.min()), "n_comm": len(comm),
                  "wire": sum(s.wire_bytes for s in comm),
                  "loss": float(m1["loss"])}))
"""
    out = json.loads(run_sub(code).strip().splitlines()[-1])
    assert out["bit_ok"], out
    assert out["ge_min"] > 0, out          # exchange cycles recorded/device
    assert out["n_comm"] > 0 and out["wire"] > 0, out
    assert np.isfinite(out["loss"]), out
