"""Packed-SoA probe-state layout equivalence (the PR-5 overhaul net).

The packed layout (contiguous counter planes, batched transition
scatters, scalar clock words, enter-subtract/exit-add totals) must be
observationally identical to the retained legacy dict-of-small-arrays
layout: same decoded records bit for bit, same oracle integer equality,
same spill streams, and bit-identical model outputs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProbeConfig, probe
from repro.core.buffer import state_bytes
from repro.core.instrument import (STATE_LAYOUT_VERSION, decode_record,
                                   init_state, state_layout, state_totals)


def _nested(x, w):
    def inner(c, _):
        with jax.named_scope("inner"):
            return jnp.tanh(c @ w) + c, None

    def outer(c, _):
        with jax.named_scope("group"):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            with jax.named_scope("mix"):
                c = c @ w.T @ w
        return c, None

    with jax.named_scope("outer"):
        x, _ = jax.lax.scan(outer, x, None, length=2)

    def cond(s):
        return jnp.sum(jnp.abs(s[0])) < 1e3

    def grow(s):
        with jax.named_scope("grow"):
            return (s[0] * 1.4 + 0.1, s[1] + 1)

    with jax.named_scope("dynamic"):
        x, n = jax.lax.while_loop(cond, grow, (x, jnp.int32(0)))
    with jax.named_scope("head"):
        return jnp.sum(x * x), n


_ARGS = (jnp.ones((4, 8)) * 0.05, jnp.full((8, 8), 0.07))


def _decoded_pair(cfg):
    recs = {}
    outs = {}
    pfs = {}
    for layout in ("packed", "legacy"):
        pf = probe(_nested, cfg.replace(layout=layout))
        out, rec = pf(*_ARGS)
        pfs[layout], outs[layout], recs[layout] = pf, out, rec
    return pfs, outs, recs


def _assert_decoded_equal(dp, dl):
    assert set(dp) == set(dl)
    for key in dp:
        assert np.array_equal(np.asarray(dp[key]), np.asarray(dl[key])), key


@pytest.mark.parametrize("cfg", [
    ProbeConfig(inline="off_all"),
    ProbeConfig(inline="off_all", buffer_depth=2),
    ProbeConfig(inline="off_all", buffer_depth=2, offload=1.0),
    ProbeConfig(inline="off_all", buffer_depth=8, offload=0.5),
    ProbeConfig(targets=("outer",), buffer_depth=3),
], ids=["default", "depth2", "spill_all", "spill_half", "targeted"])
def test_packed_decode_equals_legacy(cfg):
    pfs, outs, recs = _decoded_pair(cfg)
    assert state_layout(recs["packed"]) == "packed"
    assert state_layout(recs["legacy"]) == "legacy"
    _assert_decoded_equal(decode_record(recs["packed"]),
                          decode_record(recs["legacy"]))
    # model outputs bit-identical across layouts
    for a, b in zip(jax.tree_util.tree_leaves(outs["packed"]),
                    jax.tree_util.tree_leaves(outs["legacy"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # spill streams identical (offloaded history reassembles the same)
    for pid in range(pfs["packed"].assignment.n):
        if pfs["packed"].assignment.spill[pid]:
            assert pfs["packed"].sink.records(pid) == \
                pfs["legacy"].sink.records(pid), pid


def test_both_layouts_match_oracle_exactly():
    for layout in ("packed", "legacy"):
        pf = probe(_nested, ProbeConfig(inline="off_all", layout=layout))
        _, rec = pf(*_ARGS)
        dec = decode_record(rec)
        oc = pf.oracle(*_ARGS)
        for i, p in enumerate(pf.probe_paths()):
            assert int(dec["totals"][i]) == oc.totals[i], (layout, p)
            assert int(dec["calls"][i]) == oc.calls[i], (layout, p)
            assert int(dec["starts"][i]) == oc.starts[i], (layout, p)
            assert int(dec["ends"][i]) == oc.ends[i], (layout, p)
        assert dec["cycle"] == oc.cycle, layout


def test_kernel_oracle_exact_under_packed_layout():
    """KernelOracle grid-step replay stays integer-equal with the packed
    state threaded through the intra-kernel cycles-only scan."""
    from repro.kernels import flash_attention as fa

    def fn(q, k, v):
        with jax.named_scope("attn"):
            return fa.flash_attention(q, k, v, causal=True, block_q=32,
                                      block_k=32, interpret=True)

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (1, 1, 64, 16)) for kk in ks)
    pf = probe(fn, ProbeConfig(inline="off_all", kernel_probes=("*",)))
    out, rec = pf(q, k, v)
    assert np.array_equal(np.asarray(out),
                          np.asarray(jax.jit(fn)(q, k, v)))  # bit-identity
    dec = decode_record(rec)
    oc = pf.oracle(q, k, v)
    assert list(dec["totals"]) == oc.totals
    assert list(dec["calls"]) == list(oc.calls)
    assert dec["cycle"] == oc.cycle
    assert any(p.endswith("/grid") for p in pf.probe_paths())


def test_shard_oracle_exact_under_packed_layout(tiny_mesh):
    """ShardOracle per-device replay stays integer-equal with the packed
    state carried as the device-sharded buffer."""
    from jax.sharding import PartitionSpec as P
    from repro.core import mesh_probe

    mesh = tiny_mesh

    def body(x, w):
        with jax.named_scope("block"):
            y = jnp.tanh(x @ w)
        with jax.named_scope("mix"):
            return jax.lax.psum(y, "dev")

    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4) * 0.01
    w = jnp.full((4, 4), 0.1, jnp.float32)
    mpf = mesh_probe(body, mesh, (P("dev"), P()), P(),
                     ProbeConfig(inline="off_all"))
    out, state = mpf(x, w)
    rec = mpf.decode(state)
    for d in range(mpf.n_devices):
        oc = mpf.oracle(x, w, device=d)
        dev = rec.device(d)
        assert list(dev["totals"]) == oc.totals, d
        assert list(dev["calls"]) == list(oc.calls), d
        assert dev["cycle"] == oc.cycle, d


def test_state_layout_shapes_and_bytes():
    st_p = init_state(5, 4)
    st_l = init_state(5, 4, layout="legacy")
    assert st_p["cnt"].shape == (3, 5, 2)
    assert st_p["cyc_hi"].shape == () and st_p["cyc_lo"].shape == ()
    assert len(jax.tree_util.tree_leaves(st_p)) == 5
    assert len(jax.tree_util.tree_leaves(st_l)) == 7
    # packed drops the LAST plane: 8 bytes per probe
    assert state_bytes(5, 4, layout="legacy") - state_bytes(5, 4) == 5 * 8
    assert np.array_equal(state_totals(st_p), np.zeros(5))
    assert np.array_equal(state_totals(st_l), np.zeros(5))
    assert STATE_LAYOUT_VERSION >= 2


def test_eval_cache_key_depends_on_layout_version(tmp_path, monkeypatch):
    """On-disk DSE measurements recorded under one probe-state layout
    must miss when the layout version changes (satellite: stale dict-
    layout caches can never serve packed-layout runs)."""
    import repro.core.instrument as inst
    from repro.core.incremental import EvalCache

    cache = EvalCache(str(tmp_path))
    cache.put("k", {"a": 1}, "fp", "dev", cycles_per_step=10.0, steps=3)
    assert cache.get("k", {"a": 1}, "fp", "dev") is not None
    key_now = EvalCache.entry_key("k", {"a": 1}, "fp", "dev")
    monkeypatch.setattr(inst, "STATE_LAYOUT_VERSION",
                        inst.STATE_LAYOUT_VERSION + 1)
    assert EvalCache.entry_key("k", {"a": 1}, "fp", "dev") != key_now
    assert cache.get("k", {"a": 1}, "fp", "dev") is None
