"""Intra-kernel grid-step probing (core.kernelprobe).

The Table-II exactness contract, one level below the jaxpr: for every
probed ``pallas_call`` the device-side grid-step counters must equal
the ``KernelOracle``'s Python-integer replay EXACTLY, the datapath must
stay bit-identical probed vs unprobed, and the kernel subtree must obey
sum-of-grid-steps == kernel-scope totals. Exhaustive block/pipeline
sweeps are ``slow``; the fast subset keeps one representative per
kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelOracle, ProbeConfig, ProbeSession,
                        kernel_grid_heat, kernel_grid_table, probe)
from repro.core.instrument import decode_record
from repro.kernels import flash_attention as fa
from repro.kernels import ssd_scan as ssdk


def _flash_args(B=1, H=2, S=128, D=32, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(k1, (B, H, S, D)),
            jax.random.normal(k2, (B, H, S, D)),
            jax.random.normal(k3, (B, H, S, D)))


def _flash_fn(bq, bk, pp=1, causal=True):
    def fn(q, k, v):
        with jax.named_scope("attn"):
            return fa.flash_attention(q, k, v, causal=causal, block_q=bq,
                                      block_k=bk, pipeline=pp,
                                      interpret=True)
    return fn


def _ssd_args(B=1, H=2, L=128, P=16, N=32, G=2, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (jax.random.normal(ks[0], (B, H, L, P)) * 0.5,
            -jnp.abs(jax.random.normal(ks[1], (B, H, L))) * 0.3,
            jax.random.normal(ks[2], (B, G, L, N)) * 0.5,
            jax.random.normal(ks[3], (B, G, L, N)) * 0.5)


def _ssd_fn(chunk, pp=1):
    def fn(x, a, b, c):
        with jax.named_scope("ssd"):
            return ssdk.ssd_scan(x, a, b, c, chunk=chunk, pipeline=pp,
                                 interpret=True)
    return fn


KCFG = ProbeConfig(inline="off_all", kernel_probes=("*",))


def _decoded(rec):
    dec = decode_record(rec)
    return dec["totals"], dec["calls"]


def _assert_oracle_exact(pf, rec, oc):
    dec = decode_record(rec)
    for i, p in enumerate(pf.probe_paths()):
        assert int(dec["totals"][i]) == oc.totals[i], p
        assert int(dec["calls"][i]) == oc.calls[i], p
        assert int(dec["starts"][i]) == oc.starts[i], p
        assert int(dec["ends"][i]) == oc.ends[i], p
    assert dec["cycle"] == oc.cycle


def _assert_grid_invariants(pf, rec):
    """kernel totals == grid totals; grid calls == steps x kernel calls."""
    totals, calls = _decoded(rec)
    paths = list(pf.probe_paths())
    h = pf.hierarchy
    seen = 0
    for i, p in enumerate(paths):
        node = h.node(p)
        if node is None or node.kind != "kernel":
            continue
        seen += 1
        gp = p + "/grid"
        gi = paths.index(gp)
        gnode = h.node(gp)
        assert int(totals[i]) == int(totals[gi]), p
        assert int(calls[gi]) == int(np.prod(gnode.grid)) * int(calls[i]), p
        # inner scopes never exceed their grid parent
        for j, q in enumerate(paths):
            if q.startswith(gp + "/"):
                assert int(totals[j]) <= int(totals[gi]), q
    assert seen, "no kernel nodes probed"


# ------------------------------------------------------------ fast set

def test_flash_grid_probe_exact_and_bit_identical():
    fn = _flash_fn(64, 64)
    args = _flash_args()
    pf = probe(fn, KCFG)
    out, rec = pf(*args)
    assert jnp.array_equal(out, jax.jit(fn)(*args))      # bit-identity
    _assert_oracle_exact(pf, rec, pf.oracle(*args))
    _assert_grid_invariants(pf, rec)
    assert any(p.endswith("/grid/kv_block") for p in pf.probe_paths())


def test_ssd_grid_probe_exact_and_bit_identical():
    fn = _ssd_fn(32, pp=2)
    args = _ssd_args()
    pf = probe(fn, KCFG)
    out, rec = pf(*args)
    assert jnp.array_equal(out, jax.jit(fn)(*args))
    _assert_oracle_exact(pf, rec, pf.oracle(*args))
    _assert_grid_invariants(pf, rec)
    assert any(p.endswith("/grid/sub_chunk") for p in pf.probe_paths())


def test_causal_skip_shows_in_grid_steps():
    """Measured per-step cycles must expose the causal triangle — the
    signal the flat cost model cannot see (and what DSE calibration
    feeds on): skipped (iq, ik) tiles are cheaper than computed ones."""
    fn = _flash_fn(64, 64)
    pf = probe(fn, KCFG.replace(offload=1.0, buffer_depth=4))
    _, rec = pf(*_flash_args())
    rep = pf.report(rec)
    grid_row = next(r for r in rep.rows if r.path.endswith("/grid"))
    durs = [e - s for s, e in grid_row.iters]
    assert len(durs) == grid_row.calls                  # full history
    assert max(durs) > min(durs)                        # skew exists
    assert sum(durs) == grid_row.total_cycles           # lossless
    table = kernel_grid_table(pf.hierarchy, rep)
    heat = kernel_grid_heat(pf.hierarchy, rep)
    assert "skew" in table and "flash_kernel#0/grid" in table
    assert "heat" in heat and "skew=" in heat


def test_noncausal_grid_steps_balanced_in_kv_block():
    """Without the causal predicate every kv_block visit computes, so
    the kv_block scope splits evenly across grid steps."""
    fn = _flash_fn(64, 64, causal=False)
    pf = probe(fn, KCFG.replace(offload=1.0, buffer_depth=4))
    _, rec = pf(*_flash_args())
    rep = pf.report(rec)
    row = next(r for r in rep.rows if r.path.endswith("/kv_block"))
    durs = [e - s for s, e in row.iters]
    assert len(set(durs)) == 1


def test_kernel_probes_off_is_seed_behavior():
    fn = _flash_fn(64, 64)
    pf = probe(fn, ProbeConfig(inline="off_all"))
    _, rec = pf(*_flash_args())
    assert not any("/kernel/" in p for p in pf.probe_paths())
    _assert_oracle_exact(pf, rec, pf.oracle(*_flash_args()))


def test_retarget_flips_kernel_probes_without_retracing():
    fn = _flash_fn(64, 64)
    args = _flash_args()
    pf = probe(fn, ProbeConfig(inline="off_all"))
    pf(*args)
    closed = pf._closed
    assert not any("/kernel/" in p for p in pf.probe_paths())
    pf.retarget(KCFG)
    _, rec = pf(*args)
    assert pf._closed is closed            # trace reused, only re-extracted
    assert any("/kernel/" in p for p in pf.probe_paths())
    _assert_grid_invariants(pf, rec)


def test_kernel_probes_reject_wallclock():
    pf = probe(_flash_fn(64, 64),
               ProbeConfig(kernel_probes=("*",), cycle_source="wallclock"))
    with pytest.raises(ValueError, match="model"):
        pf(*_flash_args())


def test_kernel_probe_name_filter():
    fn = _flash_fn(64, 64)
    pf = probe(fn, ProbeConfig(inline="off_all",
                               kernel_probes=("ssd_kernel",)))
    pf(*_flash_args())
    assert not any("/kernel/" in p for p in pf.probe_paths())


def test_session_accumulates_grid_rows():
    """ProbeSession sees intra-kernel rows with zero API change; calls
    accumulate steps x grid size with no retrace."""
    fn = _flash_fn(64, 64)
    args = _flash_args()
    with ProbeSession(fn, KCFG.replace(offload=1.0)) as s:
        for _ in range(3):
            s.step(*args)
        snap = s.snapshot()
    grid = [r for r in snap.rows if r.path.endswith("/grid")]
    assert grid, snap.rows
    steps = int(np.prod(
        s.pf.hierarchy.node(grid[0].path).grid))
    assert grid[0].calls == 3 * steps
    assert grid[0].total_cycles > 0


def test_mesh_record_sees_kernel_rows(tiny_mesh):
    """MeshProbeSession/CycleRecord path: a kernel-probed shard body on
    a 1-device mesh produces grid rows integer-equal to ShardOracle."""
    from jax.sharding import PartitionSpec as P
    from repro.core import mesh_probe

    mesh = tiny_mesh
    args = _flash_args(S=64, D=16)
    fn = _flash_fn(32, 32)
    mpf = mesh_probe(fn, mesh, in_specs=(P(), P(), P()), out_specs=P(),
                     config=KCFG)
    out, state = mpf(*args)
    assert np.array_equal(np.asarray(out), np.asarray(mpf.unprobed()(*args)))
    rec = mpf.decode(state)
    gi = [i for i, p in enumerate(rec.paths) if p.endswith("/grid")]
    assert gi, rec.paths
    oc = mpf.oracle(*args, device=0)
    assert list(rec.device(0)["totals"]) == oc.totals
    assert rec.device(0)["cycle"] == oc.cycle


def test_kernel_oracle_grid_totals_helper():
    fn = _flash_fn(64, 64)
    args = _flash_args()
    pf = probe(fn, KCFG)
    _, rec = pf(*args)
    orc = KernelOracle(pf.hierarchy, pf.assignment)
    flat = jax.tree_util.tree_leaves(args)
    oc = orc.run(pf.hierarchy.closed_jaxpr, flat)
    gt = orc.grid_totals(oc, pf.probe_paths())
    totals, _ = _decoded(rec)
    for path, cyc in gt.items():
        assert cyc == int(totals[list(pf.probe_paths()).index(path)])


def test_dse_tile_calibration_shrinks_residual():
    """The calibrated cost model prices tiles with measured grid-step
    cycles: calibrating on the default config must shrink the per-tile
    residual of a DIFFERENT config (bench_dse gates this end to end)."""
    from repro.core import DSEEngine, EvalCache
    from repro.core import costmodel as cm
    from repro.kernels.search_spaces import flash_attention_space
    import tempfile

    space = flash_attention_space(B=1, H=1, S=128, D=16,
                                  blocks_q=(64, 128), blocks_k=(64,),
                                  pipelines=(1,))
    eng = DSEEngine(space, cache=EvalCache(tempfile.mkdtemp()),
                    max_steps=1)
    try:
        # both configs tile the q axis, so both have causal skips the
        # static max-branch model over-prices
        src = eng.analyze({"block_q": 64, "block_k": 64, "pipeline": 1})
        dst = eng.analyze({"block_q": 32, "block_k": 32, "pipeline": 1})
        eng.measure_tiles(src)
        eng.measure_tiles(dst)
        assert src.tile_measured is not None
        assert src.tile_residual > 0              # causal skips unseen
        uncal = abs(dst.tile_residual)
        scale = eng.calibrate([src])              # learn on src only
        assert scale is not None and 0 < scale < 1
        # exact self-convergence: the ratio is over the body term only
        # (DMA subtracted), so re-analyzing the source config must land
        # on its measured tiles up to integer rounding
        src_cal = eng.analyze(src.config)
        self_resid = abs(src_cal.resources.static_cycles /
                         src_cal.resources.grid_steps - src.tile_measured)
        assert self_resid <= 1.0, self_resid
        dst_cal = eng.analyze(dst.config)
        cal = abs(dst_cal.resources.static_cycles /
                  dst_cal.resources.grid_steps - dst.tile_measured)
        assert cal < uncal                        # transfers to dst
    finally:
        cm.clear_kernel_calibration()


def test_measure_tiles_survives_deep_scope_nesting():
    """Grid probes must not be crowded out of the probe budget by
    shallow wrapper scopes (measure_tiles retargets onto the kernel
    subtrees), and a kernel-free space must fail loudly."""
    from repro.core import DSEEngine, EvalCache
    from repro.core.dse import SearchSpace
    import tempfile

    args = _flash_args(B=1, H=1, S=64, D=16)

    def bind(cfg):
        def fn(q, k, v):
            out = (q, k, v)
            import contextlib
            with contextlib.ExitStack() as stack:
                for i in range(20):           # > max_probes shallow scopes
                    stack.enter_context(jax.named_scope(f"wrap{i}"))
                return fa.flash_attention(*out, causal=True, block_q=32,
                                          block_k=32, interpret=True)
        return fn

    space = SearchSpace(kernel_id="flash_attention", axes={"pipeline": (1,)},
                        bind=bind, args=args, default={"pipeline": 1})
    eng = DSEEngine(space, cache=EvalCache(tempfile.mkdtemp()))
    t = eng.analyze({"pipeline": 1})
    eng.measure_tiles(t)
    assert t.tile_measured is not None and t.tile_measured > 0

    plain = SearchSpace(kernel_id="none", axes={"a": (1,)},
                        bind=lambda cfg: (lambda q, k, v: q + k + v),
                        args=args, default={"a": 1})
    eng2 = DSEEngine(plain, cache=EvalCache(tempfile.mkdtemp()))
    t2 = eng2.analyze({"a": 1})
    with pytest.raises(ValueError, match="no statically-gridded"):
        eng2.measure_tiles(t2)


# ------------------------------------------- exhaustive sweeps (slow)

@pytest.mark.slow
@pytest.mark.parametrize("bq,bk,pp", [
    (64, 64, 2), (64, 32, 2), (128, 64, 1), (32, 64, 2), (128, 32, 4),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_grid_sweep_exact(bq, bk, pp, causal):
    fn = _flash_fn(bq, bk, pp, causal)
    args = _flash_args(S=128)
    pf = probe(fn, KCFG)
    out, rec = pf(*args)
    assert jnp.array_equal(out, jax.jit(fn)(*args))
    _assert_oracle_exact(pf, rec, pf.oracle(*args))
    _assert_grid_invariants(pf, rec)


@pytest.mark.slow
@pytest.mark.parametrize("chunk,pp", [(64, 2), (64, 4), (128, 4), (32, 1)])
def test_ssd_grid_sweep_exact(chunk, pp):
    fn = _ssd_fn(chunk, pp)
    args = _ssd_args()
    pf = probe(fn, KCFG)
    out, rec = pf(*args)
    assert jnp.array_equal(out, jax.jit(fn)(*args))
    _assert_oracle_exact(pf, rec, pf.oracle(*args))
    _assert_grid_invariants(pf, rec)
