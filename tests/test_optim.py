"""Optimizer substrate: AdamW math, schedules, clipping, int8 moments,
error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.optim import adamw, compression
from repro.optim.quantized import QTensor, dequantize, quantize
from repro.optim.schedule import make_schedule


def test_adamw_matches_reference():
    tcfg = TrainConfig(learning_rate=1e-2, weight_decay=0.0, beta1=0.9,
                       beta2=0.999, eps=1e-8, warmup_steps=0, total_steps=10,
                       grad_clip=1e9)
    sched = lambda step: 1e-2
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st = adamw.init(p)
    p1, st1, _ = adamw.update(p, g, st, tcfg, sched)
    # closed-form single step: m=0.1g_hat... bias-corrected Adam
    m = 0.1 * np.asarray(g["w"]) / (1 - 0.9)
    v = 0.001 * np.asarray(g["w"]) ** 2 / (1 - 0.999)
    expect = np.asarray(p["w"]) - 1e-2 * m / (np.sqrt(v) + 1e-8)
    assert np.allclose(np.asarray(p1["w"]), expect, rtol=1e-5)
    assert int(st1.step) == 1


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 3.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 3.0 * np.sqrt(10)) < 1e-4
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5


def test_wsd_schedule_shape():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                       stable_ratio=0.5)
    f = make_schedule("wsd", tcfg)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6       # end of warmup
    assert abs(float(f(30)) - 1.0) < 1e-6       # stable plateau
    assert float(f(99)) < 0.2                   # decayed
    # monotone decay after stable phase
    xs = [float(f(s)) for s in range(55, 100, 5)]
    assert all(a >= b for a, b in zip(xs, xs[1:]))


def test_cosine_schedule_bounds():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=5, total_steps=50)
    f = make_schedule("cosine", tcfg)
    vals = [float(f(s)) for s in range(51)]
    assert max(vals) <= 1.0 + 1e-6
    assert vals[-1] >= 0.1 - 1e-6               # floor at 10%


def test_int8_moments_close_to_f32():
    tcfg = TrainConfig(warmup_steps=1, total_steps=20, learning_rate=1e-2)
    sched = make_schedule("cosine", tcfg)
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (8, 300))}
    s32, s8 = adamw.init(params, "float32"), adamw.init(params, "int8")
    p32, p8 = params, dict(params)
    for i in range(8):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (8, 300)) * 0.1}
        p32, s32, _ = adamw.update(p32, g, s32, tcfg, sched)
        p8, s8, _ = adamw.update(p8, g, s8, tcfg, sched)
    drift = float(jnp.abs(p32["w"] - p8["w"]).max() / jnp.abs(p32["w"]).max())
    assert drift < 0.03
    assert isinstance(s8.mu["w"], QTensor)
    assert s8.mu["w"].q.dtype == jnp.int8


def test_quantize_roundtrip_error_bound():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (16, 128)) * 5.0
    err = jnp.abs(dequantize(quantize(x)) - x)
    rowmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float((err / rowmax).max()) <= (0.5 / 127) + 1e-6


def test_compression_error_feedback_unbiased_over_time():
    """Error feedback: the accumulated applied signal converges to the
    accumulated true signal (residual stays bounded)."""
    key = jax.random.PRNGKey(2)
    g_true = jax.random.normal(key, (64,))
    res = compression.init_residual({"g": g_true})
    applied = jnp.zeros((64,))
    for i in range(20):
        payload, scales, res = compression.compress({"g": g_true}, res)
        deq = compression.decompress(payload, scales)
        applied = applied + deq["g"]
    # applied ~= 20 * g_true within the (bounded) residual
    err = float(jnp.abs(applied - 20 * g_true).max())
    assert err < float(jnp.abs(g_true).max())   # residual never grows
