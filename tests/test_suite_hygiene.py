"""Test-suite speed audit: the fast CI subset must stay fast.

CI's tier-1 job runs ``-m "not slow"`` under a hard step timeout; the
heavyweight end-to-end modules and the kernel-probe exhaustive sweeps
must therefore carry ``@pytest.mark.slow``. These checks are static
(marks and workflow text), so a heavy test silently joining the fast
subset fails here instead of timing out CI twenty minutes later."""
import os
import re

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# modules whose every test is heavyweight (subprocess meshes, full
# train/serve loops): module-level pytestmark required
SLOW_MODULES = ("test_distributed.py",)

# individually slow tests: exhaustive kernel-probe sweeps, full train
# loops and train-step probing must never run in the fast subset
SLOW_TESTS = {
    "test_kernelprobe.py": ("test_flash_grid_sweep_exact",
                            "test_ssd_grid_sweep_exact"),
    "test_probe_accuracy.py": ("test_probe_train_step_exact",),
    "test_system.py": ("test_training_loss_decreases",
                       "test_training_resume_continues",
                       "test_probed_production_train_step",
                       "test_dryrun_cell_machinery_smoke"),
    "test_conformance_sweep.py": (
        "test_discovering_spec_seed5_full_conformance",),
    "test_telemetry.py": ("test_e2e_live_decode_with_status_server",),
}

# corpus/registry parametrizations where only a fast head stays in the
# tier-1 subset: the decorator block must route the tail through
# pytest.param(..., marks=pytest.mark.slow)
SLOW_PARAM_TESTS = {
    "test_conformance_sweep.py": ("test_corpus_graph_conformance",),
    "test_registry_probes.py": ("test_arch_probed_records_match_golden",),
}


def _read(name):
    with open(os.path.join(REPO, "tests", name)) as f:
        return f.read()


def _decorator_block(src: str, name: str) -> str:
    """Source between the previous top-level def/class and ``name``'s
    def — i.e. the target's decorators (however many lines they span)."""
    m = re.search(r"^def " + re.escape(name) + r"\b", src, re.M)
    assert m, f"{name} missing (renamed without updating the speed audit?)"
    prev = [p.end() for p in
            re.finditer(r"^(?:def|class) \w+.*$", src, re.M)
            if p.end() < m.start()]
    return src[(prev[-1] if prev else 0):m.start()]


def test_heavy_modules_are_slow_marked():
    """Every test in the heavyweight modules is excluded from the fast
    subset — via a module-level pytestmark or per-test marks."""
    for mod in SLOW_MODULES:
        src = _read(mod)
        if re.search(r"^pytestmark\s*=\s*pytest\.mark\.slow", src, re.M):
            continue
        n_tests = len(re.findall(r"^def test_", src, re.M))
        n_slow = len(re.findall(r"@pytest\.mark\.slow", src))
        assert n_slow >= n_tests, \
            f"{mod}: {n_tests} tests but only {n_slow} slow marks"


def test_exhaustive_sweeps_are_slow_marked():
    for mod, names in SLOW_TESTS.items():
        src = _read(mod)
        for name in names:
            assert "pytest.mark.slow" in _decorator_block(src, name), \
                f"{mod}: {name} must be @pytest.mark.slow"


def test_partially_slow_parametrizations_route_tail_to_slow():
    """Corpus-style parametrizations keep a small fast head; the rest of
    the id range must flow through pytest.param(..., marks=slow)."""
    for mod, names in SLOW_PARAM_TESTS.items():
        src = _read(mod)
        assert "marks=pytest.mark.slow" in src, \
            f"{mod}: no slow-routed parametrize tail"
        for name in names:
            assert "parametrize" in _decorator_block(src, name), \
                f"{mod}: {name} must be parametrized"


def test_fast_job_keeps_hard_timeout_and_slow_filter():
    """The CI fast job must exclude slow tests AND keep a hard timeout
    at or below the current budget (raising it is a reviewed decision,
    not a drive-by)."""
    with open(os.path.join(REPO, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert 'not slow' in ci
    step_timeouts = [int(x) for x in
                     re.findall(r"timeout-minutes:\s*(\d+)", ci)]
    assert step_timeouts and max(step_timeouts) <= 30


def test_slow_marker_registered():
    with open(os.path.join(REPO, "pytest.ini")) as f:
        assert "slow:" in f.read()


def test_no_hard_coded_ports_in_tests():
    """Network-facing tests must bind port 0 and read the real port
    back (``StatusServer.port``) — a hard-coded port is a flake on any
    shared CI runner."""
    pat = re.compile(r"""(?:localhost|127\.0\.0\.1)[:"']{1,2}\s*(\d{2,5})"""
                     r"""|port\s*=\s*(\d+)""")
    for name in sorted(os.listdir(os.path.join(REPO, "tests"))):
        if not name.endswith(".py"):
            continue
        for m in pat.finditer(_read(name)):
            port = int(m.group(1) or m.group(2))
            assert port == 0, \
                f"{name}: hard-coded port {port} ({m.group(0)!r}); " \
                f"bind port=0 and read the bound port back"
