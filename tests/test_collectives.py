"""Ring wire-byte model: HLO text parsing edge cases (variadic tuples,
token operands, iota replica groups) and the traced-jaxpr view that the
mesh probe joins against the scope hierarchy."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.collectives import (jaxpr_collectives,
                                      parse_collective_bytes,
                                      parse_replica_group_size,
                                      ring_wire_bytes)


def test_ring_wire_bytes_formulas():
    assert ring_wire_bytes("all-gather", 800, 8) == 800 * 7 / 8
    assert ring_wire_bytes("reduce-scatter", 100, 4) == 300
    assert ring_wire_bytes("all-reduce", 400, 4) == 2 * 400 * 3 / 4
    assert ring_wire_bytes("all-to-all", 160, 2) == 80
    assert ring_wire_bytes("collective-permute", 64, 1) == 64
    # G == 1 moves nothing for group collectives
    assert ring_wire_bytes("all-reduce", 400, 1) == 0
    assert ring_wire_bytes("all-gather", 400, 1) == 0
    with pytest.raises(ValueError):
        ring_wire_bytes("all-of-the-above", 1, 2)


def test_replica_group_parsing_edge_cases():
    # explicit groups: G = size of the FIRST group
    assert parse_replica_group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert parse_replica_group_size("replica_groups={{0},{1}}") == 1
    # empty group braces -> all devices, size unknown -> 1 (no traffic)
    assert parse_replica_group_size("replica_groups={{}}") == 1
    # iota form: [n_groups, group_size]<=[total]
    assert parse_replica_group_size("replica_groups=[2,4]<=[8]") == 4
    assert parse_replica_group_size("replica_groups=[8,1]<=[8]") == 1
    # absent attribute (collective-permute)
    assert parse_replica_group_size("source_target_pairs={{0,1}}") == 1


def test_parse_hlo_variadic_tuple_and_token():
    hlo = "\n".join([
        # variadic all-reduce over a tuple INCLUDING a token operand
        "  ar = (f32[4,8]{1,0}, bf16[16]{0}, token[]) all-reduce(a, b, t), "
        "replica_groups={{0,1,2,3}}, to_apply=add",
        # async pair: -start counted once, -done skipped
        "  ag = f32[32,8]{1,0} all-gather-start(x), replica_groups=[2,4]<=[8]"
        ", dimensions={0}",
        "  agd = f32[32,8]{1,0} all-gather-done(ag)",
        # permute has no replica_groups
        "  cp = u32[2]{0} collective-permute(y), "
        "source_target_pairs={{0,1},{1,0}}",
        # a non-collective line must not match
        "  d = f32[8,8]{1,0} dot(p, q), lhs_contracting_dims={1}",
    ])
    out = parse_collective_bytes(hlo)
    ar = out["all-reduce"]
    # token[] contributes 0 bytes; f32[4,8] + bf16[16] = 128 + 32
    assert ar["count"] == 1 and ar["result_bytes"] == 160
    assert ar["wire_bytes"] == pytest.approx(2 * 160 * 3 / 4)
    ag = out["all-gather"]
    assert ag["count"] == 1 and ag["result_bytes"] == 32 * 8 * 4
    assert ag["wire_bytes"] == pytest.approx(32 * 8 * 4 * 3 / 4)
    cp = out["collective-permute"]
    assert cp["count"] == 1 and cp["wire_bytes"] == 8
    assert "dot" not in out and len(out) == 3


def test_jaxpr_collectives_joins_scopes_and_groups():
    from repro.distributed import compat

    def fn(x):
        with jax.named_scope("sync"):
            s = jax.lax.psum(x, "a")            # over axis a (size 2)
        with jax.named_scope("gather"):
            g = jax.lax.all_gather(x, "b")      # over axis b (size 4)
        return jnp.sum(s) + jnp.sum(g)

    sizes = {"a": 2, "b": 4}
    with compat.extend_axis_env(sizes):
        closed = jax.make_jaxpr(fn)(jnp.ones((8,), jnp.float32))
    sites = {s.primitive: s for s in
             jaxpr_collectives(closed.jaxpr, sizes)}
    psum = sites["psum"]
    assert psum.kind == "all-reduce" and psum.group_size == 2
    assert psum.result_bytes == 32
    assert psum.wire_bytes == pytest.approx(2 * 32 * 1 / 2)
    ag = sites["all_gather"]
    assert ag.kind == "all-gather" and ag.group_size == 4
    assert ag.result_bytes == 4 * 32            # gathered along axis b
    assert ag.wire_bytes == pytest.approx(4 * 32 * 3 / 4)


def test_costmodel_collective_term_responds_to_mesh_size():
    """With axis sizes in context the collective term uses ring wire
    bytes (mesh-size sensitive); without, the legacy operand-bytes
    fallback keeps old numbers (baseline compatibility)."""
    from repro.core import costmodel as cm
    from repro.distributed import compat

    def fn(x):
        return jax.lax.psum(x, "dev")

    with compat.extend_axis_env({"dev": 8}):
        closed = jax.make_jaxpr(fn)(jnp.ones((4096,), jnp.float32))
    (eqn,) = [e for e in closed.jaxpr.eqns if e.primitive.name == "psum"]
    legacy = cm.eqn_cost(eqn)
    assert legacy.comm_bytes == 4096 * 4        # operand bytes fallback
    with cm.collective_axis_sizes({"dev": 8}):
        c8 = cm.eqn_cost(eqn)
    with cm.collective_axis_sizes({"dev": 2}):
        c2 = cm.eqn_cost(eqn)
    assert c8.comm_bytes == int(2 * 4096 * 4 * 7 / 8 + 0.5)
    assert c2.comm_bytes == int(2 * 4096 * 4 * 1 / 2)
    assert c8.cycles > c2.cycles                # bigger ring, more cycles
    with cm.collective_axis_sizes(None):
        assert cm.eqn_cost(eqn).comm_bytes == legacy.comm_bytes
