"""End-to-end behaviour: real training runs converge, the probed train
step is non-intrusive and exact, serving decodes, dry-run machinery
lowers a small cell."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import probe, ProbeConfig
from repro.core.instrument import decode_record


@pytest.mark.slow
def test_training_loss_decreases(tmp_path):
    from repro.launch.train import train
    _, _, hist = train("tinyllama-1.1b", steps=40, batch=4, seq=64,
                       checkpoint_dir=str(tmp_path / "ck"), log_every=100)
    first = np.mean(hist[:5])
    last = np.mean(hist[-5:])
    assert last < first - 0.15, (first, last)


@pytest.mark.slow
def test_training_resume_continues(tmp_path):
    from repro.checkpoint import Checkpointer
    from repro.launch.train import train
    d = str(tmp_path / "ck")
    train("tinyllama-1.1b", steps=10, batch=2, seq=32, checkpoint_dir=d,
          log_every=100)
    assert Checkpointer(d).latest() == 10
    # resume to 14 from the stored state (exactly-once data accounting)
    _, _, hist = train("tinyllama-1.1b", steps=14, batch=2, seq=32,
                       checkpoint_dir=d, resume=True, log_every=100)
    assert len(hist) == 4


def test_serve_decodes_tokens():
    from repro.launch.serve import serve
    toks = serve("tinyllama-1.1b", batch=2, prompt_len=16, max_new=4,
                 cache_len=32)
    assert toks.shape == (2, 4)
    from repro.configs.registry import smoke_config
    assert toks.max() < smoke_config("tinyllama-1.1b").vocab_size


@pytest.mark.slow
def test_probed_production_train_step(key):
    """RealProbe on the REAL train step (optimizer included): exact vs
    oracle + identical numerics to the unprobed step."""
    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.distributed.steps import build_train_step
    from repro.models import Model
    from repro.optim import adamw

    cfg = smoke_config("mamba2-370m")
    model = Model(cfg)
    params = model.init(key)
    opt = adamw.init(params, cfg.moment_dtype)
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    step = build_train_step(model, TrainConfig(total_steps=10,
                                               warmup_steps=1))
    pf = probe(step, ProbeConfig(max_probes=25))
    (p1, o1, m1), rec = pf(params, opt, batch)
    p0, o0, m0 = jax.jit(step)(params, opt, batch)
    assert np.allclose(float(m0["loss"]), float(m1["loss"]), rtol=1e-6)
    oc = pf.oracle(params, opt, batch)
    dec = decode_record(rec)
    for i, path in enumerate(pf.probe_paths()):
        assert int(dec["totals"][i]) == oc.totals[i], path
    rep = pf.report(rec)
    assert rep.bottleneck() is not None
    assert rep.timeline()


@pytest.mark.slow
def test_dryrun_cell_machinery_smoke():
    """lower_cell-equivalent flow on 1 device with a smoke config: the
    same builders + sharding plumbing the 512-way dry-run uses."""
    from repro.configs.base import ShapeConfig, TrainConfig
    from repro.configs.registry import smoke_config
    from repro.distributed.steps import build_train_step
    from repro.models import Model
    from repro.optim import adamw

    cfg = smoke_config("granite-3-2b")
    model = Model(cfg)
    shape = ShapeConfig("t", seq_len=64, global_batch=4, kind="train")
    ins = model.input_specs(shape)
    params_abs = model.abstract_params()
    opt_abs = jax.eval_shape(lambda p: adamw.init(p, cfg.moment_dtype),
                             params_abs)
    step = build_train_step(model, TrainConfig())
    lowered = jax.jit(step).lower(params_abs, opt_abs, ins)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None
    from repro.launch.hlo_cost import analyze
    cost = analyze(compiled.as_text())
    assert cost["flops"] > 0
    assert cost["bytes"] > 0


def test_hlo_cost_trip_count_awareness():
    """The roofline source must multiply scan bodies by trip count."""
    from repro.launch.hlo_cost import analyze

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y.sum()

    x = jnp.ones((64, 64))
    w = jnp.ones((64, 64))
    c8 = analyze(jax.jit(f).lower(x, w).compile().as_text())

    def f1(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=1)
        return y.sum()

    c1 = analyze(jax.jit(f1).lower(x, w).compile().as_text())
    ratio = c8["flops"] / max(c1["flops"], 1)
    assert 6.0 < ratio < 10.0, ratio
