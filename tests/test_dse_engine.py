"""Probe-guided autotuning DSE engine (ISSUE 2 tentpole): cache
hit/miss semantics under IR-hash invalidation, static pruning safety,
successive-halving budget accounting, and the repro.tune CLI."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (DeviceBudget, DSEEngine, EvalCache, SearchSpace,
                        device_kind)
from repro.kernels import tuning
from repro.kernels.search_spaces import flash_attention_space


def toy_space(scale: float = 1.0, values=(1, 2, 4)) -> SearchSpace:
    """Cheap non-Pallas space: model cycles grow with cfg['n'], so the
    measured-best is always n=min(values) and the default (n=max) loses."""
    x = jnp.ones((8, 16)) * 0.1
    w = jnp.eye(16) * 0.5

    def bind(cfg):
        def fn(x, w):
            y = x
            for _ in range(cfg["n"]):
                y = jnp.tanh(y @ w) * scale
            return y
        return fn

    return SearchSpace(kernel_id="toy", axes={"n": tuple(values)},
                       bind=bind, args=(x, w),
                       default={"n": max(values)})


@pytest.fixture()
def cache(tmp_path):
    return EvalCache(str(tmp_path / "dse"))


# ------------------------------------------------------------- cache

def test_cache_hit_miss_semantics(cache):
    dev = device_kind()
    cfg = {"block_q": 64, "block_k": 64, "pipeline": 1}
    assert cache.get("flash_attention", cfg, "aaaa", dev) is None
    cache.put("flash_attention", cfg, "aaaa", dev,
              cycles_per_step=123.0, steps=4)
    hit = cache.get("flash_attention", cfg, "aaaa", dev)
    assert hit is not None and hit["cycles_per_step"] == 123.0
    # a longer-run requirement misses a short-run entry
    assert cache.get("flash_attention", cfg, "aaaa", dev,
                     min_steps=8) is None
    # IR-hash invalidation: same config, edited kernel -> different hash
    assert cache.get("flash_attention", cfg, "bbbb", dev) is None
    # config identity is exact
    assert cache.get("flash_attention", {**cfg, "block_q": 128}, "aaaa",
                     dev) is None
    # persists across instances (on-disk)
    again = EvalCache(cache.root)
    assert again.get("flash_attention", cfg, "aaaa", dev) is not None
    assert again.best_config("flash_attention", dev) == cfg


def test_warm_cache_skips_all_measurements(cache):
    space = toy_space()
    cold = DSEEngine(space, cache=cache, max_steps=4).tune()
    assert cold.n_measurements > 0
    warm = DSEEngine(space, cache=cache, max_steps=4).tune()
    assert warm.n_measurements == 0, "warm run must be 100% cache hits"
    assert warm.measured_steps == 0
    assert warm.n_cache_hits > 0
    assert warm.best.config == cold.best.config


def test_latest_tuning_run_decides_best_config(cache):
    # raw eval entries are not mutually comparable (cycles scale with
    # shape); best_config must serve the LATEST run's winner, not the
    # globally lowest-cycles entry
    first = DSEEngine(toy_space(values=(1, 2, 4)), cache=cache,
                      max_steps=2).tune()
    assert first.best.config == {"n": 1}
    assert cache.best_config("toy") == {"n": 1}
    # a later run over a space excluding n=1: its winner (n=2, higher
    # absolute cycles than the stale n=1 entry) must now be served
    second = DSEEngine(toy_space(values=(2, 4)), cache=cache,
                       max_steps=2).tune()
    assert second.best.config == {"n": 2}
    assert cache.best_config("toy") == {"n": 2}
    # clearing the kernel also clears its winner record
    cache.clear("toy")
    assert cache.best_config("toy") is None


def test_kernel_edit_invalidates_cache(cache):
    # "editing the kernel" = a bind that lowers to different IR; the
    # fingerprint changes, so identical configs re-measure
    cold = DSEEngine(toy_space(scale=1.0), cache=cache, max_steps=2).tune()
    edited = DSEEngine(toy_space(scale=2.0), cache=cache,
                       max_steps=2).tune()
    assert edited.n_measurements == cold.n_measurements
    # and the unedited space still hits
    warm = DSEEngine(toy_space(scale=1.0), cache=cache, max_steps=2).tune()
    assert warm.n_measurements == 0


# ------------------------------------------- successive halving budget

def test_successive_halving_budget_accounting(cache):
    # 3 candidates, r0=1, eta=2, max_steps=4:
    #   rung 1: 3 x 1 step; keep ceil(3/2)=2
    #   rung 2: 2 x 2 steps; keep 1
    #   rung 3: 1 x 4 steps -> done
    # + the default baseline (n=4, eliminated at rung 1) topped up to
    #   the finalist's 4 steps for a like-for-like comparison
    res = DSEEngine(toy_space(values=(1, 2, 4)), cache=cache,
                    r0=1, eta=2, max_steps=4).tune()
    assert res.n_candidates == 3
    assert res.n_measurements == (3 + 2 + 1) + 1
    assert res.measured_steps == (3 * 1 + 2 * 2 + 1 * 4) + 4
    # the cheapest config wins and ran the full finalist budget
    assert res.best.config == {"n": 1}
    assert res.best.steps == 4
    # the baseline was re-measured at the finalist's rung
    assert res.default.steps == res.best.steps
    # an eliminated non-default candidate kept its short-run measurement
    mid = next(t for t in res.trials if t.config == {"n": 2})
    assert mid.steps < 4


def test_default_always_measured(cache):
    res = DSEEngine(toy_space(), cache=cache, max_steps=2).tune()
    assert res.default is not None and res.default.measured
    assert res.default.config == {"n": 4}
    assert res.best.cycles_per_step <= res.default.cycles_per_step
    assert res.speedup >= 1.0


# ------------------------------------------------------ static pruning

@pytest.fixture(scope="module")
def flash_space():
    return flash_attention_space(B=1, H=1, S=128, D=16,
                                 blocks_q=(64, 128), blocks_k=(64, 128),
                                 pipelines=(1, 2))


def test_pruning_never_discards_measured_best(flash_space, tmp_path):
    # measure EVERY candidate (r0 == max_steps: single exhaustive rung)
    unpruned = DSEEngine(flash_space, budget=None,
                         cache=EvalCache(str(tmp_path / "a")),
                         r0=1, max_steps=1).tune()
    assert unpruned.n_pruned == 0
    measured_best = unpruned.best.config
    # default pruning = real device ceilings + a generous static-cycles
    # ratio; neither may reject the config that actually measures best
    engine = DSEEngine(flash_space, budget=DeviceBudget(),
                       cache=EvalCache(str(tmp_path / "b")),
                       static_prune_ratio=4.0, r0=1, max_steps=1)
    trials = [engine.analyze(c) for c in flash_space.candidates()]
    survivors = engine.prune(trials)
    assert measured_best in [t.config for t in survivors]


def test_tight_budget_prunes_but_respects_it(flash_space, cache):
    # a VMEM ceiling between the smallest and largest candidate
    engine = DSEEngine(flash_space, budget=None, cache=cache)
    trials = [engine.analyze(c) for c in flash_space.candidates()]
    sizes = sorted(t.resources.vmem_bytes for t in trials)
    ceiling = (sizes[0] + sizes[-1]) // 2
    engine = DSEEngine(flash_space,
                       budget=DeviceBudget(vmem_bytes=ceiling), cache=cache)
    survivors = engine.prune(trials)
    assert 0 < len(survivors) < len(trials)
    assert all(t.resources.vmem_bytes <= ceiling for t in survivors)
    pruned = [t for t in trials if t.pruned is not None]
    assert all("vmem" in t.pruned for t in pruned)


# ---------------------------------------------------- tuned registry

def test_tuned_registry_resolution(cache):
    tuning.clear_tuned()
    try:
        assert tuning.tuned_value("flash_attention", "block_q", 128) == 128
        tuning.set_tuned("flash_attention", {"block_q": 64, "block_k": 64,
                                             "pipeline": 2})
        assert tuning.tuned_value("flash_attention", "block_q", 128) == 64
        # tuned configs change tiling, never outputs
        from repro.kernels import ops, ref
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 16))
        k = jax.random.normal(ks[1], (1, 2, 128, 16))
        v = jax.random.normal(ks[2], (1, 2, 128, 16))
        o_tuned = ops.flash_attention(q, k, v, causal=True)
        o_ref = ref.flash_attention_ref(q, k, v, causal=True)
        assert float(jnp.abs(o_tuned - o_ref).max()) < 2e-5
    finally:
        tuning.clear_tuned()


def test_tuned_config_survives_foreign_shapes(cache):
    # a config tuned at S=256 must not crash the wrappers at shapes it
    # doesn't divide — tiles fall back to the gcd, pipeline to 1
    from repro.kernels import ops, ref
    tuning.clear_tuned()
    try:
        tuning.set_tuned("flash_attention", {"block_q": 64, "block_k": 64,
                                             "pipeline": 2})
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 96, 16))      # 96 % 64 != 0
        k = jax.random.normal(ks[1], (1, 2, 96, 16))
        v = jax.random.normal(ks[2], (1, 2, 96, 16))
        o = ops.flash_attention(q, k, v, causal=True)
        o_ref = ref.flash_attention_ref(q, k, v, causal=True)
        assert float(jnp.abs(o - o_ref).max()) < 2e-5
        tuning.set_tuned("ssd_scan", {"chunk": 64, "pipeline": 4})
        B, L, H, P, G, N = 1, 96, 4, 8, 2, 16             # 96 % 64 != 0
        x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
        a = -jnp.abs(jax.random.normal(ks[1], (B, L, H))) * 0.3
        b = jax.random.normal(ks[2], (B, L, G, N)) * 0.5
        c = jax.random.normal(jax.random.fold_in(ks[2], 1),
                              (B, L, G, N)) * 0.5
        y = ops.ssd_scan(x, a, b, c)
        yk = ref.ssd_ref(x.transpose(0, 2, 1, 3), a.transpose(0, 2, 1),
                         b.transpose(0, 2, 1, 3),
                         c.transpose(0, 2, 1, 3))[0].transpose(0, 2, 1, 3)
        assert float(jnp.abs(y - yk).max() /
                     (jnp.abs(yk).max() + 1e-9)) < 2e-5
    finally:
        tuning.clear_tuned()


def test_load_cache_into_registry(cache):
    cfg = {"block_q": 64, "block_k": 64, "pipeline": 1}
    cache.put("flash_attention", cfg, "ffff", device_kind(),
              cycles_per_step=10.0, steps=4)
    tuning.clear_tuned()
    try:
        loaded = tuning.load_cache("flash_attention", cache_dir=cache.root)
        assert loaded == {"flash_attention": cfg}
        assert tuning.tuned_value("flash_attention", "block_q", 128) == 64
    finally:
        tuning.clear_tuned()


# ------------------------------------------------------------ CLI

def test_tune_cli_smoke(tmp_path, capsys):
    from repro.launch.tune import main
    cache_dir = str(tmp_path / "cli")
    rc = main(["--kernel", "flash_attention", "--seq", "64", "--dim", "16",
               "--heads", "1", "--cache-dir", cache_dir, "--max-steps", "2",
               "--json", str(tmp_path / "tune.json")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "DSE leaderboard: flash_attention" in out
    assert (tmp_path / "tune.json").exists()
    # the winner is now loadable for --autotune
    assert EvalCache(cache_dir).best_config("flash_attention") is not None
    tuning.clear_tuned()


# --------------------------------------- cache correctness regressions

def test_put_keeps_higher_step_entry(cache):
    """Regression: put() used to overwrite unconditionally, so a cheap
    1-step probe could clobber a converged 8-step measurement."""
    cfg = {"n": 1}
    cache.put("toy", cfg, "aaaa", device_kind(),
              cycles_per_step=100.0, steps=8)
    kept = cache.put("toy", cfg, "aaaa", device_kind(),
                     cycles_per_step=999.0, steps=1)
    assert kept["steps"] == 8 and kept["cycles_per_step"] == 100.0
    got = cache.get("toy", cfg, "aaaa", device_kind())
    assert got["steps"] == 8 and got["cycles_per_step"] == 100.0
    # equal step count is a refresh, not a downgrade
    cache.put("toy", cfg, "aaaa", device_kind(),
              cycles_per_step=90.0, steps=8)
    assert cache.get("toy", cfg, "aaaa", device_kind())[
        "cycles_per_step"] == 90.0


_WRITER = """
import sys
from repro.core import EvalCache
root, tag = sys.argv[1], sys.argv[2]
cache = EvalCache(root)
for i in range(40):
    cache.put("toy", {"n": i}, "f" + tag, "cpu",
              cycles_per_step=float(i), steps=4)
cache.set_winner("toy_" + tag, "cpu", {"n": int(tag)},
                 cycles_per_step=1.0)
print("done")
"""


def test_concurrent_writers_lose_no_entries(tmp_path):
    """Regression: _save() rewrote the whole file from a possibly-stale
    in-memory snapshot with no locking, so two processes sharing a cache
    dir silently dropped each other's measurements."""
    import os
    import subprocess
    import sys

    import repro
    root = str(tmp_path / "shared")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(repro.__file__))
    procs = [subprocess.Popen([sys.executable, "-c", _WRITER, root, tag],
                              env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE)
             for tag in ("0", "1")]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
        assert b"done" in out
    merged = EvalCache(root)
    for tag in ("0", "1"):
        hits = [e for e in merged.entries("toy")
                if e["fingerprint"] == f"f{tag}"]
        assert len(hits) == 40, f"writer {tag} lost {40 - len(hits)} entries"
        assert merged.best_config(f"toy_{tag}", "cpu") == {"n": int(tag)}


# -------------------------------------------------------- sweep farm

def test_sweep_farm_two_workers_smoke(tmp_path):
    """Tier-1 end-to-end: 2-process capture/measure over a shared cache,
    simulator-first filtering, warm rerun fully served from artifacts."""
    from repro.core.dse import run_sweep
    shapes = [{"S": 64, "D": 16}, {"S": 128, "D": 16}]
    cache = EvalCache(str(tmp_path / "sweep"))
    res = run_sweep("flash_attention", shapes, workers=2, top_k=6,
                    steps=2, cache=cache, calibrate=False)
    assert res.n_candidates > 2 * res.n_finalists
    assert res.n_measured <= res.n_finalists <= 6
    assert res.n_captured == res.n_candidates
    assert len(res.shapes) == 2
    for sh in res.shapes:
        assert sh.best_cycles <= sh.default_cycles
        assert sh.best_config is not None
    assert len(cache.entries("flash_attention")) == res.n_measured
    assert cache.best_config("flash_attention") is not None
    # warm rerun: traces + evals all on disk, nothing touches the device
    res2 = run_sweep("flash_attention", shapes, workers=2, top_k=6,
                     steps=2, cache=EvalCache(str(tmp_path / "sweep")),
                     calibrate=False)
    assert res2.n_measured == 0 and res2.n_captured == 0
    assert res2.n_cache_hits == res.n_measured
    assert [s.best_config for s in res2.shapes] == \
        [s.best_config for s in res.shapes]


def test_sweep_calibration_transfers(tmp_path):
    from repro.core import costmodel as cm
    from repro.core.dse import run_sweep
    cm.clear_kernel_calibration()
    try:
        res = run_sweep("flash_attention", [{"S": 64, "D": 16}], workers=0,
                        top_k=2, steps=2,
                        cache=EvalCache(str(tmp_path / "cal")),
                        calibrate=True)
    finally:
        cm.clear_kernel_calibration()
    assert res.n_calibration_runs == 1
    assert res.calibration_scale is not None and res.calibration_scale > 0
