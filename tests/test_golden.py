"""Golden-record conformance: decoded probe records must match the
committed canonical JSON bit for bit (tools/regen_golden.py).

This is the regression net under the profiler's exactness contracts:
probe selection order, event ordering, cost-model pricing, ring/spill
layout and the intra-kernel grid-step rows all feed the record, so any
drift — intended or not — surfaces as a JSON diff here. Records depend
on the traced jaxpr and therefore the jax version; the committed files
carry the version they were generated with (the CI baseline pin) and
the test skips elsewhere (the pinned nightly matrix keeps it running).
"""
import json
import os
import sys

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import regen_golden  # noqa: E402


def _load(name):
    path = regen_golden.golden_path(name)
    if not os.path.exists(path):
        pytest.fail(f"missing golden record {path} — run "
                    f"PYTHONPATH=src python tools/regen_golden.py")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("name", sorted(regen_golden.CASES))
def test_golden_record_exact(name):
    golden = _load(name)
    if golden["jax"] != jax.__version__:
        pytest.skip(f"golden for jax {golden['jax']}, running "
                    f"{jax.__version__} — regenerate under the pin to "
                    f"compare")
    got = json.loads(regen_golden.encode(regen_golden.run_case(name)))
    assert got == golden, (
        f"decoded record for {name!r} drifted from tests/golden/ — "
        f"inspect with `python tools/regen_golden.py --diff --case {name}` "
        f"and regenerate if the change is intentional")


def test_golden_two_consecutive_runs_identical():
    """Decode determinism: two fresh builds of the same case produce
    byte-identical canonical records (no trace-order or id() leakage
    into the record)."""
    a = regen_golden.encode(regen_golden.run_case("flash_grid"))
    b = regen_golden.encode(regen_golden.run_case("flash_grid"))
    assert a == b


def test_golden_covers_kernel_rows():
    """The committed kernel cases must actually pin intra-kernel rows —
    a regen that silently loses the grid subtree should fail loudly."""
    for name in ("flash_grid", "ssd_grid"):
        golden = _load(name)
        assert any(p.endswith("/grid") for p in golden["paths"]), name
        assert any("/kernel/" in p for p in golden["paths"]), name
