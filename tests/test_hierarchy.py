"""Hierarchy extraction (C-to-RTL mapping analogue) + inline policies."""
import jax
import jax.numpy as jnp

from repro.core import extract, probe, ProbeConfig
from repro.core.hierarchy import normalize_stack
from repro.core.inline import selectable_paths


def _fn(x, w):
    with jax.named_scope("embed"):
        x = x + 1.0
    def body(c, _):
        with jax.named_scope("layer"):
            with jax.named_scope("attn"):
                c = jnp.tanh(c @ w)
            with jax.named_scope("mlp"):
                c = c @ w.T + c
        return c, None
    with jax.named_scope("layers"):
        x, _ = jax.lax.scan(body, x, None, length=7)
    return x.sum()


def test_normalize_stack():
    assert normalize_stack("a/b") == ("a", "b")
    assert normalize_stack("jvp(a)/b") == ("a", "b")
    assert normalize_stack("transpose(jvp(a))/b") == ("a~bwd", "b")
    assert normalize_stack("jvp()") == ()
    assert normalize_stack("") == ()


def test_extract_tree_structure():
    jaxpr = jax.make_jaxpr(_fn)(jnp.ones((4, 8)), jnp.ones((8, 8)))
    h = extract(jaxpr)
    paths = set(h.all_paths())
    assert {"embed", "layers", "layers/scan#0", "layers/scan#0/layer",
            "layers/scan#0/layer/attn",
            "layers/scan#0/layer/mlp"} <= paths
    loop = h.node("layers/scan#0")
    assert loop.kind == "loop" and loop.trip_count == 7
    # static totals: parent >= sum of direct children per visit
    layer = h.node("layers/scan#0/layer")
    attn = h.node("layers/scan#0/layer/attn")
    mlp = h.node("layers/scan#0/layer/mlp")
    assert layer.static_cycles >= attn.static_cycles + mlp.static_cycles


def test_source_mapping_present():
    jaxpr = jax.make_jaxpr(_fn)(jnp.ones((4, 8)), jnp.ones((8, 8)))
    h = extract(jaxpr)
    table = {r["path"]: r for r in h.mapping_table()}
    assert table["layers/scan#0/layer/attn"]["source"].startswith(
        "test_hierarchy.py")


def test_grad_scopes_marked_bwd():
    f = lambda x, w: _fn(x, w)
    jaxpr = jax.make_jaxpr(jax.grad(f))(jnp.ones((4, 8)), jnp.ones((8, 8)))
    h = extract(jaxpr)
    paths = h.all_paths()
    assert any(p.startswith("layers~bwd") for p in paths)
    assert any(p.startswith("layers/") for p in paths)


def test_inline_policies():
    jaxpr = jax.make_jaxpr(_fn)(jnp.ones((4, 8)), jnp.ones((8, 8)))
    h = extract(jaxpr)
    off_all = set(selectable_paths(h, "off_all", ("",)))
    default = set(selectable_paths(h, "default", ("",)))
    off_top = set(selectable_paths(
        h, "off_top", ("layers/scan#0/layer",)))
    assert default <= off_all
    # 'embed' is a 1-eqn scope: inlined by default, kept by off_all
    assert "embed" in off_all and "embed" not in default
    # off_top keeps full detail under the target
    assert "layers/scan#0/layer/attn" in off_top


def test_max_probes_cap():
    def fn(x):
        for i in range(10):
            with jax.named_scope(f"s{i}"):
                x = jnp.tanh(x) * 1.1 + x
        return x.sum()
    pf = probe(fn, ProbeConfig(inline="off_all", max_probes=5))
    pf(jnp.ones((4, 4)))
    assert len(pf.probe_paths()) == 5           # paper's 50-module cap


def test_depth_limit():
    jaxpr_fn = _fn
    pf = probe(jaxpr_fn, ProbeConfig(inline="off_all", depth_limit=1))
    pf(jnp.ones((4, 8)), jnp.ones((8, 8)))
    assert all(p.count("/") <= 1 for p in pf.probe_paths())
