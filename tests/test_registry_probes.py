"""Full registry probe coverage: every ``registry.list_archs()`` smoke
config runs through a probed ``build_train_step`` AND a probed serve
``decode_step``, and both decoded records are golden-locked against
``tests/golden/arch_<slug>.json`` (tools/regen_golden.py).

This closes the gap where only tinyllama's forward pass had a pinned
record: MoE dispatch, SSM scans, shared-attention interleaving, mrope
and the audio/vision frontends each shape the probe tree differently,
so each arch gets its own canonical record. Records depend on the
traced jaxpr and therefore the jax version; like test_golden.py the
comparison skips off the CI pin (the nightly pinned matrix keeps it
exercised).

Also home to the registry structural invariants (satellite coverage for
``all_cells()`` / ``supported_shapes()`` skip logic and the smoke_config
branch rules for moe / ssm / mrope archs).
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.registry import smoke_config

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import regen_golden  # noqa: E402

ARCHS = registry.list_archs()

# two structurally distinct archs stay in the fast tier (one ssm, one
# moe); the rest of the registry runs with the slow suite
FAST_ARCHS = ("mamba2-370m", "granite-moe-1b-a400m")


def _arch_params(arch):
    return [pytest.param(a) if a in FAST_ARCHS
            else pytest.param(a, marks=pytest.mark.slow) for a in arch]


def _load_arch(arch):
    path = regen_golden.golden_path(regen_golden.arch_slug(arch))
    if not os.path.exists(path):
        pytest.fail(f"missing golden record {path} — run "
                    f"PYTHONPATH=src python tools/regen_golden.py")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_arch_probed_records_match_golden(arch):
    golden = _load_arch(arch)
    if golden["jax"] != jax.__version__:
        pytest.skip(f"golden for jax {golden['jax']}, running "
                    f"{jax.__version__} — regenerate under the pin")
    got = json.loads(regen_golden.encode(regen_golden.run_arch_case(arch)))
    assert got == golden, (
        f"probed record for {arch!r} drifted — inspect with "
        f"`python tools/regen_golden.py --diff --case "
        f"{regen_golden.arch_slug(arch)}`")


def test_every_arch_has_a_committed_golden():
    """The acceptance bar: one golden file per registry arch, each with
    BOTH a train and a serve record and a nonempty probe set."""
    for arch in ARCHS:
        golden = _load_arch(arch)
        assert golden["arch"] == arch
        for phase in ("train", "serve"):
            assert golden[phase]["paths"], (arch, phase)
            rec = golden[phase]["record"]
            assert rec["cycle"] > 0, (arch, phase)
            assert sum(rec["calls"]) > 0, (arch, phase)


def test_probed_decode_is_bit_identical_unprobed():
    """Serve-path non-intrusiveness (never covered before): the probed
    decode step returns logits/cache/token bit-identical to plain jit —
    exercised on the audio-frontend arch, whose embeds input path is the
    one no other probe test touches."""
    from repro.configs.base import ShapeConfig
    from repro.core import ProbeConfig, probe
    from repro.models import Model
    from repro.models.frontends import synth_frontend_batch

    cfg = smoke_config("musicgen-large")
    m = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B = 2
    cache = m.init_cache(ShapeConfig("t", seq_len=64, global_batch=B,
                                     kind="decode"))
    fb = synth_frontend_batch(cfg, B, 1, jnp.bfloat16, key)
    batch = {"embeds": fb["embeds"], "pos": jnp.int32(3)}
    pf = probe(m.decode_step, ProbeConfig(max_probes=24))
    (logits, cache2, nxt), rec = pf(params, cache, batch)
    logits0, cache20, nxt0 = jax.jit(m.decode_step)(params, cache, batch)
    assert np.array_equal(np.asarray(logits), np.asarray(logits0))
    assert np.array_equal(np.asarray(nxt), np.asarray(nxt0))
    for a, b in zip(jax.tree_util.tree_leaves(cache2),
                    jax.tree_util.tree_leaves(cache20)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------ registry structural rules

def test_all_cells_skip_logic():
    """``all_cells`` marks exactly the long_500k cells of non-long-
    context archs as skipped, and nothing else."""
    cells = registry.all_cells()
    assert len(cells) == len(ARCHS) * len(registry.SHAPES)
    for arch, shape, skip in cells:
        cfg = registry.get_config(arch)
        assert skip == (shape == "long_500k"
                        and not cfg.supports_long_context), (arch, shape)


def test_supported_shapes_matches_cells():
    """``supported_shapes`` is exactly the non-skipped rows of
    ``all_cells`` for each arch, in the global shape order."""
    shape_order = list(registry.SHAPES)
    for arch in ARCHS:
        cfg = registry.get_config(arch)
        names = [s.name for s in registry.supported_shapes(cfg)]
        want = [shape for a, shape, skip in registry.all_cells()
                if a == arch and not skip]
        assert names == want, arch
        assert names == [n for n in shape_order if n in names]
        assert ("long_500k" in names) == cfg.supports_long_context


def test_smoke_config_structural_invariants():
    """The smoke reduction must preserve each arch's structural family:
    the moe / ssm / mrope / shared-attention branches all stay active
    (otherwise registry probe coverage silently tests plain dense
    transformers ten times)."""
    for arch in ARCHS:
        full = registry.get_config(arch)
        cfg = smoke_config(arch)
        assert cfg.num_layers == 2 and cfg.d_model == 64, arch
        assert cfg.vocab_size == 257, arch          # odd: uneven shards
        assert (cfg.moe is None) == (full.moe is None), arch
        assert (cfg.ssm is None) == (full.ssm is None), arch
        assert cfg.frontend == full.frontend, arch
        if full.moe is not None:
            assert cfg.moe.num_experts == 4, arch
            assert cfg.moe.top_k <= 2, arch
            assert cfg.moe.dense_residual == full.moe.dense_residual
            assert (cfg.moe.residual_d_ff > 0) == full.moe.dense_residual
        if full.ssm is not None:
            assert cfg.ssm.d_state == 16, arch
            assert cfg.ssm.head_dim == 8, arch
            assert cfg.ssm.chunk_size == 16, arch
        if full.pos_emb == "mrope":
            assert cfg.mrope_sections == (2, 3, 3), arch
            assert sum(cfg.mrope_sections) == cfg.head_dim // 2, arch
        if full.shared_attn_every:
            assert cfg.shared_attn_every == 1, arch
        if full.num_heads:
            assert cfg.num_heads == 4, arch
            assert 1 <= cfg.num_kv_heads <= 4, arch


def test_smoke_registry_covers_families():
    """The registry itself must span the families the conformance sweep
    models: moe, ssm, frontend and mrope archs all present."""
    cfgs = {a: registry.get_config(a) for a in ARCHS}
    assert any(c.moe is not None for c in cfgs.values())
    assert any(c.ssm is not None for c in cfgs.values())
    assert any(c.frontend == "vision" for c in cfgs.values())
    assert any(c.frontend == "audio" for c in cfgs.values())
    assert any(c.pos_emb == "mrope" for c in cfgs.values())
