"""Fault-tolerance: atomic/async/sharded checkpointing + elastic restore
+ exactly-once data accounting."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import DataConfig, PipelineState, TokenPipeline


def _tree(key, scale=1.0):
    return {"a": jnp.full((4, 8), scale), "b": {"c": jnp.arange(6.0) * scale}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    tree = _tree(None, 3.0)
    ck.save(7, tree, extra={"step": 7})
    assert ck.latest() == 7
    restored, extra = ck.restore(7, tree)
    assert extra["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_async_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(None, float(s)))
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_atomicity_tmp_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(1, _tree(None))
    # a crashed half-written checkpoint must be invisible
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ck.latest() == 1


def test_elastic_restore_resharding(tmp_path):
    """Restore onto a different sharding (elastic restart)."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    tree = _tree(None, 2.0)
    ck.save(5, tree)
    shardings = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree)
    restored, _ = ck.restore(5, tree, shardings=shardings)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_checkpoint_restart_resumes_training(tmp_path):
    from repro.launch.train import train
    d = str(tmp_path / "ck")
    _, _, hist1 = train("tinyllama-1.1b", steps=6, batch=2, seq=32,
                        checkpoint_dir=d, log_every=100)
    # restart from the saved checkpoint and continue deterministically
    from repro.checkpoint import Checkpointer
    assert Checkpointer(d).latest() == 6


# ------------------------------------------------------ data pipeline

def test_pipeline_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch_at(11), p2.batch_at(11)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_pipeline_host_sharding_partitions_global_batch():
    cfg = DataConfig(vocab_size=1000, seq_len=8, global_batch=8, seed=0)
    full = TokenPipeline(cfg).batch_at(5)["tokens"]
    parts = []
    for h in range(4):
        c = DataConfig(vocab_size=1000, seq_len=8, global_batch=8, seed=0,
                       num_hosts=4, host_index=h)
        parts.append(TokenPipeline(c).batch_at(5)["tokens"])
    assert np.array_equal(np.concatenate(parts), full)


def test_pipeline_elastic_reshard_no_dup_no_skip():
    cfg = DataConfig(vocab_size=500, seq_len=8, global_batch=6, seed=1,
                     num_hosts=2, host_index=0)
    p = TokenPipeline(cfg)
    next(p)                                  # consume step 0
    # node loss: restart on 3 hosts from the same global step
    p2 = p.reshard(num_hosts=3, host_index=1)
    assert p2.state.step == 1
    b = p2.batch_at(1)
    # host 1 of 3 sees samples [2,3] of the global step-1 batch
    ref = TokenPipeline(DataConfig(vocab_size=500, seq_len=8,
                                   global_batch=6, seed=1)).batch_at(1)
    assert np.array_equal(b["tokens"], ref["tokens"][2:4])


def test_pipeline_state_roundtrip():
    st = PipelineState(step=42)
    assert PipelineState.from_dict(st.to_dict()).step == 42
