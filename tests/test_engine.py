"""Continuous-batching engine: scheduling invariants and bit-identity.

Three layers of coverage:

- host-side bookkeeping units (page table refcounts, prefix trie);
- hypothesis scheduler properties over random request traces, run
  against deterministic fake steps (arrival order, prompt/decode
  lengths and shared prefixes drawn freely) — no starvation, page
  refcounts balance to zero at drain, and batched outputs equal the
  closed-form sequential replay of every request;
- real-model end-to-end: a mixed trace served by the engine is
  bit-identical to the unbatched reference serving path, with zero
  retraces and a populated per-phase cycle bill, through both the
  dense-gather decode and the paged-attention Pallas kernel.
"""
import json
import os
import sys
import types

import jax
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

from repro.engine import (EngineConfig, InferenceEngine, NULL_PAGE,
                          PagePoolExhausted, PageTable, PrefixTree,
                          engine_compatible)


# ------------------------------------------------- page table / trie

def test_pagetable_alloc_share_free_roundtrip():
    t = PageTable(8, 16)
    assert t.free_pages == 7 and t.balanced()
    a = t.alloc(3)
    assert len(set(a)) == 3 and NULL_PAGE not in a
    assert t.used_pages == 3 and t.peak_used == 3
    t.share(a[0])
    t.free(a[0])
    assert t.used_pages == 3          # still referenced once
    for p in a:
        t.free(p)
    assert t.balanced() and t.peak_used == 3


def test_pagetable_errors():
    t = PageTable(4, 16)
    with pytest.raises(PagePoolExhausted):
        t.alloc(4)                    # only 3 non-null pages exist
    p = t.alloc(1)[0]
    t.free(p)
    with pytest.raises(ValueError):
        t.free(p)                     # double free
    with pytest.raises(ValueError):
        t.share(p)                    # share of a dead page
    with pytest.raises(ValueError):
        PageTable(1, 16)              # no room for the null page


def test_prefix_tree_match_insert_clear():
    t = PageTable(16, 4)
    tree = PrefixTree(t)
    pages = t.alloc(3)
    keys = [(1, 2, 3, 4), (5, 6, 7, 8), (9, 10, 11, 12)]
    assert tree.insert(keys, pages) == 3
    # full hit takes one reference per page for the caller
    got = tree.match(keys[:2])
    assert got == pages[:2] and tree.hits == 2
    # diverging path stops at the shared prefix
    assert tree.match([keys[0], (0, 0, 0, 0)]) == pages[:1]
    assert tree.misses == 1
    assert tree.lookup(keys) == 3     # lookup takes no references
    for p in got + pages[:1]:
        t.free(p)
    for p in pages:                   # the requests' own references
        t.free(p)
    assert not t.balanced()           # tree still holds its references
    tree.clear()
    assert t.balanced() and tree.nodes == 0


def test_prefix_tree_lru_victim_order_deterministic():
    """Eviction frees tree-only pages in least-recently-matched order;
    a fresh match moves a branch to the back of the victim line."""
    t = PageTable(16, 4)
    tree = PrefixTree(t)
    pages = t.alloc(3)
    keys = [(i, i, i, i) for i in range(3)]
    for k, p in zip(keys, pages):
        tree.insert([k], [p])
        t.free(p)                     # request gone; tree ref only
    for p in tree.match([keys[1]]):   # re-touch the middle branch
        t.free(p)
    assert tree.evict(2) == [pages[0], pages[2]]   # LRU first, k1 hot
    assert tree.evict(5) == [pages[1]]             # then the rest
    assert tree.nodes == 0 and t.balanced()
    assert tree.evicted == 3


def test_prefix_tree_evict_leaf_first_cascade():
    """A chain a->b->c evicts leaf-first (c, b, a): parents become
    evictable only once their last child is gone."""
    t = PageTable(16, 4)
    tree = PrefixTree(t)
    pages = t.alloc(3)
    keys = [(1, 1, 1, 1), (2, 2, 2, 2), (3, 3, 3, 3)]
    tree.insert(keys, pages)
    for p in pages:
        t.free(p)
    assert tree.evict(3) == pages[::-1]
    assert t.balanced()


def test_prefix_tree_evict_spares_in_use_and_protected():
    t = PageTable(16, 4)
    tree = PrefixTree(t)
    pages = t.alloc(3)
    keys = [(i, i, i, i) for i in range(3)]
    for k, p in zip(keys, pages):
        tree.insert([k], [p])
    t.free(pages[1])                  # only the middle is tree-only
    t.free(pages[2])
    # pages[0] still live (refcount 2) and keys[2] is protected
    assert tree.evict(3, protect=[keys[2]]) == [pages[1]]
    assert t.refcount[pages[0]] == 2 and t.refcount[pages[2]] == 1
    freed = tree.evict_all()          # drain drops every tree ref
    assert freed == [pages[2]]        # pages[0]'s live ref survives
    assert t.refcount[pages[0]] == 1
    t.free(pages[0])
    assert t.balanced()


# ------------------------------------- scheduler properties (fake steps)

_FAKE_VOCAB = 997
_FAKE_PS = 4


def _fake_prefill_tok(prompt):
    return (sum(prompt) * 13 + (len(prompt) - 1) * 5) % _FAKE_VOCAB


def _fake_next_tok(tok, pos):
    return (tok * 31 + pos * 7) % _FAKE_VOCAB


def _fake_replay(prompt, max_new):
    """Closed-form sequential (batch-1) serving of one request."""
    out = [_fake_prefill_tok(prompt)]
    for i in range(max_new - 1):
        out.append(_fake_next_tok(out[-1], len(prompt) + i))
    return out


class _FakeStepEngine(InferenceEngine):
    """Engine with deterministic host-side step fakes: decode output
    depends only on the lane's own (token, position), so any batching
    or padding mistake in the scheduler shows up as a token diff.

    The prefill fake stores each page's token sum in its KV block and
    the cache fake really scatters it into the pool, so the chunkpf fake
    must read its context sums back *through the page table* — a wrong
    ctx page list, a stale pool, or an eviction of an in-use page all
    surface as a first-token mismatch against the sequential replay."""

    def _build(self, phase, size):
        cfg, c = self.model.cfg, self.config

        def kv_block(n_pages, toks):
            shape = (cfg.num_layers, n_pages, c.page_size,
                     cfg.num_kv_heads, cfg.resolved_head_dim)
            k = np.zeros(shape, np.float32)
            k[0, :, 0, 0, 0] = toks.reshape(n_pages, c.page_size).sum(1)
            return k, np.zeros(shape, np.float32)

        def one_hot(tok):
            logits = np.zeros((1, _FAKE_VOCAB), np.float32)
            logits[0, tok] = 1.0
            return logits

        if phase == "prefill":
            def prefill(params, batch):
                toks = np.asarray(batch["tokens"])
                li = int(np.asarray(batch["last_idx"])[0])
                tok = (int(toks.sum()) * 13 + li * 5) % _FAKE_VOCAB
                return (one_hot(tok),) + kv_block(size, toks)
            return prefill
        if phase == "chunkpf":
            cs, n = size

            def chunkpf(params, pk, pv, batch):
                toks = np.asarray(batch["tokens"])
                li = int(np.asarray(batch["last_idx"])[0])
                ctx = np.asarray(batch["ctx_pages"])
                ctx_sum = int(np.asarray(pk)[0, ctx, 0, 0, 0].sum())
                tok = ((ctx_sum + int(toks.sum())) * 13
                       + (cs * c.page_size + li) * 5) % _FAKE_VOCAB
                return (one_hot(tok),) + kv_block(n, toks)
            return chunkpf
        if phase == "cache":
            def scatter(pk, pv, k, v, ids):
                pk = np.asarray(pk).copy()
                pv = np.asarray(pv).copy()
                pk[:, np.asarray(ids)] = np.asarray(k)
                pv[:, np.asarray(ids)] = np.asarray(v)
                return pk, pv
            return scatter

        def decode(params, pk, pv, batch):
            t = np.asarray(batch["tokens"])[:, 0].astype(np.int64)
            p = np.asarray(batch["pos"]).astype(np.int64)
            nt = ((t * 31 + p * 7) % _FAKE_VOCAB).astype(np.int32)
            return np.zeros((size, _FAKE_VOCAB), np.float32), pk, pv, nt
        return decode


def _fake_engine(**overrides):
    cfg = types.SimpleNamespace(
        family="llama", frontend="none", num_layers=1, num_kv_heads=1,
        resolved_head_dim=2, kv_cache_dtype="float32", moe=None)
    model = types.SimpleNamespace(cfg=cfg)
    kw = dict(page_size=_FAKE_PS, pool_pages=10, max_pages=6,
              buckets=(1, 2, 4))
    kw.update(overrides)
    return _FakeStepEngine(model, None, EngineConfig(**kw))


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # dev-only dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def _traces(draw):
        """Random request traces: shared prefixes (full pages),
        free-form tails, mixed decode budgets, arbitrary arrival."""
        prefixes = [draw(st.lists(st.integers(0, 50), min_size=_FAKE_PS,
                                  max_size=_FAKE_PS)) for _ in range(2)]
        n = draw(st.integers(1, 8))
        reqs = []
        for _ in range(n):
            base = prefixes[draw(st.integers(0, 1))] \
                if draw(st.booleans()) else []
            tail = draw(st.lists(st.integers(0, 50), min_size=1,
                                 max_size=3 * _FAKE_PS))
            max_new = draw(st.integers(1, 2 * _FAKE_PS))
            prompt = (base + tail)[:6 * _FAKE_PS - max_new + 1]
            reqs.append((prompt, max_new))
        return reqs

    @settings(max_examples=40, deadline=None)
    @given(_traces())
    def test_random_trace_matches_sequential_replay(reqs):
        eng = _fake_engine()
        rids = [eng.submit(p, m) for p, m in reqs]
        done = eng.run()
        by_rid = {r.rid: r for r in done}
        assert sorted(by_rid) == sorted(rids)      # no starvation
        for rid, (prompt, max_new) in zip(rids, reqs):
            assert by_rid[rid].out_tokens == _fake_replay(prompt, max_new)
        eng.drain()
        assert eng.table.balanced()
        assert eng.table.peak_used <= eng.config.pool_pages - 1

    @settings(max_examples=20, deadline=None)
    @given(_traces(), st.booleans())
    def test_random_trace_page_accounting(reqs, prefix_cache):
        eng = _fake_engine(pool_pages=8, prefix_cache=prefix_cache)
        for p, m in reqs:
            eng.submit(p, m)
        done = eng.run()
        assert all(len(r.out_tokens) == m
                   for r, (_, m) in zip(sorted(done, key=lambda r: r.rid),
                                        reqs))
        assert all(not r.pages for r in done)      # released on finish
        eng.drain()
        assert eng.table.balanced()

    @settings(max_examples=40, deadline=None)
    @given(_traces(), st.integers(1, 3))
    def test_random_trace_chunked_matches_replay(reqs, chunk):
        """Chunked prefill (any chunk size) yields the same token
        streams as whole-prompt serving — the fake chunkpf step reads
        its context sums back through the page table, so a wrong ctx
        page list or a stale pool breaks the first token."""
        eng = _fake_engine(prefill_chunk_pages=chunk)
        rids = [eng.submit(p, m) for p, m in reqs]
        done = eng.run()
        by_rid = {r.rid: r for r in done}
        for rid, (prompt, max_new) in zip(rids, reqs):
            assert by_rid[rid].out_tokens == _fake_replay(prompt, max_new)
        eng.drain()
        assert eng.table.balanced()

    @settings(max_examples=40, deadline=None)
    @given(_traces(), st.integers(0, 2),
           st.sampled_from(["lru", "clear"]))
    def test_random_trace_eviction_under_pressure(reqs, chunk, policy):
        """Admit/evict/complete under a pool sized to force eviction:
        token streams still replay exactly (an evicted-in-use page
        would corrupt a chunk's context read or a shared prefix),
        refcounts balance at drain, and the evictor never frees a page
        a live request references."""
        eng = _fake_engine(pool_pages=8, prefill_chunk_pages=chunk,
                           evict_policy=policy)
        rids = [eng.submit(p, m) for p, m in reqs]
        done = eng.run()
        by_rid = {r.rid: r for r in done}
        assert sorted(by_rid) == sorted(rids)
        for rid, (prompt, max_new) in zip(rids, reqs):
            assert by_rid[rid].out_tokens == _fake_replay(prompt, max_new)
        st_ = eng.stats()
        assert st_["evictions"] == eng.evictions >= 0
        eng.drain()
        assert eng.table.balanced()


def test_fake_engine_prefix_sharing_counts():
    eng = _fake_engine()
    shared = list(range(_FAKE_PS))
    eng.submit(shared + [7, 8], 2)
    eng.submit(shared + [9], 2)
    eng.run()
    st_ = eng.stats()
    assert st_["prefix_hits"] == 1 and st_["prefix_misses"] == 1
    assert {r.shared_pages for r in eng.reap()} == {0, 1}
    eng.drain()
    assert eng.table.balanced()


def test_submit_validation_and_compat():
    eng = _fake_engine()
    with pytest.raises(ValueError):
        eng.submit([], 2)
    with pytest.raises(ValueError):
        eng.submit([1], 0)
    with pytest.raises(ValueError):                # needs > max_pages
        eng.submit(list(range(6 * _FAKE_PS)), _FAKE_PS)
    bad = types.SimpleNamespace(cfg=types.SimpleNamespace(
        family="ssm", frontend="none"))
    assert not engine_compatible(bad.cfg)
    with pytest.raises(ValueError):
        InferenceEngine(bad, None)


def test_fcfs_head_blocks_until_pages_free():
    """A large head-of-queue request waits for pool pressure to clear
    but is never overtaken (and eventually completes)."""
    eng = _fake_engine(pool_pages=8, max_pages=6, buckets=(1, 2))
    eng.submit(list(range(10)), 2)                 # 3 pages
    eng.submit(list(range(16)), 5)                 # 5 pages: must wait
    eng.submit([1, 2], 1)                          # 1 page: behind head
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2]
    assert all(len(r.out_tokens) == m
               for r, m in zip(done, (2, 5, 1)))
    eng.drain()
    assert eng.table.balanced()


def test_chunked_prefill_unblocks_decode_head_of_line():
    """A long prompt admitted behind a running decode lane counts HoL
    displacement whole-prompt but not chunked — and chunking splits it
    into per-chunk steps interleaved with decode rounds."""
    def serve(**kw):
        eng = _fake_engine(buckets=(1, 2), **kw)
        eng.submit([1, 2, 3], 8)                    # decode-heavy
        eng.submit(list(range(16)), 2)              # 4-page prompt
        done = eng.run()
        st_ = eng.stats()
        eng.drain()
        return done, st_

    whole_done, whole = serve()
    chunk_done, chunk = serve(prefill_chunk_pages=1)
    assert [r.out_tokens for r in whole_done] == \
        [r.out_tokens for r in chunk_done]
    assert whole["hol_blocked_steps"] == 3          # ceil(4/1) - 1
    assert chunk["hol_blocked_steps"] == 0
    assert chunk["phases"]["chunkpf"]["steps"] == 3  # pages 1..3
    assert whole["tokens_out"] == chunk["tokens_out"] == 10


def test_chunked_prefill_shares_completed_chunks_incrementally():
    """A request arriving mid-prefill of a sibling with the same prompt
    shares every chunk the sibling has already finished (the tree is
    fed incrementally, not only at prefill completion)."""
    eng = _fake_engine(prefill_chunk_pages=1, buckets=(1,))
    prompt = list(range(20))                        # 5 full pages
    eng.submit(prompt, 1)
    done = eng.run()
    eng.submit(prompt + [3], 1)                     # same 5-page prefix
    done += eng.run()
    assert done[1].shared_pages == 5
    assert [r.out_tokens for r in done] == \
        [_fake_replay(prompt, 1), _fake_replay(prompt + [3], 1)]
    # skipped fully-shared leading chunks: only the final chunk ran
    # for the second request (pages 5 of 6 -> one chunkpf at ctx 5)
    assert eng.chunk_stats[(5, 1)]["steps"] == 1
    eng.drain()
    assert eng.table.balanced()


def test_engine_config_validation_gates():
    with pytest.raises(ValueError):                 # unknown policy
        _fake_engine(evict_policy="random")
    with pytest.raises(ValueError):                 # donation vs probe
        _fake_engine(donate=True, probe=True)
    with pytest.raises(ValueError):                 # negative chunk
        _fake_engine(prefill_chunk_pages=-1)
    # capacity MoE drops tokens by total count -> chunking refused
    cfg = types.SimpleNamespace(
        family="llama", frontend="none", num_layers=1, num_kv_heads=1,
        resolved_head_dim=2, kv_cache_dtype="float32",
        moe=types.SimpleNamespace(impl="capacity"))
    model = types.SimpleNamespace(cfg=cfg)
    with pytest.raises(ValueError):
        _FakeStepEngine(model, None,
                        EngineConfig(prefill_chunk_pages=2))
    # dropless routing is fine
    cfg.moe = types.SimpleNamespace(impl="ragged")
    _FakeStepEngine(model, None, EngineConfig(prefill_chunk_pages=2))


def test_donation_argnums_per_phase():
    from repro.engine import donation_argnums
    assert donation_argnums("cache") == (0, 1)
    assert donation_argnums("decode") == (1, 2)
    assert donation_argnums("prefill") == ()
    assert donation_argnums("chunkpf") == ()


# ------------------------------------------- real model, bit-identity

def _reference_serve(model, params, prompt, max_new):
    """Unbatched (batch-1, dense-cache) reference token stream."""
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.distributed.steps import build_decode_step, build_prefill_step
    P = len(prompt)
    pf = jax.jit(build_prefill_step(model, ShapeConfig("r", 128, 1,
                                                       "prefill")))
    dec = jax.jit(build_decode_step(model))
    lg, cache = pf(params, {"tokens": jnp.array([prompt], jnp.int32)})
    nt = jnp.argmax(lg, -1).astype(jnp.int32)
    out = [int(nt[0])]
    for i in range(max_new - 1):
        lg, cache, nt = dec(params, cache, {"tokens": nt[:, None],
                                            "pos": jnp.int32(P + i)})
        out.append(int(nt[0]))
    return out


def _mixed_trace(vocab, seed=7):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, 16).tolist()
    prompts = [prefix + rng.integers(0, vocab, 5).tolist(),
               rng.integers(0, vocab, 7).tolist(),
               prefix + rng.integers(0, vocab, 9).tolist()]
    return prompts, [5, 3, 4]


def test_engine_bit_identical_and_probed(tiny_model):
    cfg, model, params = tiny_model
    prompts, max_new = _mixed_trace(cfg.vocab_size)
    refs = [_reference_serve(model, params, p, m)
            for p, m in zip(prompts, max_new)]
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, pool_pages=16, max_pages=2, buckets=(1, 2, 4),
        probe=True, interpret=True))
    for p, m in zip(prompts, max_new):
        eng.submit(p, m)
    done = eng.run()
    for r, ref in zip(done, refs):
        assert r.out_tokens == ref                 # bit-identical
    stats = eng.stats()
    assert stats["retraces"] == 0
    assert stats["prefix_hits"] >= 1               # third request reuses
    assert all(v["cycles"] > 0 for v in stats["phases"].values())
    assert all(r.phase_cycles["prefill"] > 0 for r in done)
    assert all(r.phase_cycles["decode"] > 0 for r in done)
    assert "prefill" in eng.phase_table()
    assert "shared pages" in eng.request_table(done)
    eng.drain()
    assert eng.table.balanced()
    eng.close()


def test_chunk_prefill_step_byte_identical(tiny_model):
    """Step-level: a 2-page prompt prefilled page 0 whole + page 1 via
    chunkpf equals the one-shot 2-page prefill byte for byte — logits
    at the real last token AND the page-major KV blocks."""
    import jax.numpy as jnp
    from repro.engine import (build_chunk_prefill, build_engine_prefill,
                              build_page_scatter)
    cfg, model, params = tiny_model
    ps, P = 16, 27
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab_size, (1, 2 * ps)).astype(np.int32)
    toks[0, P:] = 0
    lg_w, k_w, v_w = jax.jit(build_engine_prefill(model, 2, ps))(
        params, {"tokens": jnp.asarray(toks),
                 "last_idx": jnp.array([P - 1], jnp.int32)})
    lg0, k0, v0 = jax.jit(build_engine_prefill(model, 1, ps))(
        params, {"tokens": jnp.asarray(toks[:, :ps]),
                 "last_idx": jnp.array([ps - 1], jnp.int32)})
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    pool = jnp.zeros((cfg.num_layers, 8, ps, kv, hd),
                     jnp.dtype(cfg.kv_cache_dtype))
    pool_k, pool_v = jax.jit(build_page_scatter(1))(
        pool, pool, k0, v0, jnp.array([3], jnp.int32))
    lg_c, k_c, v_c = jax.jit(build_chunk_prefill(model, 1, 1, ps))(
        params, pool_k, pool_v,
        {"tokens": jnp.asarray(toks[:, ps:]),
         "ctx_pages": jnp.array([3], jnp.int32),
         "last_idx": jnp.array([P - 1 - ps], jnp.int32)})
    assert jnp.array_equal(lg_w, lg_c)
    assert jnp.array_equal(k_w[:, :1], k0) and jnp.array_equal(
        v_w[:, :1], v0)
    assert jnp.array_equal(k_w[:, 1:], k_c) and jnp.array_equal(
        v_w[:, 1:], v_c)


def test_engine_chunked_and_donated_bit_identical(tiny_model):
    """End-to-end: the engine with chunked prefill — probed, and again
    with donated pool buffers forced on — serves the mixed trace with
    the exact whole-prompt token streams and zero retraces."""
    import warnings
    cfg, model, params = tiny_model
    prompts, max_new = _mixed_trace(cfg.vocab_size)
    refs = [_reference_serve(model, params, p, m)
            for p, m in zip(prompts, max_new)]
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, pool_pages=16, max_pages=2, buckets=(1, 2, 4),
        probe=True, interpret=True, prefill_chunk_pages=1))
    for p, m in zip(prompts, max_new):
        eng.submit(p, m)
    done = eng.run()
    for r, ref in zip(done, refs):
        assert r.out_tokens == ref
    st_ = eng.stats()
    assert st_["retraces"] == 0
    assert st_["phases"]["chunkpf"]["steps"] >= 1
    assert st_["phases"]["chunkpf"]["cycles"] > 0   # probed like others
    assert "chunk pages" in eng.chunk_table()
    eng.drain()
    assert eng.table.balanced()
    eng.close()

    with warnings.catch_warnings():
        # CPU backends can't honor donation; jax warns but stays correct
        warnings.simplefilter("ignore")
        eng = InferenceEngine(model, params, EngineConfig(
            page_size=16, pool_pages=16, max_pages=2, buckets=(1, 2, 4),
            interpret=True, prefill_chunk_pages=1, donate=True))
        eng.warmup()                   # donation rebinds the pool here
        for p, m in zip(prompts, max_new):
            eng.submit(p, m)
        done = eng.run()
    for r, ref in zip(done, refs):
        assert r.out_tokens == ref
    assert eng.stats()["retraces"] == 0
    eng.drain()
    assert eng.table.balanced()


@pytest.mark.slow
def test_engine_kernel_path_bit_identical(tiny_model):
    """Same trace through the paged-attention Pallas decode kernel."""
    cfg, model, params = tiny_model
    prompts, max_new = _mixed_trace(cfg.vocab_size)
    refs = [_reference_serve(model, params, p, m)
            for p, m in zip(prompts, max_new)]
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, pool_pages=16, max_pages=2, buckets=(1, 4),
        use_kernel=True, pages_per_step=2, interpret=True))
    for p, m in zip(prompts, max_new):
        eng.submit(p, m)
    done = eng.run()
    for r, ref in zip(done, refs):
        assert r.out_tokens == ref
    assert eng.stats()["retraces"] == 0
    eng.drain()
    assert eng.table.balanced()


def test_paged_attention_kernel_matches_dense():
    """Kernel-level: Pallas paged attention equals the dense-gather
    einsum reference bit for bit, across pipelining depths."""
    import jax.numpy as jnp
    from repro.kernels.paged_attention import paged_attention
    B, KV, G, HD, PS, NP, POOL = 3, 2, 2, 8, 4, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, KV, G, HD), jnp.float32)
    pk = jax.random.normal(ks[1], (POOL, PS, KV, HD)).astype(jnp.bfloat16)
    pv = jax.random.normal(ks[2], (POOL, PS, KV, HD)).astype(jnp.bfloat16)
    pages = jax.random.permutation(
        ks[3], POOL)[:B * NP].reshape(B, NP).astype(jnp.int32)
    pos = jnp.array([0, 7, 15], jnp.int32)
    s_max = PS * NP
    kd = pk[pages].reshape(B, s_max, KV, HD)
    vd = pv[pages].reshape(B, s_max, KV, HD)
    qg = q[:, None]
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.bfloat16),
                   kd.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) / np.sqrt(HD)
    mask = jnp.arange(s_max)[None, :] <= pos[:, None]
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    ref = jnp.einsum("bkgqs,bskh->bkgqh",
                     (p / p.sum(-1, keepdims=True)).astype(jnp.bfloat16),
                     vd.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)[:, :, :, 0]
    for pps in (1, 2, 4):
        out = paged_attention(q, pk, pv, pages, pos, pages_per_step=pps,
                              interpret=True)
        assert np.array_equal(np.asarray(out), np.asarray(ref)), pps


def test_paged_attention_search_space_registered():
    from repro.kernels.search_spaces import SPACES, paged_attention_space
    assert SPACES["paged_attention"] is paged_attention_space
    space = paged_attention_space(B=2, KV=2, G=1, HD=8, page_size=4,
                                  n_pages=4, pool_pages=16,
                                  pages_per_step=(1, 2, 4))
    assert space.candidates() == [{"pages_per_step": v} for v in (1, 2, 4)]
    fn = space.bind({"pages_per_step": 2})
    out = fn(*space.args)
    assert out.shape == (2, 2, 1, 8)
    assert not space.is_valid({"pages_per_step": 3})


def test_chunked_prefill_search_space_registered():
    """The chunk-size schedule axis is a first-class DSE space: every
    candidate traces, and all chunkings produce bit-identical logits
    and pool contents (a pure schedule change)."""
    from repro.kernels.search_spaces import (SPACES, chunked_prefill_space,
                                             sweep_shapes, sweep_space)
    assert SPACES["chunked_prefill"] is chunked_prefill_space
    space = chunked_prefill_space(prompt_pages=3, page_size=8)
    assert space.axes == {"chunk_pages": (1, 2, 3)}
    assert space.default == {"chunk_pages": 3}
    assert not space.is_valid({"chunk_pages": 4})
    outs = {}
    for cand in space.candidates():
        logits, pk, pv = jax.jit(space.bind(cand))(*space.args)
        outs[cand["chunk_pages"]] = tuple(
            np.asarray(x) for x in (logits, pk, pv))
    ref = outs[3]                      # whole-prompt baseline
    for k, got in outs.items():
        for a, b in zip(got, ref):
            assert np.array_equal(a, b), f"chunk_pages={k} diverged"
    sw = sweep_space("chunked_prefill", prompt_pages=2, page_size=8)
    assert sw.axes == {"chunk_pages": (1, 2)}
    assert sweep_shapes("chunked_prefill") == [{"prompt_pages": 2},
                                               {"prompt_pages": 4}]


@pytest.mark.slow
def test_serve_wrapper_bit_identical_to_legacy():
    """launch.serve routed through the engine returns exactly the
    legacy lock-step loop's tokens (flags preserved, batch=1 incl.)."""
    from repro.launch.serve import serve
    a = serve(batch=2, prompt_len=9, max_new=3, engine=False)
    b = serve(batch=2, prompt_len=9, max_new=3, engine=True)
    assert np.array_equal(a, b)
    c = serve(batch=1, prompt_len=5, max_new=2, engine=True, profile=True)
    d = serve(batch=1, prompt_len=5, max_new=2, engine=False)
    assert np.array_equal(c, d)


@pytest.mark.slow
def test_engine_soak_short():
    from repro.engine.soak import soak
    out = soak(waves=2, requests_per_wave=4, seed=1, verbose=False)
    assert out["served"] == 8 and out["retraces"] == 0


@pytest.mark.slow
def test_engine_soak_pressure_short():
    """Undersized pool: the soak's own asserts cover flat memory and
    balanced drain; here we check pressure actually evicted and the
    chunked scheduler survives the same trace with zero retraces."""
    from repro.engine.soak import soak
    out = soak(waves=2, requests_per_wave=6, seed=1, pressure=True,
               chunk=2, min_hit_rate=0.0, verbose=False)
    assert out["served"] == 12 and out["retraces"] == 0
    assert out["evictions"] > 0
    assert out["buffers_last"] <= out["buffers_first"] + 16


# --------------------------------------------------- golden lock

def test_engine_golden_locked():
    import regen_golden
    path = regen_golden.golden_path(regen_golden.ENGINE_CASE)
    assert os.path.exists(path), \
        "missing tests/golden/engine_serve.json — run tools/regen_golden.py"
    with open(path) as f:
        golden = json.load(f)
    if golden["jax"] != jax.__version__:
        pytest.skip(f"golden for jax {golden['jax']}, running "
                    f"{jax.__version__}")
    got = json.loads(regen_golden.encode(regen_golden.run_engine_case()))
    assert got == golden, (
        "engine serving record drifted — inspect with `python "
        "tools/regen_golden.py --diff --case engine_serve`")
    assert golden["stats"]["retraces"] == 0
    assert golden["stats"]["balanced_after_drain"] is True
