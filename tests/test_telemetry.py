"""Telemetry control plane: bus exactness, HTTP export, drift sentinel.

Four layers of coverage:

- bus/stream units: window deltas are exact aggregate deltas, served
  rows are exactly the ``StreamAggregator`` values, engine topics and
  alert rings stay bounded;
- fault injection (``repro.testing.faults``): a fake-clock
  deterministic driver plants step-changes, ramps, and single-device
  stragglers into synthetic streams — the sentinel must fire on every
  planted fault (naming the right probe/device), within a bounded
  number of windows, and never on stationary or seed-jittered traffic
  (zero false positives across a seeded sweep);
- HTTP: the status server binds port 0 (tests read the real port —
  no hard-coded ports anywhere), serves key-sorted schema-stable JSON,
  and ``/probes`` values round-trip bit-exactly;
- end-to-end (slow): a live probed decode session on the tiny model
  with the server attached is polled mid-decode and stays
  bit-identical to the unprobed reference.

Hypothesis property tests (aggregate exactness over random streams,
sentinel chunking invariance) are dev-only; seeded sweeps assert the
same properties when hypothesis is absent.
"""
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.streaming import HIST_BUCKETS, StreamAggregator
from repro.telemetry import (DriftSentinel, ProbeStream, SentinelConfig,
                             StatusServer, TelemetryBus, hist_quantile,
                             make_retune_hook, render_metrics)
from repro.testing.faults import (FakeClock, FaultDriver, RampFault,
                                  StepFault, StragglerFault)

SWEEP_SEEDS = range(10)


def _get(url: str) -> bytes:
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.read()


def _get_json(url: str):
    raw = _get(url)
    return json.loads(raw), raw


# ------------------------------------------------------------- bus units

def test_stream_get_or_create_and_unknown():
    bus = TelemetryBus()
    a = bus.stream("s", ("x", "y"))
    assert bus.stream("s") is a                    # get without paths
    assert bus.stream("s", ("x", "y")) is a        # same shape: same stream
    b = bus.stream("s", ("x", "y", "z"))           # reshape replaces
    assert b is not a and b.n_rows == 3
    with pytest.raises(KeyError):
        bus.stream("nope")


def test_window_frame_exact_deltas():
    bus = TelemetryBus()
    frames = []
    bus.subscribe("window", frames.append)
    st = bus.stream("s", ("x", "y"))
    st.add(0, np.array([10, 20, 30]))
    st.add(1, np.array([5]))
    f1 = st.roll(0, 4)
    st.add(0, np.array([1000]))
    f2 = st.roll(4, 8, exact_totals=np.array([1000, 0]))
    assert frames == [f1, f2]
    assert f1.index == 0 and f2.index == 1
    assert list(f1.counts) == [3, 1] and list(f1.totals) == [60, 5]
    assert list(f2.counts) == [1, 0] and list(f2.totals) == [1000, 0]
    assert list(f2.exact_totals) == [1000, 0]
    # histogram deltas partition the cumulative histogram exactly
    assert np.array_equal(f1.hist + f2.hist, st.agg.hist)
    assert f2.p99(0) == hist_quantile(f2.hist[0], 0.99)


def test_rows_are_exactly_aggregator_values():
    rng = np.random.default_rng(0)
    stream = ProbeStream("s", ("a", "b", "c"))
    ref = StreamAggregator(3, ema_alpha=0.1)
    for _ in range(20):
        pid = int(rng.integers(0, 3))
        durs = rng.integers(1, 100_000, rng.integers(1, 50))
        stream.add(pid, durs)
        ref.add(pid, durs)
    for row, r in enumerate(stream.rows()):
        assert r["calls"] == int(ref.count[row])
        assert r["total_cycles"] == int(ref.total[row])
        assert r["mean"] == float(ref.total[row]) / ref.count[row]
        assert r["ema"] == float(ref.ema[row])
        assert r["min"] == int(ref.min[row])
        assert r["max"] == int(ref.max[row])
        assert r["p50"] == ref.quantile(row, 0.50)
        assert r["p99"] == ref.quantile(row, 0.99)


def test_hist_quantile_matches_aggregator_quantile():
    rng = np.random.default_rng(1)
    agg = StreamAggregator(1)
    durs = rng.integers(1, 1 << 20, 500)
    agg.add(0, durs)
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        assert hist_quantile(agg.hist[0], q) == agg.quantile(0, q)
    assert hist_quantile(np.zeros(HIST_BUCKETS, np.int64), 0.5) == 0


def test_engine_topics_and_bounded_rings():
    bus = TelemetryBus(max_alerts=3, max_requests=2)
    phases, requests = [], []
    bus.subscribe("phase", lambda *a: phases.append(a))
    bus.subscribe("request", requests.append)
    bus.publish_phase("decode", cycles=100, batch=4)
    bus.publish_phase("decode", cycles=50, batch=4)
    bus.publish_phase("prefill", cycles=7)
    for i in range(5):
        bus.publish_request({"rid": i})
        bus.publish_alert({"kind": "x", "n": i})
    st = bus.status()
    assert st["engine"]["phases"]["decode"] == {"steps": 2, "cycles": 150}
    assert st["engine"]["requests"] == 5
    assert st["alerts"] == 5                       # total keeps counting
    assert len(bus.alerts()) == 3                  # ...ring is bounded
    assert len(bus.engine.recent) == 2
    assert len(phases) == 3 and len(requests) == 5


def test_subscribe_unknown_topic_and_unsubscribe():
    bus = TelemetryBus()
    with pytest.raises(ValueError):
        bus.subscribe("bogus", print)
    got = []
    fn = bus.subscribe("window", got.append)
    st = bus.stream("s", ("x",))
    st.roll()
    bus.unsubscribe("window", fn)
    st.roll()
    assert len(got) == 1


# ----------------------------------------------------- fault injection

def test_stationary_traffic_zero_false_positives():
    """The acceptance sweep: jittered but stationary traffic, many
    seeds, single-device and mesh — the sentinel stays silent."""
    for seed in SWEEP_SEEDS:
        for n_devices in (1, 4):
            bus = TelemetryBus()
            s = DriftSentinel(bus)
            FaultDriver(bus, seed=seed, n_devices=n_devices).run(20)
            assert s.tripped() == [], (seed, n_devices, s.tripped())


def test_step_fault_fires_once_named_and_bounded():
    cfg = SentinelConfig()
    for seed in SWEEP_SEEDS:
        bus = TelemetryBus()
        s = DriftSentinel(bus, cfg)
        FaultDriver(bus, seed=seed,
                    faults=[StepFault("attn", at_window=8)]).run(20)
        evs = s.tripped()
        # exactly once (rebaseline adopts the post-step regime)...
        assert len(evs) == 1, (seed, evs)
        ev = evs[0]
        # ...naming the right probe, never the healthy one...
        assert ev.path == "attn" and ev.stream == "drive"
        # ...within the hysteresis-bounded window budget
        assert 8 <= ev.window < 8 + cfg.trip_windows


def test_ramp_fault_fires_repeatedly():
    for seed in (0, 1, 2):
        bus = TelemetryBus()
        s = DriftSentinel(bus)
        FaultDriver(bus, seed=seed,
                    faults=[RampFault("mlp", start_window=8)]).run(24)
        evs = s.tripped()
        assert len(evs) >= 2, (seed, evs)          # keeps drifting → re-fires
        assert all(e.path == "mlp" for e in evs)
        assert evs[0].window < 8 + 4               # bounded first detection


def test_straggler_fault_names_the_device():
    cfg = SentinelConfig()
    for seed in SWEEP_SEEDS:
        bus = TelemetryBus()
        s = DriftSentinel(bus, cfg)
        FaultDriver(bus, seed=seed, n_devices=4,
                    faults=[StragglerFault(device=2, at_window=8)]).run(14)
        evs = s.tripped()
        assert evs, seed
        assert all(e.kind == "straggler" for e in evs), (seed, evs)
        assert all(e.device == 2 for e in evs), (seed, evs)
        assert min(e.window for e in evs) < 8 + cfg.trip_windows + 1


def test_simultaneous_faults_both_detected():
    """A straggling device and an independent global step on another
    probe: the straggler event names the device, the step event does
    not blame it."""
    bus = TelemetryBus()
    s = DriftSentinel(bus)
    FaultDriver(bus, seed=5, n_devices=4, paths=("attn", "mlp"),
                faults=[StragglerFault(device=1, at_window=8,
                                       path="attn"),
                        StepFault("mlp", at_window=8)]).run(16)
    kinds = {(e.kind, e.path) for e in s.tripped()}
    assert ("straggler", "attn") in kinds
    assert any(e.path == "mlp" and e.kind != "straggler"
               for e in s.tripped())
    stragglers = [e for e in s.tripped() if e.kind == "straggler"]
    assert all(e.device == 1 for e in stragglers)


def test_min_samples_gate_never_judges_thin_windows():
    bus = TelemetryBus()
    s = DriftSentinel(bus, SentinelConfig(min_samples=8))
    FaultDriver(bus, seed=0, samples_per_window=4,
                faults=[StepFault("attn", at_window=2)]).run(20)
    assert s.tripped() == []


def test_sentinel_decisions_invariant_to_chunking():
    """Publishing 1 row at a time vs whole windows at once must produce
    identical frames and identical sentinel verdicts."""
    def run(chunk):
        bus = TelemetryBus()
        s = DriftSentinel(bus)
        d = FaultDriver(bus, seed=7, n_devices=2,
                        faults=[StepFault("attn", at_window=6),
                                StragglerFault(device=1, at_window=12)],
                        chunk=chunk)
        frames = d.run(18)
        return frames, [(e.kind, e.path, e.device, e.window)
                        for e in s.tripped()]

    ref_frames, ref_events = run(None)
    for chunk in (1, 7, 64):
        frames, events = run(chunk)
        assert events == ref_events, chunk
        for a, b in zip(frames, ref_frames):
            assert np.array_equal(a.counts, b.counts)
            assert np.array_equal(a.totals, b.totals)
            assert np.array_equal(a.hist, b.hist)


def test_fake_clock_and_driver_determinism():
    clock = FakeClock()
    bus = TelemetryBus()
    d = FaultDriver(bus, seed=3, clock=clock)
    d.run(2)
    assert clock.now() > 0
    bus2 = TelemetryBus()
    d2 = FaultDriver(bus2, seed=3)
    d2.run(2)
    assert np.array_equal(d.stream.agg.total, d2.stream.agg.total)
    assert d2.clock.now() == clock.now()


def test_report_tables_render_sentinel_state():
    from repro.core.report import sentinel_table, telemetry_alert_table
    bus = TelemetryBus()
    s = DriftSentinel(bus)
    assert "no drift events" in telemetry_alert_table([])
    assert "no windows" in sentinel_table(s)
    FaultDriver(bus, seed=0, n_devices=4,
                faults=[StragglerFault(device=2, at_window=8)]).run(12)
    tab = telemetry_alert_table(s.tripped())
    assert "straggler" in tab and "drive" in tab
    assert any(line.split()[4] == "2" for line in tab.splitlines()[1:])
    st = sentinel_table(s)
    assert "drive" in st and "event(s) fired" in st


def test_retune_hook_fires_on_drift():
    tuned = []
    hook = make_retune_hook(tuned.append, background=False)
    bus = TelemetryBus()
    s = DriftSentinel(bus, retune=hook)
    FaultDriver(bus, seed=1,
                faults=[StepFault("attn", at_window=6)]).run(12)
    assert hook.fired == len(s.tripped()) == len(tuned) == 1
    assert tuned[0].path == "attn"
    assert hook.last_result is None or hook.last_result == tuned[0]


# -------------------------------------------------------- HTTP server

@pytest.fixture
def live():
    """A bus with stream + engine + alert data behind a live server."""
    bus = TelemetryBus()
    sentinel = DriftSentinel(bus)
    driver = FaultDriver(bus, seed=2, n_devices=2,
                         faults=[StepFault("attn", at_window=6)])
    driver.run(12)
    bus.publish_phase("decode", cycles=500, batch=2)
    bus.publish_request({"rid": 0, "tokens": 4})
    with StatusServer(bus) as srv:
        yield bus, sentinel, srv


def test_server_binds_ephemeral_port(live):
    bus, _, srv = live
    assert srv.port > 0                            # OS-assigned, readable
    doc, _ = _get_json(srv.url + "/status")
    assert doc["schema"] == 1
    assert doc["streams"]["drive"]["windows"] == 12
    # two servers on one bus never collide (no hard-coded ports)
    with StatusServer(bus) as srv2:
        assert srv2.port != srv.port
        assert _get_json(srv2.url + "/status")[0]["schema"] == 1


def test_status_schema_documented_fields(live):
    _, _, srv = live
    doc, _ = _get_json(srv.url + "/status")
    assert sorted(doc) == ["alerts", "engine", "schema", "streams",
                           "uptime_s"]
    s = doc["streams"]["drive"]
    assert sorted(s) == ["n_devices", "n_probes", "rows_published",
                         "samples", "total_cycles", "windows"]
    assert sorted(doc["engine"]) == ["phases", "requests"]


def test_json_bytes_are_key_sorted_canonical(live):
    _, _, srv = live
    for ep in ("/status", "/probes", "/mesh/skew", "/engine/phases",
               "/alerts"):
        raw = _get(srv.url + ep)
        doc = json.loads(raw)
        canon = (json.dumps(doc, sort_keys=True,
                            separators=(",", ":")) + "\n").encode()
        assert raw == canon, ep


def test_probes_endpoint_exactly_matches_aggregator(live):
    bus, _, srv = live
    doc, _ = _get_json(srv.url + "/probes")
    stream = bus.stream("drive")
    served = doc["drive"]
    local = stream.rows()
    assert served == json.loads(json.dumps(local))  # float round-trip
    agg = stream.agg
    for row, r in enumerate(served):
        assert r["calls"] == int(agg.count[row])
        assert r["total_cycles"] == int(agg.total[row])
        assert r["p99"] == agg.quantile(row, 0.99)
        assert r["ema"] == float(agg.ema[row])      # bit-exact over HTTP


def test_mesh_skew_endpoint(live):
    bus, _, srv = live
    doc, _ = _get_json(srv.url + "/mesh/skew")
    d = doc["drive"]
    assert d["n_devices"] == 2 and d["paths"] == ["attn", "mlp"]
    totals = np.array(d["per_device_totals"])
    assert totals.shape == (2, 2)
    assert np.array_equal(totals.reshape(-1), bus.stream("drive").agg.total)
    per_probe = totals.max(0) - totals.min(0)
    assert d["skew"] == [int(x) for x in per_probe]
    assert d["worst"]["device"] in (0, 1)


def test_engine_and_alert_endpoints(live):
    bus, sentinel, srv = live
    eng, _ = _get_json(srv.url + "/engine/phases")
    assert eng["phases"]["decode"] == {"steps": 1, "cycles": 500}
    assert eng["buckets"] == {"2": 1}
    assert eng["requests_done"] == 1
    assert eng["recent_requests"] == [{"rid": 0, "tokens": 4}]
    al, _ = _get_json(srv.url + "/alerts")
    assert al["total"] == len(sentinel.tripped()) >= 1
    ev = al["events"][0]
    assert ev["kind"] == "hist-drift" and ev["path"] == "attn"
    assert sorted(ev) == ["detail", "device", "kind", "path", "severity",
                          "stream", "threshold", "window"]


def test_metrics_prometheus_exposition(live):
    bus, _, srv = live
    body = _get(srv.url + "/metrics").decode()
    assert body == render_metrics(bus)
    assert "# TYPE repro_probe_calls_total counter" in body
    agg = bus.stream("drive").agg
    line = (f'repro_probe_calls_total{{device="0",path="attn",'
            f'stream="drive"}} {int(agg.count[0])}')
    assert line in body
    assert f"repro_alerts_total {bus.alerts_total}" in body
    assert "repro_engine_phase_cycles_total{phase=\"decode\"} 500" in body


def test_unknown_endpoint_404(live):
    _, _, srv = live
    with pytest.raises(urllib.error.HTTPError) as e:
        _get(srv.url + "/bogus")
    assert e.value.code == 404
    doc = json.loads(e.value.read())
    assert "/mesh/skew" in doc["endpoints"]


# ---------------------------------------------- hypothesis properties

def test_property_served_aggregates_equal_one_shot():
    """For random record streams, /probes values == a one-shot
    StreamAggregator fed the same data (dev-only dependency)."""
    pytest.importorskip(
        "hypothesis",
        reason="dev-only dependency — pip install -r requirements-dev.txt")
    from hypothesis import given, settings, strategies as st

    chunks = st.lists(
        st.tuples(st.integers(0, 2),
                  st.lists(st.integers(1, 1 << 30), min_size=1,
                           max_size=20)),
        min_size=1, max_size=20)

    @settings(max_examples=20, deadline=None)
    @given(chunks)
    def inner(data):
        bus = TelemetryBus()
        stream = bus.stream("p", ("a", "b", "c"))
        ref = StreamAggregator(3, ema_alpha=0.1)
        for pid, durs in data:
            arr = np.array(durs, np.int64)
            stream.add(pid, arr)
            ref.add(pid, arr)
        with StatusServer(bus) as srv:
            doc, _ = _get_json(srv.url + "/probes")
        for row, r in enumerate(doc["p"]):
            assert r["calls"] == int(ref.count[row])
            assert r["total_cycles"] == int(ref.total[row])
            assert r["ema"] == float(ref.ema[row])
            assert r["min"] == (int(ref.min[row]) if ref.count[row] else 0)
            assert r["max"] == int(ref.max[row])
            assert r["p50"] == ref.quantile(row, 0.5)
            assert r["p99"] == ref.quantile(row, 0.99)

    inner()


def test_property_sentinel_chunking_invariance():
    """Sentinel verdicts depend only on window deltas, never on how the
    rows were chunked into ``add`` calls (dev-only dependency)."""
    pytest.importorskip(
        "hypothesis",
        reason="dev-only dependency — pip install -r requirements-dev.txt")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1 << 16), st.integers(1, 64),
           st.integers(4, 10))
    def inner(seed, chunk, at_window):
        def run(c):
            bus = TelemetryBus()
            s = DriftSentinel(bus)
            FaultDriver(bus, seed=seed, chunk=c,
                        faults=[StepFault("attn", at_window=at_window)]
                        ).run(at_window + 6)
            return [(e.kind, e.path, e.window) for e in s.tripped()]
        assert run(None) == run(chunk)

    inner()


# ----------------------------------------- session / engine integration

def _tiny_workload(x, w):
    import jax
    import jax.numpy as jnp

    def body(c, _):
        with jax.named_scope("layer"):
            c = jnp.tanh(c @ w) + c
        return c, None
    with jax.named_scope("layers"):
        x, _ = jax.lax.scan(body, x, None, length=3)
    with jax.named_scope("head"):
        return jnp.sum(x * x)


def test_probe_session_publishes_windows_to_bus():
    import jax.numpy as jnp

    from repro.core import ProbeConfig, ProbeSession
    bus = TelemetryBus()
    frames = []
    bus.subscribe("window", frames.append)
    args = (jnp.ones((4, 8)) * 0.05, jnp.full((8, 8), 0.07))
    cfg = ProbeConfig(inline="off_all", offload=1.0, buffer_depth=2)
    with ProbeSession(_tiny_workload, cfg, window_steps=2, bus=bus,
                      source="sess") as s:
        for _ in range(6):
            s.step(*args)
        snap = s.snapshot()
        s.sink.flush()
    stream = bus.stream("sess")
    assert stream.paths == tuple(snap.paths)
    # the session's aggregator IS the bus stream's aggregator
    assert stream.agg is s.sink.stats
    assert stream.windows >= 3
    # window deltas partition the totals exactly; exact device-counter
    # deltas ride along and sum to the same thing
    by_row = np.zeros(stream.n_rows, np.int64)
    exact = np.zeros(stream.n_rows, np.int64)
    for f in frames:
        by_row += f.totals
        assert f.exact_totals is not None
        exact += f.exact_totals
    assert np.array_equal(by_row, stream.agg.total)
    assert np.array_equal(exact, stream.agg.total)
    assert bus.status()["streams"]["sess"]["samples"] == \
        int(stream.agg.count.sum())


def test_mesh_session_publishes_device_major_stream(tiny_mesh):
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import MeshProbeSession, ProbeConfig, mesh_probe
    bus = TelemetryBus()
    x = jnp.arange(8.0).reshape(2, 4) * 0.01
    w = jnp.full((4, 4), 0.25)
    with MeshProbeSession(
            mesh_probe(_tiny_workload, tiny_mesh, (P("dev"), P()), P(),
                       ProbeConfig(inline="off_all")),
            window_steps=2, bus=bus, source="mesh") as s:
        for _ in range(4):
            s.step(x, w)
        snap = s.snapshot()
    stream = bus.stream("mesh")
    assert stream.n_devices == snap.record.n_devices == 1
    assert stream.windows == 2
    assert np.array_equal(stream.agg.total.reshape(1, -1),
                          snap.record.totals)


# ------------------------------------------------- end-to-end (slow)

@pytest.mark.slow
def test_e2e_live_decode_with_status_server(tiny_model):
    """A probed decode loop on the tiny model with the status server
    attached: endpoints stay live and schema-stable mid-decode, served
    aggregates equal the in-process ones, and the decoded tokens are
    bit-identical to the unprobed reference."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ShapeConfig
    from repro.core import ProbeConfig, ProbeSession
    from repro.distributed.steps import build_decode_step, build_prefill_step
    cfg, model, params = tiny_model
    batch, prompt_len, max_new, cache_len = 2, 16, 6, 32
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    prefill = jax.jit(build_prefill_step(
        model, ShapeConfig("pf", cache_len, batch, "prefill")))

    def decode_loop(decode, on_step=None):
        logits, cache = prefill(params, {"tokens": tokens})
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [np.asarray(next_tok)]
        for i in range(max_new - 1):
            dbatch = {"tokens": next_tok[:, None],
                      "pos": jnp.int32(prompt_len + i)}
            logits, cache, next_tok = decode(params, cache, dbatch)
            out.append(np.asarray(next_tok))
            if on_step is not None:
                on_step(i)
        return np.stack(out, axis=1)

    bus = TelemetryBus()
    sentinel = DriftSentinel(bus)
    polled = []
    with StatusServer(bus) as srv:
        def poll(i):
            if i == 2:                              # mid-decode, live
                polled.append(_get_json(srv.url + "/status"))
                polled.append((None, _get(srv.url + "/metrics")))
        with ProbeSession(build_decode_step(model),
                          ProbeConfig(offload=1.0, max_probes=16),
                          window_steps=2, bus=bus,
                          source="serve/decode") as s:
            got = decode_loop(s.step, poll)
            snap = s.snapshot()
        doc, _ = _get_json(srv.url + "/probes")
        status, _ = _get_json(srv.url + "/status")
        metrics = _get(srv.url + "/metrics").decode()
    assert sentinel.tripped() == []                 # healthy run: silent

    # bit-identity with the server attached
    ref = decode_loop(jax.jit(build_decode_step(model)))
    assert np.array_equal(got, ref)

    # mid-decode polls parsed and carried the documented schema
    assert polled and sorted(polled[0][0]) == [
        "alerts", "engine", "schema", "streams", "uptime_s"]
    assert b"repro_probe_calls_total" in polled[1][1]

    # served aggregates == the in-process stream aggregator, exactly
    # (JSON round-trip included); the session snapshot may lead by the
    # ring remainder (< buffer_depth rows not yet spilled at the poll)
    stream = bus.stream("serve/decode")
    assert stream.agg is s.sink.stats
    assert doc["serve/decode"] == json.loads(json.dumps(stream.rows()))
    depth = ProbeConfig().buffer_depth
    served = {r["path"]: r for r in doc["serve/decode"]}
    for row in snap.rows:
        if not row.calls:
            continue
        r = served[row.path]
        assert 0 <= row.calls - r["calls"] < depth, row.path
        assert r["total_cycles"] <= row.total_cycles, row.path
        if r["calls"]:
            assert row.min <= r["min"] <= r["max"] <= row.max, row.path
    assert status["streams"]["serve/decode"]["windows"] >= 2
    assert 'stream="serve/decode"' in metrics
