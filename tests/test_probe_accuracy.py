"""The paper's Table II experiment: RealProbe counters must equal the
independent oracle ("ILA") EXACTLY — integer equality, every workload.
Also: non-intrusiveness (outputs unchanged) and offload losslessness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import probe, ProbeConfig
from repro.core.instrument import decode_record


def _assert_exact(pf, rec, oc):
    dec = decode_record(rec)
    for i, p in enumerate(pf.probe_paths()):
        assert int(dec["totals"][i]) == oc.totals[i], p
        assert int(dec["calls"][i]) == oc.calls[i], p
        assert int(dec["starts"][i]) == oc.starts[i], p
        assert int(dec["ends"][i]) == oc.ends[i], p
    assert dec["cycle"] == oc.cycle


def _workload_scan(x, w):
    def body(c, _):
        with jax.named_scope("layer"):
            c = jnp.tanh(c @ w) @ w.T + c
        return c, None
    with jax.named_scope("layers"):
        x, _ = jax.lax.scan(body, x, None, length=5)
    with jax.named_scope("head"):
        return jnp.sum(x * x)


def _workload_while(x, w):
    def cond(c):
        return jnp.sum(jnp.abs(c[0])) < 1e4
    def body(c):
        with jax.named_scope("grow"):
            return (c[0] @ w * 1.2 + 1.0, c[1] + 1)
    with jax.named_scope("dynamic"):
        y, n = jax.lax.while_loop(cond, body, (x, jnp.int32(0)))
    return jnp.sum(y), n


def _workload_cond(x, w):
    def heavy(v):
        with jax.named_scope("heavy"):
            return jnp.tanh(v @ w) @ w.T
    def light(v):
        with jax.named_scope("light"):
            return v * 2.0
    with jax.named_scope("branch"):
        y = jax.lax.cond(jnp.sum(x) > 0, heavy, light, x)
    return jnp.sum(y)


def _workload_nested(x, w):
    def inner_body(c, _):
        with jax.named_scope("inner"):
            return jnp.tanh(c @ w) + c, None
    def outer_body(c, _):
        with jax.named_scope("group"):
            c, _ = jax.lax.scan(inner_body, c, None, length=3)
            with jax.named_scope("mix"):
                c = c @ w.T @ w
        return c, None
    with jax.named_scope("outer"):
        x, _ = jax.lax.scan(outer_body, x, None, length=2)
    return jnp.sum(x)


WORKLOADS = {
    "scan": _workload_scan,
    "while_dynamic": _workload_while,
    "cond": _workload_cond,
    "nested_scan": _workload_nested,
}


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_probe_matches_oracle_exactly(name):
    fn = WORKLOADS[name]
    x = jnp.ones((8, 16)) * 0.05
    w = jnp.full((16, 16), 0.07)
    pf = probe(fn, ProbeConfig(inline="off_all"))
    out, rec = pf(x, w)
    # non-intrusive
    out0 = jax.jit(fn)(x, w)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(out0)):
        assert np.allclose(np.asarray(a), np.asarray(b))
    _assert_exact(pf, rec, pf.oracle(x, w))


def test_probe_with_offload_lossless():
    fn = WORKLOADS["scan"]
    x = jnp.ones((8, 16)) * 0.05
    w = jnp.full((16, 16), 0.07)
    pf = probe(fn, ProbeConfig(inline="off_all", buffer_depth=2, offload=1.0))
    out, rec = pf(x, w)
    oc = pf.oracle(x, w)
    _assert_exact(pf, rec, oc)
    li = pf.probe_paths().index("layers/scan#0/layer")
    rep = pf.report(rec)
    row = rep.row("layers/scan#0/layer")
    assert row.iters == oc.history[li]          # full history reassembled
    assert pf.sink.dumps > 0


def test_probe_first4_truncation_without_offload():
    fn = WORKLOADS["scan"]
    x = jnp.ones((8, 16)) * 0.05
    w = jnp.full((16, 16), 0.07)
    pf = probe(fn, ProbeConfig(inline="off_all", buffer_depth=4))
    out, rec = pf(x, w)
    oc = pf.oracle(x, w)
    li = pf.probe_paths().index("layers/scan#0/layer")
    rep = pf.report(rec)
    row = rep.row("layers/scan#0/layer")
    assert row.calls == 5
    assert len(row.iters) == 4                   # first-4 kept (paper)
    assert row.iters == oc.history[li][:4]
    # totals are still exact despite truncation
    assert row.total_cycles == oc.totals[li]


@pytest.mark.slow
def test_probe_train_step_exact(tiny_model):
    cfg, m, params = tiny_model
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}

    def train_step(params, batch):
        (loss, _), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(
            params, batch)
        return loss

    pf = probe(train_step, ProbeConfig(max_probes=48))
    loss, rec = pf(params, batch)
    loss0 = jax.jit(train_step)(params, batch)
    assert np.allclose(float(loss), float(loss0), rtol=1e-6)
    _assert_exact(pf, rec, pf.oracle(params, batch))
    # forward and backward scopes both present
    paths = pf.probe_paths()
    assert any("~bwd" in p for p in paths)


def test_static_estimate_marks_dynamic_unknown():
    """C-synth analogue: while-loop trip counts are '?' statically but
    exact at runtime (the Fig 1 / Table II 'discrepancy' story)."""
    fn = WORKLOADS["while_dynamic"]
    x = jnp.ones((8, 16)) * 0.05
    w = jnp.full((16, 16), 0.07)
    pf = probe(fn, ProbeConfig(inline="off_all"))
    (out, n), rec = pf(x, w)
    rep = pf.report(rec)
    row = rep.row("dynamic/while#0")
    assert row.dynamic                          # static estimate = "?"
    assert row.calls == int(n)                  # runtime knows the truth
    assert row.total_cycles > 0


def test_wallclock_mode_runs_and_orders():
    fn = WORKLOADS["scan"]
    x = jnp.ones((8, 16)) * 0.05
    w = jnp.full((16, 16), 0.07)
    pf = probe(fn, ProbeConfig(inline="off_all", cycle_source="wallclock"))
    out, rec = pf(x, w)
    rep = pf.report(rec)
    row = rep.row("layers")
    assert row.end >= row.start > 0             # monotone host timestamps
    assert row.total_cycles > 0
