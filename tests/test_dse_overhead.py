"""Paper §IV-D/E substrate: analytical overhead model, adaptive
allocation, DSE Pareto sweep, incremental re-instrumentation."""
import jax
import jax.numpy as jnp

from repro.core import (OverheadModel, ProbeConfig, adapt_allocation,
                        measure_incremental, measure_overhead, run_dse)
from repro.core.buffer import state_bytes


def _fn(x, w):
    def body(c, _):
        with jax.named_scope("layer"):
            with jax.named_scope("attn"):
                c = jnp.tanh(c @ w) @ w.T + c
            with jax.named_scope("mlp"):
                c = jax.nn.silu(c @ w) @ w.T + c
        return c, None
    with jax.named_scope("layers"):
        x, _ = jax.lax.scan(body, x, None, length=6)
    with jax.named_scope("head"):
        return jnp.sum(x * x)


X = jnp.ones((8, 32)) * 0.1
W = jnp.full((32, 32), 0.05)


def test_overhead_model_fits_measurements():
    samples = []
    for tgt, depth in [(("",), 4), (("layers",), 8),
                       (("layers/scan#0/layer",), 4), (("head",), 4)]:
        samples.append(measure_overhead(
            _fn, (X, W), ProbeConfig(targets=tgt, buffer_depth=depth,
                                     inline="off_all")))
    m = OverheadModel.fit(samples)
    for s in samples:
        pred = m.predict_eqns(s)
        assert abs(pred - s["extra_eqns"]) / max(s["extra_eqns"], 1) < 0.25
        assert m.predict_state_bytes(s["n_probes"], s["depth"]) == \
            s["state_bytes"]


def test_overhead_scales_with_probes():
    few = measure_overhead(_fn, (X, W),
                           ProbeConfig(targets=("head",), inline="off_all"))
    many = measure_overhead(_fn, (X, W),
                            ProbeConfig(targets=("",), inline="off_all"))
    assert many["n_probes"] > few["n_probes"]
    assert many["extra_eqns"] > few["extra_eqns"]


def test_adapt_allocation_fits_budget():
    n, d = adapt_allocation(50, 64, budget_bytes=state_bytes(50, 8))
    assert state_bytes(n, d) <= state_bytes(50, 8)
    assert n == 50 and d <= 8            # prefers shrinking depth
    n2, d2 = adapt_allocation(50, 4, budget_bytes=state_bytes(10, 1))
    assert state_bytes(n2, d2) <= state_bytes(10, 1)
    assert n2 < 50                       # then drops probes


def test_dse_sweep_and_pareto():
    res = run_dse(_fn, (X, W), ProbeConfig(inline="off_all"),
                  storages=("registers", "bram"),
                  offload_ratios=(0.0, 0.5), repeats=1)
    assert len(res.points) == 4
    assert 1 <= len(res.pareto) <= 4
    assert res.best() is not None
    # offloading points actually shipped bytes to the "DRAM" sink
    off = [p for p in res.points if p.offload_ratio > 0]
    assert any(p.dram_bytes > 0 for p in off)
    # deeper buffers cost more state
    reg = next(p for p in res.points
               if p.storage == "registers" and p.offload_ratio == 0)
    bram = next(p for p in res.points
                if p.storage == "bram" and p.offload_ratio == 0)
    assert bram.state_bytes > reg.state_bytes
    assert res.table()                  # renders


def test_incremental_reuse():
    t = measure_incremental(
        _fn, (X, W),
        ProbeConfig(targets=("layers",), inline="off_all"),
        ProbeConfig(targets=("layers/scan#0/layer/mlp",), inline="off_all"))
    assert t.base_compile_reused         # model executable untouched
    assert t.retarget_total_s < t.cold_total_s
    assert t.reuse_fraction > 0
    assert t.table()
