"""Trace-once cycle simulator (ISSUE 9 tentpole): `price()` must be
integer-identical to BOTH live clocks — the kernel-probed grid replay
(sim mode) and the DSEEngine ProbeSession measurement (flat mode) — and
the artifacts must round-trip canonically and re-price under the
calibration / mesh contexts current at pricing time."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core import costmodel as cm
from repro.core import tracesim as ts
from repro.core.dse import DSEEngine
from repro.core.instrument import decode_record
from repro.core.pragma import ProbeConfig, probe
from repro.kernels.search_spaces import (flash_attention_space,
                                         paged_attention_space,
                                         ssd_scan_space)

CASES = {
    "flash_attention": (
        lambda: flash_attention_space(S=128, D=32, blocks_q=(32, 64),
                                      blocks_k=(32, 64), pipelines=(1, 2)),
        [{"block_q": 32, "block_k": 32, "pipeline": 1},
         {"block_q": 64, "block_k": 32, "pipeline": 2}],
    ),
    "ssd_scan": (
        lambda: ssd_scan_space(L=128, chunks=(32, 64), pipelines=(1, 2)),
        [{"chunk": 32, "pipeline": 2}, {"chunk": 64, "pipeline": 1}],
    ),
    "paged_attention": (
        lambda: paged_attention_space(),
        [{"pages_per_step": 2}, {"pages_per_step": 8}],
    ),
}


@pytest.fixture(scope="module", params=sorted(CASES))
def captured(request):
    """One walked capture per golden kernel, shared across tests."""
    build, configs = CASES[request.param]
    space = build()
    trace = ts.capture(space, configs, walk=True,
                       space_fingerprint=ts.space_fingerprint(space))
    return space, configs, trace


def live_grid_replay_cycles(space, config) -> int:
    """The live kernel-probed decode span (the clock sim mode models)."""
    pc = ProbeConfig(targets=("",), max_probes=16, buffer_depth=2,
                     cycle_source="model", kernel_probes=("*",),
                     inline="off_all")
    pf = probe(space.bind(config), pc)
    _, rec = pf(*space.args)
    return int(decode_record(jax.device_get(rec))["cycle"])


# --------------------------------------------------- integer exactness

def test_sim_price_equals_live_grid_replay(captured):
    space, configs, trace = captured
    for cfg in configs:
        sim = ts.price(trace, cfg, mode="sim")
        live = live_grid_replay_cycles(space, cfg)
        assert sim == live, (space.kernel_id, cfg)
        entry = trace.entries[ts.config_key(cfg)]
        assert entry.exact and entry.walked


def test_flat_price_equals_engine_measurement(captured):
    space, configs, trace = captured
    engine = DSEEngine(space, budget=None)
    for cfg in configs:
        flat = ts.price(trace, cfg, mode="flat")
        measured = engine._measure(cfg, 2)
        assert flat == int(measured) == measured, (space.kernel_id, cfg)


def test_calibrated_reprice_matches_measure(captured):
    """Installing a kernel calibration re-prices the SAME artifact to
    the engine's calibrated model clock — no re-capture."""
    space, configs, trace = captured
    cfg = configs[0]
    uncal = ts.price(trace, cfg, mode="flat")
    cm.clear_kernel_calibration()
    try:
        entry = trace.entries[ts.config_key(cfg)]
        for site in entry.sites:
            cm.set_kernel_calibration(site.kernel, 0.5)
        recal = ts.price(trace, cfg, mode="flat")
        assert recal < uncal
        assert recal == DSEEngine(space, budget=None)._measure(cfg, 2)
        # sim mode walks measured branch structure: calibration-free
        assert ts.price(trace, cfg, mode="sim") == ts.price(
            trace, cfg, mode="sim")
    finally:
        cm.clear_kernel_calibration()
    assert ts.price(trace, cfg, mode="flat") == uncal


# ------------------------------------------------------- serialization

def test_trace_json_roundtrip_canonical(captured):
    space, configs, trace = captured
    s1 = ts.to_json(trace)
    back = ts.from_json(s1)
    assert ts.to_json(back) == s1, "round-trip must be byte-identical"
    # canonical: parse -> dump(sorted) is a fixed point
    assert json.dumps(json.loads(s1), sort_keys=True,
                      separators=(",", ":")) == s1
    for cfg in configs:
        assert ts.price(back, cfg, mode="sim") == \
            ts.price(trace, cfg, mode="sim")
        assert ts.price(back, cfg, mode="flat") == \
            ts.price(trace, cfg, mode="flat")


def test_trace_store_merge_and_staleness_key(tmp_path, captured):
    space, configs, trace = captured
    store = ts.TraceStore(str(tmp_path))
    half = ts.KernelTrace(kernel_id=trace.kernel_id, shape=trace.shape,
                          space_fingerprint=trace.space_fingerprint)
    k0, k1 = (ts.config_key(c) for c in configs[:2])
    half.entries[k0] = trace.entries[k0]
    store.merge(half)
    other = ts.KernelTrace(kernel_id=trace.kernel_id, shape=trace.shape,
                           space_fingerprint=trace.space_fingerprint)
    other.entries[k1] = trace.entries[k1]
    merged = store.merge(other)
    assert set(merged.entries) >= {k0, k1}, "merge must keep both writers"
    loaded = store.load(trace.kernel_id, trace.shape,
                        trace.space_fingerprint)
    assert loaded is not None and set(loaded.entries) >= {k0, k1}
    # a kernel edit changes the space fingerprint -> different artifact
    assert store.load(trace.kernel_id, trace.shape, "deadbeef") is None


# --------------------------------------------------- collective context

def test_collective_sites_reprice_with_mesh_context():
    from repro.distributed import compat

    def fn(x):
        return jax.lax.psum(x * 2.0, "dev")

    with compat.extend_axis_env({"dev": 8}):
        closed = jax.make_jaxpr(fn)(jnp.ones((4096,), jnp.float32))
    entry = ts.capture_closed(closed)
    assert len(entry.collectives) == 1
    (eqn,) = [e for e in closed.jaxpr.eqns if e.primitive.name == "psum"]
    base = entry.base_cycles
    # priced against whatever context is CURRENT at price() time, with
    # the same arithmetic as the live eqn cost
    assert ts.price(entry, mode="flat") == base + cm.eqn_cost(eqn).cycles
    with cm.collective_axis_sizes({"dev": 8}):
        p8 = ts.price(entry, mode="flat")
        assert p8 == base + cm.eqn_cost(eqn).cycles
    with cm.collective_axis_sizes({"dev": 2}):
        p2 = ts.price(entry, mode="flat")
        assert p2 == base + cm.eqn_cost(eqn).cycles
    assert p8 > p2, "bigger ring, more wire cycles"


# -------------------------------------------------------- cheap checks

def test_price_requires_config_for_trace(captured):
    space, configs, trace = captured
    with pytest.raises(ValueError):
        ts.price(trace)
    with pytest.raises(KeyError):
        ts.price(trace, {"not": "captured"})
    with pytest.raises(ValueError):
        ts.price(trace, configs[0], mode="oracle")


def test_unwalked_capture_prices_flat_in_sim_mode():
    build, configs = CASES["ssd_scan"]
    space = build()
    entry = ts.capture_entry(space, configs[0], walk=False)
    assert not entry.walked
    assert ts.price(entry, mode="sim") == ts.price(entry, mode="flat")


def test_entry_resources_match_live_analysis():
    build, configs = CASES["flash_attention"]
    space = build()
    cfg = configs[0]
    entry = ts.capture_entry(space, cfg, walk=False)
    closed = jax.make_jaxpr(space.bind(cfg))(*space.args)
    live = cm.jaxpr_kernel_resources(closed.jaxpr)
    got = ts.entry_resources(entry)
    assert (got.vmem_bytes, got.hbm_bytes, got.flops, got.grid_steps) == \
        (live.vmem_bytes, live.hbm_bytes, live.flops, live.grid_steps)
    assert got.static_cycles == live.static_cycles
