import jax
import jax.numpy as jnp
import pytest

# NOTE: no XLA_FLAGS here — smoke tests see the real (1-device) backend.
# Multi-device tests spawn subprocesses that set their own flags
# (tests/test_distributed.py), and the 512-device dry-run only ever runs
# via `python -m repro.launch.dryrun`.


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_model():
    """Shared tinyllama smoke model: (cfg, model, params), initialized
    once per session. Model init dominates several probe/system tests;
    params are jax arrays (immutable), so session sharing is safe."""
    from repro.configs.registry import smoke_config
    from repro.models import Model
    cfg = smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="session")
def tiny_mesh():
    """Shared 1-device probe mesh (the fast in-process mesh tests all
    build the same one)."""
    from repro.launch.mesh import make_mesh
    return make_mesh((1,), ("dev",))


def tiny_batch(cfg, B=2, S=64, seed=0):
    k = jax.random.PRNGKey(seed)
    from repro.models.frontends import synth_frontend_batch
    if cfg.frontend != "none":
        batch = dict(synth_frontend_batch(cfg, B, S, jnp.bfloat16, k))
    else:
        batch = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    batch["labels"] = jax.random.randint(jax.random.fold_in(k, 1), (B, S),
                                         0, cfg.vocab_size)
    return batch
