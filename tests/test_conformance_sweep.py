"""Fixed seeded conformance corpus (the paper's evaluation table at
repro scale): every graph in the corpus must pass all six probe
invariants. The corpus is frozen — seed S always builds the same graph
(``random_spec`` uses ``random.Random``), so a failure here is
reproducible with the printed command from any machine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing import (GraphSpec, build, random_spec,
                           run_conformance)
from repro.testing.conformance import INVARIANTS

# the tier-1 fast subset keeps a handful of graphs under the CI
# timeout; the remainder of the 40-graph corpus runs with the slow
# suite (and nightly's 200-graph sweep extends the same sequence)
FAST_SEEDS = tuple(range(8))
SLOW_SEEDS = tuple(range(8, 40))
CORPUS = FAST_SEEDS + SLOW_SEEDS


@pytest.mark.parametrize(
    "seed",
    list(FAST_SEEDS) + [pytest.param(s, marks=pytest.mark.slow)
                        for s in SLOW_SEEDS])
def test_corpus_graph_conformance(seed):
    stats = run_conformance(random_spec(seed))
    assert stats["invariants"] == INVARIANTS     # zero skipped invariants
    assert stats["n_probes"] > 0


def test_spec_json_roundtrip_and_determinism():
    for seed in range(200):
        spec = random_spec(seed)
        assert GraphSpec.from_json(spec.to_json()) == spec
        assert random_spec(seed) == spec         # draw is deterministic
        assert spec.blocks                       # never an empty graph


def test_corpus_covers_the_structure_space():
    """The frozen corpus must actually exercise the generator's whole
    vocabulary — every block kind and every wrapper appears, and both
    kernel and non-kernel graphs are present."""
    kinds, wrappers, kernels = set(), set(), set()
    for seed in CORPUS:
        spec = random_spec(seed)
        for b in spec.blocks:
            kinds.add(b.kind)
            wrappers.add(b.wrapper)
        kernels.add(spec.has_kernel)
    assert kinds >= {"mlp", "attn", "ssm", "moe", "elementwise"}
    assert "flash_kernel" in kinds or "ssd_kernel" in kinds
    assert wrappers >= {"none", "scan", "remat", "cond", "jit", "while",
                        "scan_cond"}
    assert kernels == {True, False}


def test_build_is_deterministic_per_spec():
    spec = random_spec(7)
    fn1, args1 = build(spec)
    fn2, args2 = build(spec)
    for a, b in zip(jax.tree_util.tree_leaves(args1),
                    jax.tree_util.tree_leaves(args2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(jax.jit(fn1)(*args1)),
                          np.asarray(jax.jit(fn2)(*args2)))


# ----------------------------------------------------------------------
# Regression pinned to the discovering GraphSpec: random_spec(5) put the
# same flash-attention custom_vjp (and its scan bodies) at two call
# sites; jax's tracing cache shares the traced body OBJECT between
# sites, and the id-keyed EqnInfo table attributed both sites' inner
# equations to whichever site was walked last (oracle crash / silently
# double-counted device counters). Minimal form: one module-level scan
# body traced at two scopes.

def _shared_scan_body(c, _):
    with jax.named_scope("inner"):
        return jnp.tanh(c) + 0.01, None


def test_shared_subjaxpr_per_site_attribution_seed5():
    def fn(x):
        with jax.named_scope("first"):
            a, _ = jax.lax.scan(_shared_scan_body, x, None, length=2)
        with jax.named_scope("second"):
            b, _ = jax.lax.scan(_shared_scan_body, a, None, length=3)
        return jnp.sum(a * b)

    from repro.core import ProbeConfig, probe
    from repro.core.instrument import decode_record
    pf = probe(fn, ProbeConfig(inline="off_all"))
    out, rec = pf(*(jnp.ones((4, 8)) * 0.1,))
    paths = pf.probe_paths()
    # both sites' loop bodies are probed independently
    fi = paths.index("first/scan#0/inner")
    si = paths.index("second/scan#0/inner")
    dec = decode_record(jax.device_get(rec))
    assert int(dec["calls"][fi]) == 2
    assert int(dec["calls"][si]) == 3
    # the shared body really was deduplicated by jax — the hierarchy
    # must carry per-site rows for it (the fixed failure mode)
    assert pf.hierarchy.site_info, "expected a shared traced body"
    # and the device counters still match the oracle exactly
    oc = pf.oracle(jnp.ones((4, 8)) * 0.1)
    for i, p in enumerate(paths):
        assert int(dec["totals"][i]) == oc.totals[i], p
        assert int(dec["calls"][i]) == oc.calls[i], p
    assert int(dec["cycle"]) == oc.cycle


@pytest.mark.slow
def test_discovering_spec_seed5_full_conformance():
    """The exact GraphSpec that surfaced the shared-body bug."""
    stats = run_conformance(random_spec(5))
    assert stats["invariants"] == INVARIANTS
