"""Property-based tests (hypothesis) on system invariants."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev-only dependency — pip install -r requirements-dev.txt")
from hypothesis import assume, given, settings, strategies as st

# example-count profiles: the tier-1 run keeps the conformance property
# cheap; nightly (HYPOTHESIS_PROFILE=nightly) widens the random-graph
# search the same way the 200-seed sweep widens the fixed corpus
settings.register_profile("ci", max_examples=3, deadline=None)
settings.register_profile("nightly", max_examples=30, deadline=None)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))

from repro.core.counters import (c64, c64_add, c64_add_int, c64_sub,
                                 c64_to_int)
from repro.core.buffer import state_bytes
from repro.data import DataConfig, TokenPipeline
from repro.optim.quantized import dequantize, quantize

U64 = 1 << 64


@settings(max_examples=50, deadline=None)
@given(st.integers(0, U64 - 1), st.integers(0, U64 - 1))
def test_c64_add_matches_python(a, b):
    got = int(c64_to_int(c64_add(c64(a), c64(b))))
    assert got == (a + b) % U64


@settings(max_examples=50, deadline=None)
@given(st.integers(0, U64 - 1), st.integers(0, U64 - 1))
def test_c64_sub_matches_python(a, b):
    got = int(c64_to_int(c64_sub(c64(a), c64(b))))
    assert got == (a - b) % U64


@settings(max_examples=30, deadline=None)
@given(st.integers(0, U64 - 1), st.integers(0, 1 << 40))
def test_c64_add_int_matches_python(a, d):
    got = int(c64_to_int(c64_add_int(c64(a), d)))
    assert got == (a + d) % U64


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64))
def test_state_bytes_monotone(n, d):
    assert state_bytes(n + 1, d) > state_bytes(n, d)
    assert state_bytes(n, d + 1) > state_bytes(n, d)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 8), st.integers(2, 500))
def test_pipeline_sample_pure_function_of_index(step, hosts, vocab):
    """host shards partition the global stream for any host count."""
    gb = hosts * 2
    full = TokenPipeline(DataConfig(vocab_size=vocab, seq_len=4,
                                    global_batch=gb, seed=9)).batch_at(step)
    parts = [TokenPipeline(DataConfig(vocab_size=vocab, seq_len=4,
                                      global_batch=gb, seed=9,
                                      num_hosts=hosts, host_index=h)
                           ).batch_at(step)["tokens"]
             for h in range(hosts)]
    assert np.array_equal(np.concatenate(parts), full["tokens"])
    assert full["tokens"].max() < vocab


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(1, 200), st.floats(0.1, 100.0))
def test_quantize_error_bounded(rows, cols, scale):
    key = jax.random.PRNGKey(rows * cols)
    x = jax.random.normal(key, (rows, cols)) * scale
    err = np.asarray(jnp.abs(dequantize(quantize(x)) - x))
    rowmax = np.asarray(jnp.max(jnp.abs(x), axis=-1, keepdims=True))
    assert (err <= rowmax * (0.5 / 127) + 1e-6).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(2, 5), st.integers(1, 4))
def test_probe_report_invariants(n_layers, width_pow, seed):
    """start <= end; child total <= ancestor total; span >= any total."""
    from repro.core import probe, ProbeConfig
    d = 2 ** width_pow

    def fn(x, w):
        def body(c, _):
            with jax.named_scope("layer"):
                with jax.named_scope("mm"):
                    c = jnp.tanh(c @ w) + c
            return c, None
        with jax.named_scope("layers"):
            x, _ = jax.lax.scan(body, x, None, length=n_layers)
        return x.sum()

    x = jax.random.normal(jax.random.PRNGKey(seed), (4, d))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, d)) * 0.1
    pf = probe(fn, ProbeConfig(inline="off_all"))
    _, rec = pf(x, w)
    rep = pf.report(rec)
    by_path = {r.path: r for r in rep.rows}
    for r in rep.rows:
        assert r.end >= r.start
        assert rep.span >= r.total_cycles
        parent = r.path.rsplit("/", 1)[0] if "/" in r.path else None
        if parent and parent in by_path:
            assert by_path[parent].total_cycles >= r.total_cycles
    lay = by_path.get("layers/scan#0/layer")
    assert lay is not None and lay.calls == n_layers


# --------------------------- packed vs legacy probe-state layouts

@settings(max_examples=5, deadline=None)
@given(n_layers=st.integers(1, 4), inner=st.integers(1, 3),
       depth=st.integers(1, 6), width_pow=st.integers(2, 4),
       offload=st.booleans())
def test_packed_decode_equals_legacy_on_random_hierarchies(
        n_layers, inner, depth, width_pow, offload):
    """Layout equivalence property: for random scope hierarchies (nested
    scans, data-dependent while, varying ring depths, spill on/off) the
    packed-SoA state decodes bit-for-bit identically to the legacy
    dict-of-small-arrays reference."""
    from repro.core import probe, ProbeConfig
    from repro.core.instrument import decode_record
    d = 2 ** width_pow

    def fn(x, w):
        def ib(c, _):
            with jax.named_scope("inner"):
                return jnp.tanh(c @ w) + c, None

        def ob(c, _):
            with jax.named_scope("layer"):
                c, _ = jax.lax.scan(ib, c, None, length=inner)
                with jax.named_scope("mix"):
                    c = c @ w.T
            return c, None

        with jax.named_scope("layers"):
            x, _ = jax.lax.scan(ob, x, None, length=n_layers)

        def cond(s):
            return jnp.sum(jnp.abs(s[0])) < 50.0

        def grow(s):
            with jax.named_scope("grow"):
                return (s[0] * 1.5 + 0.1, s[1] + 1)

        with jax.named_scope("dynamic"):
            x, n = jax.lax.while_loop(cond, grow, (x, jnp.int32(0)))
        return jnp.sum(x * x), n

    x = jnp.ones((4, d)) * 0.05
    w = jnp.full((d, d), 0.07)
    cfg = ProbeConfig(inline="off_all", buffer_depth=depth,
                      offload=1.0 if offload else 0.0)
    decs = {}
    for layout in ("packed", "legacy"):
        pf = probe(fn, cfg.replace(layout=layout))
        _, rec = pf(x, w)
        decs[layout] = decode_record(rec)
    for key in decs["packed"]:
        assert np.array_equal(np.asarray(decs["packed"][key]),
                              np.asarray(decs["legacy"][key])), key


# ----------------------------- intra-kernel grid-step probing invariants

def _kernel_probe_run(fn, args):
    """Probe with grid-step counters + full offload; returns
    (probed fn, report rows by path, grid node)."""
    from repro.core import probe, ProbeConfig
    pf = probe(fn, ProbeConfig(inline="off_all", kernel_probes=("*",),
                               offload=1.0, buffer_depth=4))
    out, rec = pf(*args)
    rep = pf.report(rec)
    return pf, out, {r.path: r for r in rep.rows}


def _assert_grid_sum_invariant(pf, rows):
    """sum of recorded per-grid-step cycles == grid total == parent
    kernel scope total — for every probed kernel."""
    kernels = [p for p in rows if pf.hierarchy.node(p) is not None
               and pf.hierarchy.node(p).kind == "kernel"]
    assert kernels
    for kpath in kernels:
        grow = rows[kpath + "/grid"]
        durs = [e - s for s, e in grow.iters]
        assert len(durs) == grow.calls
        assert sum(durs) == grow.total_cycles
        assert grow.total_cycles == rows[kpath].total_cycles


@settings(max_examples=6, deadline=None)
@given(bq=st.sampled_from([16, 32, 64]), bk=st.sampled_from([16, 32, 64]),
       pp=st.sampled_from([1, 2]), causal=st.booleans())
def test_flash_grid_step_cycles_sum_to_kernel_scope(bq, bk, pp, causal):
    S = 64
    assume(S % bq == 0 and S % bk == 0 and (S // bk) % pp == 0)
    from repro.kernels import flash_attention as fa
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(bq + bk + pp), 3)
    q = jax.random.normal(k1, (1, 1, S, 16))
    kk = jax.random.normal(k2, (1, 1, S, 16))
    v = jax.random.normal(k3, (1, 1, S, 16))

    def fn(q, k, v):
        return fa.flash_attention(q, k, v, causal=causal, block_q=bq,
                                  block_k=bk, pipeline=pp, interpret=True)

    pf, out, rows = _kernel_probe_run(fn, (q, kk, v))
    _assert_grid_sum_invariant(pf, rows)
    # probed output bit-identical to the unprobed kernel
    assert np.array_equal(np.asarray(out),
                          np.asarray(jax.jit(fn)(q, kk, v)))


@settings(max_examples=6, deadline=None)
@given(chunk=st.sampled_from([16, 32, 64]), pp=st.sampled_from([1, 2, 4]),
       L=st.sampled_from([64, 128]))
def test_ssd_grid_step_cycles_sum_to_kernel_scope(chunk, pp, L):
    assume(L % chunk == 0 and chunk % pp == 0)
    from repro.kernels import ssd_scan as ssdk
    ks = jax.random.split(jax.random.PRNGKey(chunk + pp + L), 4)
    x = jax.random.normal(ks[0], (1, 2, L, 8)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (1, 2, L))) * 0.3
    b = jax.random.normal(ks[2], (1, 1, L, 16)) * 0.5
    c = jax.random.normal(ks[3], (1, 1, L, 16)) * 0.5

    def fn(x, a, b, c):
        return ssdk.ssd_scan(x, a, b, c, chunk=chunk, pipeline=pp,
                             interpret=True)

    pf, out, rows = _kernel_probe_run(fn, (x, a, b, c))
    _assert_grid_sum_invariant(pf, rows)
    assert np.array_equal(np.asarray(out),
                          np.asarray(jax.jit(fn)(x, a, b, c)))


# ------------------------- seeded model-graph conformance (graphgen)

from repro.testing import GraphSpec, run_conformance  # noqa: E402
from repro.testing.graphgen import (BLOCK_KINDS, KERNEL_KINDS,  # noqa: E402
                                    KERNEL_WRAPPERS, WRAPPERS, BlockSpec)

_LOOPED = ("scan", "while", "scan_cond")


@st.composite
def graph_specs(draw, allow_kernels=True):
    """Hypothesis-native GraphSpec strategy: unlike ``random_spec`` (a
    fixed seed->spec map) this shrinks — a failing example minimizes to
    the smallest graph exhibiting the bug. At most one kernel block per
    graph (mirrors the corpus generator's constraint)."""
    n = draw(st.integers(1, 4))
    blocks = []
    kernel_left = allow_kernels
    for _ in range(n):
        if kernel_left and draw(st.integers(0, 4)) == 0:
            kernel_left = False
            blocks.append(BlockSpec(
                kind=draw(st.sampled_from(KERNEL_KINDS)),
                wrapper=draw(st.sampled_from(KERNEL_WRAPPERS))))
            continue
        wrapper = draw(st.sampled_from(WRAPPERS))
        blocks.append(BlockSpec(
            kind=draw(st.sampled_from(BLOCK_KINDS)),
            wrapper=wrapper,
            length=draw(st.integers(2, 3)) if wrapper in _LOOPED else 1))
    return GraphSpec(
        seed=draw(st.integers(0, 2 ** 31 - 1)),
        batch=draw(st.sampled_from([1, 2])),
        seq=draw(st.sampled_from([16, 32])),
        d_model=draw(st.sampled_from([16, 32])),
        blocks=tuple(blocks),
        buffer_depth=draw(st.sampled_from([2, 4])),
        offload=draw(st.sampled_from([0.0, 1.0])),
        max_probes=draw(st.sampled_from([16, 50])),
    )


@settings(max_examples=75, deadline=None)
@given(graph_specs())
def test_graphspec_json_roundtrip_any_spec(spec):
    """Serialization totality: EVERY representable spec (not just
    random_spec's image) survives the JSON round trip intact."""
    assert GraphSpec.from_json(spec.to_json()) == spec
    assert spec.has_kernel == any(b.kind in KERNEL_KINDS
                                  for b in spec.blocks)


# example count comes from the loaded profile (ci=3 / nightly=30); the
# fast invariant subset keeps tier-1 inside its timeout — the fixed
# corpus + nightly sweep cover the expensive re-probe invariants
@settings(deadline=None)
@given(graph_specs())
def test_random_graph_probe_conformance(spec):
    stats = run_conformance(
        spec, ("bit_identity", "telescoping", "oracle_equality"))
    assert stats["n_probes"] > 0
