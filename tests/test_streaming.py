"""Streaming telemetry invariants: the constant-memory aggregates must
agree EXACTLY with one-shot records on the same trace, the session's
state footprint must not grow with step count, and a live session must
never change model outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProbeConfig, ProbeSession, probe
from repro.core.streaming import StreamAggregator, StreamingSink, _buckets_of
from repro.core.buffer import row_durations
from repro.core.counters import int_to_pair
from repro.core.instrument import decode_record


def _workload(x, w):
    def body(c, _):
        with jax.named_scope("layer"):
            with jax.named_scope("mm"):
                c = jnp.tanh(c @ w) + c
        return c, None
    with jax.named_scope("layers"):
        x, _ = jax.lax.scan(body, x, None, length=5)

    def cond(s):
        return jnp.sum(jnp.abs(s[0])) < 1e3

    def grow(s):
        with jax.named_scope("grow"):
            return (s[0] * 1.4 + 0.1, s[1] + 1)
    with jax.named_scope("dynamic"):
        x, n = jax.lax.while_loop(cond, grow, (x, jnp.int32(0)))
    with jax.named_scope("head"):
        return jnp.sum(x * x), n


_ARGS = (jnp.ones((4, 8)) * 0.05, jnp.full((8, 8), 0.07))
_CFG = ProbeConfig(inline="off_all", offload=1.0, buffer_depth=2)


def _one_shot_durations():
    """Per-probe per-call cycle durations from a one-shot probe run
    (full history: HostSink records + ring remainder via the report)."""
    pf = probe(_workload, _CFG)
    _, rec = pf(*_ARGS)
    rep = pf.report(rec)
    return {r.path: np.array([e - s for s, e in r.iters], np.int64)
            for r in rep.rows}


def test_aggregator_matches_one_shot_records():
    """Session aggregates over N identical steps == N x one-shot stats
    (the deterministic model clock makes every step identical)."""
    durs = _one_shot_durations()
    N = 7
    with ProbeSession(_workload, _CFG) as s:
        for _ in range(N):
            s.step(*_ARGS)
        snap = s.snapshot()
    assert set(snap.paths) == set(durs)
    assert any(r.calls for r in snap.rows)
    for r in snap.rows:
        d = durs[r.path]
        assert r.calls == N * len(d), r.path
        assert r.observed == r.calls, r.path          # full coverage
        assert r.total_cycles == N * int(d.sum()), r.path
        if len(d) == 0:                # e.g. a never-entered while-cond
            continue
        assert r.min == int(d.min()), r.path
        assert r.max == int(d.max()), r.path
        assert r.min <= r.p50 <= r.p99 <= r.max, r.path
    # histograms: exactly N copies of the one-shot bucket counts
    merged = s._merged_stats(decode_record(jax.device_get(s._state)))
    for pid, path in enumerate(snap.paths):
        expect = np.zeros_like(merged.hist[pid])
        np.add.at(expect, _buckets_of(durs[path]), N)
        assert np.array_equal(merged.hist[pid], expect), path


def test_constant_memory_across_100_plus_steps():
    """State footprint is flat once the window deque saturates, and no
    raw spill history is ever retained."""
    sizes = {}
    with ProbeSession(_workload, _CFG, window_steps=4, max_windows=4) as s:
        for i in range(1, 121):
            s.step(*_ARGS)
            if i in (40, 80, 120):
                sizes[i] = s.state_nbytes()
        s.sink.flush()
        assert s.sink._rows == {}            # nothing stored, only folded
        assert s.sink.dumps > 0              # ...but spills did happen
    assert sizes[40] == sizes[80] == sizes[120], sizes
    assert len(s._windows) == 4


def test_outputs_bit_identical_under_live_session():
    """Non-intrusiveness holds per step with varying inputs."""
    ref = jax.jit(_workload)
    with ProbeSession(_workload, _CFG) as s:
        for i in range(6):
            x = _ARGS[0] + 0.01 * i
            got = s.step(x, _ARGS[1])
            want = ref(x, _ARGS[1])
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(want)):
                assert np.array_equal(np.asarray(a), np.asarray(b))


def test_session_totals_match_device_counters():
    """sum(per-call durations) must equal the exact device totals —
    the offload path may never lose cycles."""
    with ProbeSession(_workload, _CFG) as s:
        for _ in range(5):
            s.step(*_ARGS)
        snap = s.snapshot()
    for r in snap.rows:
        assert r.observed == r.calls, r.path
        assert r.mean * r.observed == pytest.approx(r.total_cycles), r.path


def test_no_offload_truncates_to_ring_depth():
    cfg = ProbeConfig(inline="off_all", offload=0.0, buffer_depth=2)
    with ProbeSession(_workload, cfg) as s:
        for _ in range(4):
            s.step(*_ARGS)
        snap = s.snapshot()
    active = [r for r in snap.rows if r.calls]
    assert active
    for r in active:
        # duration stats cover only the first buffer_depth calls...
        assert r.observed == min(r.calls, 2), r.path
        # ...but counters stay exact: >=1 call per step for live probes
        assert r.calls >= 4, r.path


def test_stateful_call_accumulates_across_steps():
    from repro.core.instrument import state_totals
    pf = probe(_workload, _CFG)
    _, rec1 = pf(*_ARGS)
    one = state_totals(rec1)
    state = pf.init_state()
    for _ in range(3):
        _, state = pf.stateful_call(state, *_ARGS)
    assert np.array_equal(state_totals(state), 3 * one)


def test_session_reuses_existing_probed_function():
    pf = probe(_workload, _CFG)
    pf(*_ARGS)                                 # already built once
    with ProbeSession(pf) as s:
        out = s.step(*_ARGS)
        snap = s.snapshot()                    # barrier + flush
        assert s.sink.dumps > 0                # streaming sink installed
    assert snap.steps == 1
    ref = jax.jit(_workload)(*_ARGS)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(ref)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_close_restores_original_sink_for_one_shot_use():
    """After a session ends, the wrapped function must profile one-shot
    again with full spill history (not the dead streaming worker)."""
    pf = probe(_workload, _CFG)
    orig_sink = pf.sink
    with ProbeSession(pf) as s:
        s.step(*_ARGS)
    assert pf.sink is orig_sink
    _, rec = pf(*_ARGS)                        # rebuilt on original sink
    jax.block_until_ready(rec)                 # callbacks land with it
    assert pf.sink.dumps > 0
    rep = pf.report(rec)
    hot = rep.row("layers/scan#0/layer")
    assert hot is not None and len(hot.iters) == hot.calls


def test_aggregator_unit_stats():
    agg = StreamAggregator(1, ema_alpha=0.5)
    agg.add(0, np.array([10, 10, 10, 1000]))
    assert agg.count[0] == 4
    assert agg.total[0] == 1030
    assert agg.min[0] == 10 and agg.max[0] == 1000
    # EMA leans toward the most recent (large) sample
    assert agg.ema[0] > 10
    assert agg.quantile(0, 0.5) >= 10
    assert 10 <= agg.quantile(0, 0.99) <= 1000
    before = agg.nbytes
    agg.add(0, np.arange(1, 1000))
    assert agg.nbytes == before                # constant memory


def test_streaming_sink_async_drain_is_lossless():
    sink = StreamingSink()
    sink.bind(2)
    depth = 4
    row = np.zeros((depth, 2, 2), np.uint32)
    for s_ in range(depth):
        row[s_, 0] = int_to_pair(100 * s_)
        row[s_, 1] = int_to_pair(100 * s_ + 7)
    for k in range(50):
        sink.dump(k % 2, np.True_, k * depth, row)
    sink.flush()
    assert sink.dumps == 50
    assert sink.stats.count[0] == 25 * depth
    assert sink.stats.count[1] == 25 * depth
    assert sink.stats.total[0] == 25 * depth * 7
    assert np.array_equal(row_durations(row), np.full(depth, 7))
    sink.close()
    assert sink.records(0) == []               # history is not retained
