"""benchmarks/check_regression.py — the CI benchmark-regression gate."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.check_regression import compare, main, parse_derived  # noqa: E402


def write_art(dirpath, bench, rows):
    os.makedirs(dirpath, exist_ok=True)
    art = {"bench": bench, "title": bench, "seed": 0, "rows": rows,
           "error": None}
    with open(os.path.join(dirpath, f"BENCH_{bench}.json"), "w") as f:
        json.dump(art, f, indent=1, sort_keys=True)


def rows(cycles, measurements=5, us=100.0):
    return [
        {"name": "k/cycles", "us_per_call": us,
         "derived": f"cycles={cycles};config=a=1"},
        {"name": "k/cold", "us_per_call": us,
         "derived": f"measurements={measurements}"},
    ]


def test_parse_derived():
    d = parse_derived("cycles=123;saving=91.2%;speedup_x1000=1197;"
                      "exact=28/28;config=block_q=256;x=1.5x")
    assert d["cycles"] == 123.0
    assert d["saving"] == 91.2
    assert d["speedup_x1000"] == 1197.0
    assert d["exact"] == 28.0
    assert d["x"] == 1.5


def test_gate_passes_within_tolerance(tmp_path):
    write_art(tmp_path / "base", "t", rows(1000))
    write_art(tmp_path / "cur", "t", rows(1100))          # +10% < 15%
    failures, _ = compare(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert failures == []


def test_gate_fails_on_injected_slowdown(tmp_path):
    write_art(tmp_path / "base", "t", rows(1000))
    write_art(tmp_path / "cur", "t", rows(1300))          # +30% > 15%
    failures, _ = compare(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert len(failures) == 1 and "cycles" in failures[0]
    # and through the CLI entry point
    rc = main(["--baseline", str(tmp_path / "base"),
               "--current", str(tmp_path / "cur")])
    assert rc == 1


def test_gate_catches_cache_regression(tmp_path):
    # warm-run measurements growing (cache broken) must fail
    write_art(tmp_path / "base", "t", rows(1000, measurements=0))
    write_art(tmp_path / "cur", "t", rows(1000, measurements=27))
    failures, _ = compare(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert any("measurements" in f for f in failures)


def test_gate_ignores_wall_time_by_default(tmp_path):
    write_art(tmp_path / "base", "t", rows(1000, us=100.0))
    write_art(tmp_path / "cur", "t", rows(1000, us=900.0))   # 9x slower wall
    failures, _ = compare(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert failures == []
    failures, _ = compare(str(tmp_path / "base"), str(tmp_path / "cur"),
                          include_timing=True)
    assert any("us_per_call" in f for f in failures)


def test_gate_fails_on_missing_rows_and_files(tmp_path):
    write_art(tmp_path / "base", "t", rows(1000))
    write_art(tmp_path / "cur", "t", rows(1000)[:1])      # row dropped
    failures, _ = compare(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert any("disappeared" in f for f in failures)
    failures, _ = compare(str(tmp_path / "base"), str(tmp_path / "empty"))
    assert any("missing" in f for f in failures)


def test_gate_allows_new_rows(tmp_path):
    write_art(tmp_path / "base", "t", rows(1000))
    write_art(tmp_path / "cur", "t",
              rows(1000) + [{"name": "k/new", "us_per_call": 0.0,
                             "derived": "cycles=5"}])
    failures, notes = compare(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert failures == [] and any("new row" in n for n in notes)


def test_gate_higher_better_direction(tmp_path):
    base = [{"name": "k/s", "us_per_call": 0.0,
             "derived": "speedup_x1000=1200"}]
    cur = [{"name": "k/s", "us_per_call": 0.0,
            "derived": "speedup_x1000=900"}]              # tuner got worse
    write_art(tmp_path / "base", "t", base)
    write_art(tmp_path / "cur", "t", cur)
    failures, _ = compare(str(tmp_path / "base"), str(tmp_path / "cur"))
    assert len(failures) == 1 and "down" in failures[0]


def test_gate_errors_on_failed_bench(tmp_path):
    os.makedirs(tmp_path / "base", exist_ok=True)
    art = {"bench": "t", "rows": [], "error": "RuntimeError: boom"}
    for d in ("base", "cur"):
        os.makedirs(tmp_path / d, exist_ok=True)
        with open(tmp_path / d / "BENCH_t.json", "w") as f:
            json.dump(art, f)
    with pytest.raises(SystemExit):
        compare(str(tmp_path / "base"), str(tmp_path / "cur"))
