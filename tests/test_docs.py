"""Docs stay executable: the same checker the `docs` CI job runs.

The full snippet execution needs a fresh interpreter (the multi-device
README quickstart forces an 8-device host platform before jax inits),
so it runs as a slow subprocess; the link check and the block
extractor are exercised in-process."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402


def _md_files():
    import glob
    return [os.path.join(REPO, "README.md")] + \
        sorted(glob.glob(os.path.join(REPO, "docs", "*.md")))


def test_no_dead_links():
    errors = []
    for path in _md_files():
        with open(path) as f:
            errors += check_docs.check_links(path, f.read())
    assert not errors, "\n".join(errors)


def test_extractor_blocks_and_skip_marker():
    text = "\n".join([
        "intro",
        "```python", "x = 1", "```",
        "<!-- docs-check: skip -->",
        "```python", "undefined_name", "```",
        "```bash", "echo hi", "```",
    ])
    blocks = check_docs.extract_blocks(text)
    assert [(b[0], b[3]) for b in blocks] == [
        ("python", False), ("python", True), ("bash", False)]
    assert check_docs.run_python("<test>", blocks) == []


def test_extractor_reports_failures():
    blocks = check_docs.extract_blocks(
        "```python\nraise ValueError('boom')\n```")
    errs = check_docs.run_python("<test>", blocks)
    assert len(errs) == 1 and "boom" in errs[0]


def test_readme_documents_streaming_entry_points():
    """The PR-1 API surface must stay documented (drift guard)."""
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    assert "stateful_call" in readme
    assert "decode_record" in readme
    assert "mesh_probe" in readme
    assert "xla_force_host_platform_device_count" in readme


@pytest.mark.slow
def test_docs_snippets_execute():
    """Run the real checker end-to-end in a clean interpreter."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)         # the checker sets its own
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docs.py")],
        env=env, capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, (out.stdout[-2000:] + out.stderr[-3000:])
    assert "all snippets executed" in out.stdout
