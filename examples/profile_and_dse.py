"""Profile a REAL training step with RealProbe, find the bottleneck, and
run the automated DSE over profiling configurations (paper Fig 13).

    PYTHONPATH=src python examples/profile_and_dse.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.configs.registry import smoke_config
from repro.core import ProbeConfig, probe, run_dse
from repro.distributed.steps import build_train_step
from repro.models import Model
from repro.optim import adamw


def main():
    cfg = smoke_config("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params, cfg.moment_dtype)
    batch = {"tokens": jnp.zeros((4, 64), jnp.int32),
             "labels": jnp.ones((4, 64), jnp.int32)}
    step = build_train_step(model, TrainConfig(total_steps=100,
                                               warmup_steps=10))

    # ---- profile the production train step --------------------------
    pf = probe(step, ProbeConfig(max_probes=30))
    (params2, opt2, metrics), record = pf(params, opt, batch)
    report = pf.report(record)
    print(report.table())
    bn = report.bottleneck()
    print(f"\nbottleneck: {bn.path}  ({bn.total_cycles} cycles, "
          f"{100 * bn.total_cycles / report.span:.1f}% of the step)\n")

    # ---- automated DSE over probing configurations -------------------
    res = run_dse(step, (params, opt, batch),
                  ProbeConfig(max_probes=20),
                  storages=("registers", "bram"),
                  offload_ratios=(0.0, 0.5), repeats=1)
    print(res.table())
    best = res.best()
    print(f"\nbest config: storage={best.storage} "
          f"dump={int(best.offload_ratio * 100)}% "
          f"(state {best.state_bytes} B, latency +"
          f"{best.latency_overhead * 100:.1f}%)")


if __name__ == "__main__":
    main()
