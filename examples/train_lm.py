"""End-to-end driver: train a language model with the full production
stack (data pipeline, AdamW+WSD/cosine, checkpointing, probed steps).

Default is a quick CPU-sized run; ``--model-100m`` trains a ~100M-param
tinyllama-family config for a few hundred steps (the deliverable-(b)
configuration — expect hours on one CPU core; it is sized for a real
accelerator).

    PYTHONPATH=src python examples/train_lm.py --steps 100
    PYTHONPATH=src python examples/train_lm.py --model-100m --steps 300
"""
import argparse

from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-100m", action="store_true",
                    help="~100M-param config (12L x 768) instead of smoke")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.model_100m:
        import repro.configs.registry as reg
        base = get_config("tinyllama-1.1b")
        cfg100 = base.replace(name="tinyllama-100m", num_layers=12,
                              d_model=768, num_heads=12, num_kv_heads=4,
                              head_dim=64, d_ff=2048, vocab_size=32000,
                              loss_chunk=128, attn_chunk=128)
        reg.CONFIGS[cfg100.name] = cfg100
        arch, smoke = cfg100.name, False
    else:
        arch, smoke = "tinyllama-1.1b", True

    _, _, hist = train(
        arch, smoke=smoke, steps=args.steps, batch=args.batch, seq=args.seq,
        checkpoint_dir=args.checkpoint_dir, resume=args.resume,
        tcfg=TrainConfig(total_steps=args.steps,
                         warmup_steps=max(args.steps // 20, 1),
                         learning_rate=3e-4,
                         checkpoint_every=max(args.steps // 4, 1),
                         checkpoint_dir=args.checkpoint_dir),
        log_every=max(args.steps // 20, 1))
    print(f"\nfinal loss {hist[-1]:.4f} (start {hist[0]:.4f}); "
          f"checkpoints in {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
