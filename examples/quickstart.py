"""Quickstart: the single-directive profiler on an arbitrary JAX program.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ProbeConfig, probe


# Any JAX program — NO changes needed for profiling (non-intrusive).
def my_model(x, w):
    def layer(c, _):
        with jax.named_scope("layer"):
            with jax.named_scope("attn"):
                c = jnp.tanh(c @ w) @ w.T + c
            with jax.named_scope("mlp"):
                c = jax.nn.silu(c @ w) @ w.T + c
        return c, None

    with jax.named_scope("layers"):
        x, _ = jax.lax.scan(layer, x, None, length=8)

    # data-dependent loop: static estimates CANNOT know its trip count —
    # only in-device measurement can (the paper's core point)
    def cond(s):
        return jnp.sum(jnp.abs(s[0])) < 5e3

    def grow(s):
        with jax.named_scope("grow"):
            return (s[0] * 1.5 + 0.5, s[1] + 1)

    with jax.named_scope("dynamic"):
        x, n_iters = jax.lax.while_loop(cond, grow, (x, jnp.int32(0)))
    return jnp.sum(x * x), n_iters


def main():
    x = jnp.ones((16, 64)) * 0.02
    w = jnp.full((64, 64), 1.0 / 64)

    # 1. the "pragma": one call
    pf = probe(my_model, ProbeConfig(inline="off_all"))

    # 2. run (jitted, instrumented, non-intrusive)
    (out, n_iters), record = pf(x, w)
    print(f"output={float(out):.2f}, while-loop ran {int(n_iters)} times\n")

    # 3. results: per-module cycles, timeline, C-synth-style estimates
    report = pf.report(record)
    print(report.table())
    print()
    print(report.timeline(72))

    # 4. cross-verify against the independent oracle (the "ILA")
    oracle = pf.oracle(x, w)
    i = pf.probe_paths().index("layers/scan#0/layer")
    from repro.core.instrument import decode_record
    device_cycles = int(decode_record(record)["totals"][i])
    print(f"\nlayers/scan#0/layer: device={device_cycles} "
          f"oracle={oracle.totals[i]} -> "
          f"{'100% MATCH' if device_cycles == oracle.totals[i] else 'BUG'}")

    # 5. retarget incrementally (no retrace of the model)
    pf.retarget(ProbeConfig(targets=("dynamic",), inline="off_all"))
    _, record2 = pf(x, w)
    print("\nretargeted to the dynamic subtree:")
    print(pf.report(record2).table())


if __name__ == "__main__":
    main()
