"""Probe-guided kernel autotuning, end to end (paper §IV-E closed loop):

1. tune flash_attention + ssd_scan with the DSE engine (cost-model
   pruning -> successive-halving ProbeSession measurement -> cache),
2. re-run to show the warm cache performs ZERO new measurements,
3. load the winners into the tuned-defaults registry and verify the
   model-facing ops now run the tuned tiling with identical outputs.

    PYTHONPATH=src python examples/tune_kernels.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.core import DSEEngine, EvalCache
from repro.kernels import ops, ref, tuning
from repro.kernels.search_spaces import flash_attention_space, ssd_scan_space


def main():
    cache = EvalCache(tempfile.mkdtemp(prefix="repro_tune_demo_"))
    spaces = [
        flash_attention_space(B=1, H=2, S=256, D=32,
                              blocks_q=(64, 128, 256),
                              blocks_k=(64, 128, 256), pipelines=(1, 2)),
        ssd_scan_space(B=1, H=4, G=2, L=256, P=16, N=32),
    ]
    for space in spaces:
        print(f"=== tuning {space.kernel_id} (cold) ===")
        cold = DSEEngine(space, cache=cache, max_steps=4).tune()
        print(cold.leaderboard(top=6))
        warm = DSEEngine(space, cache=cache, max_steps=4).tune()
        print(f"warm re-run: {warm.n_measurements} measurements, "
              f"{warm.n_cache_hits} cache hits "
              f"(best {warm.best.config}, {warm.speedup:.2f}x vs default)\n")
        assert warm.n_measurements == 0

    # feed the winners back into the model-facing wrappers
    loaded = tuning.load_cache(cache_dir=cache.root)
    print(f"tuned registry now holds: {loaded}")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 32))
    k = jax.random.normal(ks[1], (1, 2, 256, 32))
    v = jax.random.normal(ks[2], (1, 2, 256, 32))
    o_tuned = ops.flash_attention(q, k, v, causal=True)   # tuned tiling
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.abs(o_tuned - o_ref).max())
    print(f"ops.flash_attention under tuned config: max err {err:.2e} "
          "(tiling changed, outputs didn't)")
    tuning.clear_tuned()


if __name__ == "__main__":
    main()
