"""Batched serving: prefill a prompt batch, decode greedily in lock step
(the decode_32k / long_500k dry-run shapes lower exactly this step).

    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-370m
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    help="any of the 10 assigned archs (smoke-sized)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    toks = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                 max_new=args.max_new)
    for i, row in enumerate(toks):
        print(f"seq {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
