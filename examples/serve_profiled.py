"""Continuous in-production profiling of a serving decode loop.

Runs a >=64-step greedy decode twice — plain jitted vs under a live
``ProbeSession`` — and demonstrates the streaming telemetry guarantees:

1. outputs are bit-identical with profiling on vs off (non-intrusive);
2. profiling state size is independent of step count (constant-memory
   aggregation: the session retains running stats + a bounded window
   deque, never per-step history);
3. snapshots are available mid-flight without stopping the loop.

    PYTHONPATH=src python examples/serve_profiled.py --steps 64
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import smoke_config
from repro.core import ProbeConfig, ProbeSession
from repro.distributed.steps import build_decode_step, build_prefill_step
from repro.models.model import Model


def decode_loop(model, params, step_fn, prompt, prompt_len, steps):
    """Greedy decode `steps` tokens; step_fn is jitted-or-session.step."""
    prefill = jax.jit(build_prefill_step(
        model, ShapeConfig("pf", prompt.shape[1], prompt.shape[0],
                           "prefill")))
    logits, cache = prefill(params, {"tokens": prompt})
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    toks = [np.asarray(next_tok)]
    for i in range(steps):
        dbatch = {"tokens": next_tok[:, None],
                  "pos": jnp.int32(prompt_len + i)}
        logits, cache, next_tok = step_fn(params, cache, dbatch)
        toks.append(np.asarray(next_tok))
    return np.stack(toks, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()
    assert args.steps >= 64, "this example demonstrates a >=64-step session"

    cfg = smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)

    # ---- reference: plain jitted decode (no profiling) ----------------
    plain = jax.jit(build_decode_step(model), donate_argnums=())
    ref = decode_loop(model, params, plain, prompt, args.prompt_len,
                      args.steps)

    # ---- same loop under a live streaming session ---------------------
    session = ProbeSession(
        build_decode_step(model),
        ProbeConfig(offload=1.0, max_probes=12),
        window_steps=8, max_windows=4)
    sizes = {}

    def profiled_step(params, cache, dbatch):
        out = session.step(params, cache, dbatch)
        if session.steps in (args.steps // 2, args.steps):
            sizes[session.steps] = session.state_nbytes()
        return out

    out = decode_loop(model, params, profiled_step, prompt,
                      args.prompt_len, args.steps)
    snap = session.close()

    # 1. non-intrusive: bit-identical tokens
    assert np.array_equal(ref, out), "profiling changed model outputs!"
    print(f"outputs bit-identical over {args.steps} decode steps "
          f"(profiling on vs off): OK")

    # 2. constant memory: same footprint mid-session and at the end
    # (window deque is saturated at both sample points)
    lo, hi = sorted(sizes)
    assert sizes[lo] == sizes[hi], sizes
    print(f"profiling state at step {lo}: {sizes[lo]}B == "
          f"step {hi}: {sizes[hi]}B (independent of step count): OK")

    # 3. the telemetry itself
    print(f"\n# streaming snapshot after {snap.steps} steps "
          f"({snap.span} model cycles, {snap.wall_s:.1f}s wall)")
    print(snap.table())
    print("\n# bottleneck ranking across the last windows")
    print(snap.bump_chart())


if __name__ == "__main__":
    main()
