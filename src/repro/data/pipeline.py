"""Deterministic, sharded, checkpointable token pipeline.

Production framing: every host materializes only its own shard of the
global batch (``host_batch = global_batch / num_hosts``), derived purely
from (seed, step, host_index) — so the pipeline is (a) exactly-once
resumable from just the step number stored in the checkpoint, and (b)
elastic: after restarting on a different host count the same global
stream is re-partitioned with no duplicated/skipped samples.

Two sources: ``synthetic`` (self-seeding LCG token stream; used by tests,
examples and benches) and ``memmap`` (fixed-shape binary token file).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None         # for memmap
    num_hosts: int = 1
    host_index: int = 0

    @property
    def host_batch(self) -> int:
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide num_hosts")
        return self.global_batch // self.num_hosts


@dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d) -> "PipelineState":
        return cls(step=int(d["step"]))


class TokenPipeline:
    def __init__(self, cfg: DataConfig, state: Optional[PipelineState] = None):
        self.cfg = cfg
        self.state = state or PipelineState()
        self._mm = None
        if cfg.source == "memmap":
            if not cfg.path:
                raise ValueError("memmap source needs a path")
            self._mm = np.memmap(cfg.path, dtype=np.int32, mode="r")

    # -- deterministic sample addressing --------------------------------
    def _sample_tokens(self, global_sample_idx: int) -> np.ndarray:
        cfg = self.cfg
        n = cfg.seq_len + 1
        if self._mm is not None:
            total = (len(self._mm) - 1) // cfg.seq_len
            row = global_sample_idx % max(total, 1)
            start = row * cfg.seq_len
            return np.asarray(self._mm[start:start + n], dtype=np.int32)
        # synthetic: SplitMix64-hashed Markov stream — fully
        # index-addressable AND learnable (90% of transitions follow a
        # fixed affine next-token map, 10% are hash-random), so training
        # tests can assert the loss actually drops below ln(V).
        # (uint64 wraparound is intended — silence numpy warnings)
        idx = np.uint64((global_sample_idx * 1_000_003 +
                         cfg.seed * 7_777_777) % (1 << 64))
        out = np.empty(n, dtype=np.int32)
        x = idx
        old = np.seterr(over="ignore")
        V = cfg.vocab_size

        def nxt(x):
            x = (x + np.uint64(0x9E3779B97F4A7C15)) \
                & np.uint64(0xFFFFFFFFFFFFFFFF)
            z = x
            z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
                & np.uint64(0xFFFFFFFFFFFFFFFF)
            z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
                & np.uint64(0xFFFFFFFFFFFFFFFF)
            return x, z ^ (z >> np.uint64(31))

        x, z = nxt(x)
        out[0] = int(z % np.uint64(V))
        for i in range(1, n):
            x, z = nxt(x)
            if int(z % np.uint64(10)):            # 90%: learnable map
                out[i] = (out[i - 1] * 5 + 17) % V
            else:                                 # 10%: hash-random
                out[i] = int((z >> np.uint64(8)) % np.uint64(V))
        np.seterr(**old)
        return out

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The host-local batch for a given global step (pure function)."""
        cfg = self.cfg
        hb = cfg.host_batch
        base = step * cfg.global_batch + cfg.host_index * hb
        toks = np.stack([self._sample_tokens(base + i) for i in range(hb)])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.state.step)
        self.state.step += 1
        return b

    # -- elasticity -------------------------------------------------------
    def reshard(self, num_hosts: int, host_index: int) -> "TokenPipeline":
        """Same global stream, different host partitioning (restart after
        node loss / scale-up). Continues from the same global step."""
        cfg = dataclasses.replace(self.cfg, num_hosts=num_hosts,
                                  host_index=host_index)
        return TokenPipeline(cfg, PipelineState(step=self.state.step))
