"""Online drift sentinel: the CI regression gate, moved into serving.

``benchmarks/check_regression.py`` catches a cycle regression only
after the fact, in CI, against a committed baseline.  Production wants
the same judgement *online*: watch the live probe streams and flag the
moment a probe's cycle distribution shifts, a p99 regresses, or one
device of a mesh starts straggling.  The sentinel subscribes to the
:class:`~repro.telemetry.bus.TelemetryBus` window topic and applies
three rules to every closed window, per (stream, probe) row:

- **hist-drift** — total-variation distance between the window's
  normalized log₂-bucket histogram and the reference histogram exceeds
  ``hist_threshold``.  Catches shape changes the scalar rules miss.
- **p99-regression** — the window's histogram-estimated p99 exceeds
  ``p99_ratio ×`` the reference p99.
- **straggler** — (device-major streams only) one device's window
  cycle total exceeds ``skew_ratio ×`` the across-device median.
  Names the straggling device.

Detection discipline (what makes it testable):

- **Warmup gate.** The first ``warmup_windows`` windows of a row form
  its frozen reference; no judgement is made until the reference is
  complete, and windows with fewer than ``min_samples`` observations
  are never judged (nor folded into a partial reference verdict).
- **Hysteresis.** A rule must breach on ``trip_windows`` *consecutive*
  windows before an event fires — a single noisy window never alerts.
- **Rebaseline on fire.** Firing emits one structured
  :class:`DriftEvent` (published on the bus's ``alert`` topic), then
  resets the row: the post-drift regime becomes the next reference, so
  a persistent step change alerts exactly once and a continuing ramp
  alerts repeatedly — both asserted by the fault-injection harness in
  ``tests/test_telemetry.py``.

A ``retune`` hook (see :func:`make_retune_hook`) receives every fired
event; wiring it to :class:`~repro.core.dse.DSEEngine` re-tunes a
kernel in the background when its workload shifts (docs/telemetry.md).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.telemetry.bus import TelemetryBus, WindowFrame, hist_quantile

KINDS = ("hist-drift", "p99-regression", "straggler")


@dataclass(frozen=True)
class SentinelConfig:
    """Detection knobs (defaults sized for ≥32-sample windows)."""
    warmup_windows: int = 4       # windows forming the frozen reference
    min_samples: int = 8          # ignore windows with fewer samples
    hist_threshold: float = 0.35  # total-variation distance trip point
    p99_ratio: float = 1.8        # window p99 / reference p99 trip point
    skew_ratio: float = 2.0       # device total / median trip point
    trip_windows: int = 2         # consecutive breaches before firing


@dataclass(frozen=True)
class DriftEvent:
    """One fired detection, named down to the probe (and device)."""
    kind: str                     # one of KINDS
    stream: str
    path: str                     # probe path inside the stream
    device: Optional[int]         # straggler device (None off-mesh)
    window: int                   # frame index that tripped the rule
    severity: float               # rule statistic (tv / ratio)
    threshold: float              # the trip point it exceeded
    detail: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return {"kind": self.kind, "stream": self.stream,
                "path": self.path, "device": self.device,
                "window": self.window,
                "severity": round(float(self.severity), 4),
                "threshold": float(self.threshold),
                "detail": self.detail}


@dataclass
class _RowState:
    """Per (stream, row) detector state — constant size."""
    windows_seen: int = 0
    ref_hist: np.ndarray = None       # accumulated warmup histogram
    ref_count: int = 0
    breaches: Dict[str, int] = field(
        default_factory=lambda: {k: 0 for k in KINDS})


class DriftSentinel:
    """Sliding-window drift detection over bus streams (see module
    docstring).  Attach with ``DriftSentinel(bus)``; every fired event
    lands on the bus's alert ring (``/alerts``) and in ``self.events``.
    """

    def __init__(self, bus: TelemetryBus,
                 config: SentinelConfig = SentinelConfig(), *,
                 retune: Optional[Callable[[DriftEvent], None]] = None):
        self.bus = bus
        self.cfg = config
        self.retune = retune
        self.events: List[DriftEvent] = []
        self._rows: Dict[Tuple[str, int], _RowState] = {}
        self._lock = threading.Lock()
        bus.subscribe("window", self.observe)

    def close(self):
        self.bus.unsubscribe("window", self.observe)

    # -- state views -----------------------------------------------------
    def row_state(self, stream: str, row: int) -> _RowState:
        key = (stream, row)
        st = self._rows.get(key)
        if st is None:
            st = self._rows[key] = _RowState()
        return st

    def tripped(self) -> List[DriftEvent]:
        with self._lock:
            return list(self.events)

    # -- detection -------------------------------------------------------
    def observe(self, frame: WindowFrame):
        """Judge one closed window (the bus window-topic callback)."""
        with self._lock:
            fired = list(self._judge(frame))
        for ev in fired:
            self.bus.publish_alert(ev)
            if self.retune is not None:
                self.retune(ev)

    def _judge(self, frame: WindowFrame):
        cfg = self.cfg
        dev_totals = frame.per_device()              # (D, n)
        for row in range(len(frame.counts)):
            d, p = divmod(row, frame.n_probes)
            st = self.row_state(frame.stream, row)
            n = int(frame.counts[row])
            if n < cfg.min_samples:
                continue                             # never judged
            if st.windows_seen < cfg.warmup_windows:
                # frozen reference under construction
                if st.ref_hist is None:
                    st.ref_hist = np.zeros_like(frame.hist[row])
                st.ref_hist = st.ref_hist + frame.hist[row]
                st.ref_count += n
                st.windows_seen += 1
                continue
            st.windows_seen += 1
            # straggler first on mesh streams: a single-device shift
            # trips both it and hist-drift, and the straggler verdict
            # is the actionable one (it names the device).  A global
            # shift moves the median too, so it never trips straggler.
            ev = None
            if frame.n_devices > 1:
                ev = self._rule_straggler(frame, row, st,
                                          dev_totals, d, p)
            ev = (ev or self._rule_hist(frame, row, st)
                  or self._rule_p99(frame, row, st))
            if ev is not None:
                self._reset(st)
                yield ev

    def _fire(self, st: _RowState, kind: str, frame: WindowFrame,
              row: int, severity: float, threshold: float,
              detail: str, device: Optional[int] = None
              ) -> Optional[DriftEvent]:
        """Hysteresis: breach must persist ``trip_windows`` windows."""
        st.breaches[kind] += 1
        if st.breaches[kind] < self.cfg.trip_windows:
            return None
        d, p = divmod(row, frame.n_probes)
        if device is None and frame.n_devices > 1:
            device = d                 # device-major row names its device
        ev = DriftEvent(kind=kind, stream=frame.stream,
                        path=frame.paths[p], device=device,
                        window=frame.index, severity=severity,
                        threshold=threshold, detail=detail)
        self.events.append(ev)
        return ev

    def _reset(self, st: _RowState):
        """Rebaseline after firing: the new regime becomes the next
        reference (fresh warmup), counters cleared."""
        st.windows_seen = 0
        st.ref_hist = None
        st.ref_count = 0
        st.breaches = {k: 0 for k in KINDS}

    def _rule_hist(self, frame: WindowFrame, row: int,
                   st: _RowState) -> Optional[DriftEvent]:
        ref = st.ref_hist / max(st.ref_count, 1)
        cur = frame.hist[row] / max(int(frame.counts[row]), 1)
        tv = 0.5 * float(np.abs(ref - cur).sum())
        if tv <= self.cfg.hist_threshold:
            st.breaches["hist-drift"] = 0
            return None
        return self._fire(st, "hist-drift", frame, row, tv,
                          self.cfg.hist_threshold,
                          f"tv={tv:.3f} over {int(frame.counts[row])} "
                          f"samples")

    def _rule_p99(self, frame: WindowFrame, row: int,
                  st: _RowState) -> Optional[DriftEvent]:
        ref_p99 = hist_quantile(st.ref_hist, 0.99, count=st.ref_count)
        cur_p99 = frame.p99(row)
        ratio = cur_p99 / max(ref_p99, 1)
        if ratio <= self.cfg.p99_ratio:
            st.breaches["p99-regression"] = 0
            return None
        return self._fire(st, "p99-regression", frame, row, ratio,
                          self.cfg.p99_ratio,
                          f"p99 {ref_p99} -> {cur_p99} cycles")

    def _rule_straggler(self, frame: WindowFrame, row: int,
                        st: _RowState, dev_totals: np.ndarray,
                        device: int, probe: int) -> Optional[DriftEvent]:
        col = dev_totals[:, probe]
        med = float(np.median(col))
        mine = float(dev_totals[device, probe])
        ratio = mine / max(med, 1.0)
        if med <= 0 or ratio <= self.cfg.skew_ratio \
                or int(np.argmax(col)) != device:
            st.breaches["straggler"] = 0
            return None
        return self._fire(st, "straggler", frame, row, ratio,
                          self.cfg.skew_ratio,
                          f"device {device} at {int(mine)} cycles vs "
                          f"median {int(med)}", device=device)


def make_retune_hook(tune: Callable[[DriftEvent], Any], *,
                     background: bool = True) -> Callable[[DriftEvent], None]:
    """Wrap a tuning callable as a sentinel ``retune`` hook.

    At most one re-tune runs at a time: events arriving while a tune is
    in flight are coalesced into ``hook.skipped`` (a drifting kernel
    fires repeatedly; re-tuning once covers the batch).  With
    ``background=True`` the tune runs on a daemon thread so detection
    never blocks on a :class:`~repro.core.dse.DSEEngine` sweep; tests
    use ``background=False`` for determinism.
    """
    lock = threading.Lock()

    def hook(event: DriftEvent):
        if not lock.acquire(blocking=False):
            hook.skipped += 1
            return
        def run():
            try:
                hook.last_result = tune(event)
                hook.fired += 1
            finally:
                lock.release()
        if background:
            threading.Thread(target=run, daemon=True).start()
        else:
            run()

    hook.fired = 0
    hook.skipped = 0
    hook.last_result = None
    return hook
