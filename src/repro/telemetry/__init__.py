"""Telemetry control plane: live export of the in-process probe data.

``bus`` is the pub/sub hub every session/engine publishes decode-side
aggregates to; ``server`` exposes it over HTTP (``/status``,
``/probes``, ``/mesh/skew``, ``/engine/phases``, ``/alerts``,
``/metrics``); ``sentinel`` watches the window stream for online drift
(p99 regressions, histogram shifts, straggler devices) and can trigger
a background DSE re-tune.  See docs/telemetry.md.
"""
from repro.telemetry.bus import (ProbeStream, TelemetryBus, WindowFrame,
                                 hist_quantile)
from repro.telemetry.sentinel import (DriftEvent, DriftSentinel,
                                      SentinelConfig, make_retune_hook)
from repro.telemetry.server import (ControlPlane, StatusServer,
                                    render_metrics)

__all__ = [
    "TelemetryBus", "ProbeStream", "WindowFrame", "hist_quantile",
    "DriftSentinel", "DriftEvent", "SentinelConfig", "make_retune_hook",
    "ControlPlane", "StatusServer", "render_metrics",
]
