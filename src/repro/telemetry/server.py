"""Dependency-free threaded HTTP status server over a TelemetryBus.

The paper's workflow renders probe data *after* the run; a serving
process needs the same visibility *during* it.  This module exposes a
live :class:`~repro.telemetry.bus.TelemetryBus` over plain stdlib
``http.server`` (no new dependencies, usable from ``curl`` or any
dashboard):

================  =====================================================
endpoint          content
================  =====================================================
``/status``       bounded summary: streams, engine totals, alert count
``/probes``       per-probe aggregates per stream (calls, total, mean,
                  ema, min, p50, p99, max) — exactly the in-process
                  ``StreamAggregator`` values
``/mesh/skew``    device-major streams: per-probe skew, per-device
                  totals, worst (device, probe) cell
``/engine/phases``  per-phase step/cycle bills + recent request bills
``/alerts``       the sentinel's fired ``DriftEvent`` ring
``/metrics``      Prometheus-style text exposition of the same numbers
================  =====================================================

JSON responses are key-sorted and schema-stable (documented in
docs/telemetry.md; asserted in tests/test_telemetry.py).  The server
always binds ``port=0`` by default and reports the real port back via
``server.port`` / ``server.url`` — tests never hard-code ports.

Serving is read-only and touches only host-side aggregates, so a
session keeps its decoded records bit-identical with the server
attached (the same non-intrusiveness invariant as test_streaming.py).
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.telemetry.bus import TelemetryBus

JSON_KW = dict(sort_keys=True, separators=(",", ":"))


def render_json(obj: Any) -> bytes:
    """Canonical key-sorted JSON encoding (the schema-stability tests
    compare served bytes against exactly this)."""
    return (json.dumps(obj, **JSON_KW) + "\n").encode()


def _probes_doc(bus: TelemetryBus) -> Dict[str, Any]:
    return {name: st.rows() for name, st in bus.streams().items()}


def _skew_doc(bus: TelemetryBus) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for name, st in bus.streams().items():
        if st.n_devices <= 1:
            continue
        totals = st.agg.total.reshape(st.n_devices, len(st.paths))
        skew = st.skew()
        worst = {"device": None, "path": None}
        if totals.size and totals.any():
            d, p = np.unravel_index(int(totals.argmax()), totals.shape)
            worst = {"device": int(d), "path": st.paths[int(p)]}
        out[name] = {
            "n_devices": st.n_devices,
            "paths": list(st.paths),
            "skew": [int(s) for s in skew],
            "per_device_totals": totals.tolist(),
            "worst": worst,
        }
    return out


def _engine_doc(bus: TelemetryBus) -> Dict[str, Any]:
    with bus._lock:
        return {
            "phases": {p: dict(v) for p, v in bus.engine.phases.items()},
            "buckets": {str(k): v for k, v in bus.engine.buckets.items()},
            "requests_done": bus.engine.requests_done,
            "recent_requests": list(bus.engine.recent),
        }


def _alerts_doc(bus: TelemetryBus) -> Dict[str, Any]:
    events = [e.to_dict() if hasattr(e, "to_dict") else dict(e)
              for e in bus.alerts()]
    return {"total": bus.alerts_total, "events": events}


def _esc(v: Any) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"')


def render_metrics(bus: TelemetryBus) -> str:
    """Prometheus text exposition (counters/gauges, no dependencies)."""
    lines = []

    def metric(name: str, help_: str, kind: str, rows):
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {kind}")
        for labels, value in rows:
            lab = ",".join(f'{k}="{_esc(v)}"'
                           for k, v in sorted(labels.items()))
            lines.append(f"{name}{{{lab}}} {value}" if lab
                         else f"{name} {value}")

    calls, totals, p99s = [], [], []
    for name, st in sorted(bus.streams().items()):
        snap = st.agg.copy()
        for row in range(snap.n):
            d, p = divmod(row, len(st.paths))
            labels = {"stream": name, "path": st.paths[p]}
            if st.n_devices > 1:
                labels["device"] = d
            calls.append((labels, int(snap.count[row])))
            totals.append((labels, int(snap.total[row])))
            p99s.append((labels, snap.quantile(row, 0.99)))
    metric("repro_probe_calls_total",
           "observed calls per probe", "counter", calls)
    metric("repro_probe_cycles_total",
           "total observed cycles per probe", "counter", totals)
    metric("repro_probe_p99_cycles",
           "histogram-estimated p99 cycles per call", "gauge", p99s)
    eng = _engine_doc(bus)
    metric("repro_engine_phase_cycles_total",
           "engine cycles per phase", "counter",
           [({"phase": p}, v["cycles"])
            for p, v in sorted(eng["phases"].items())])
    metric("repro_engine_phase_steps_total",
           "engine steps per phase", "counter",
           [({"phase": p}, v["steps"])
            for p, v in sorted(eng["phases"].items())])
    metric("repro_engine_requests_total",
           "finished engine requests", "counter",
           [({}, eng["requests_done"])])
    metric("repro_alerts_total",
           "drift events fired by the sentinel", "counter",
           [({}, bus.alerts_total)])
    return "\n".join(lines) + "\n"


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-telemetry/1"

    def do_GET(self):  # noqa: N802  (http.server naming)
        bus: TelemetryBus = self.server.bus          # type: ignore
        path = self.path.split("?", 1)[0].rstrip("/") or "/status"
        routes: Dict[str, Callable[[], Any]] = {
            "/status": bus.status,
            "/probes": lambda: _probes_doc(bus),
            "/mesh/skew": lambda: _skew_doc(bus),
            "/engine/phases": lambda: _engine_doc(bus),
            "/alerts": lambda: _alerts_doc(bus),
        }
        try:
            if path == "/metrics":
                body = render_metrics(bus).encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
                code = 200
            elif path in routes:
                body, ctype, code = (render_json(routes[path]()),
                                     "application/json", 200)
            else:
                body, ctype, code = (
                    render_json({"error": f"unknown endpoint {path!r}",
                                 "endpoints": sorted(routes) + ["/metrics"]}),
                    "application/json", 404)
        except Exception as e:       # never kill the serving thread
            body, ctype, code = (render_json({"error": repr(e)}),
                                 "application/json", 500)
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, fmt, *args):
        pass                         # keep serving loops quiet


class StatusServer:
    """Threaded HTTP server over a bus.

    ::

        bus = TelemetryBus()
        srv = StatusServer(bus).start()     # binds 127.0.0.1, port 0
        print(srv.url)                      # real port read back
        ...
        srv.stop()

    ``port=0`` (the default, and the only mode the test suite uses)
    lets the OS pick a free port — no hard-coded ports anywhere.
    """

    def __init__(self, bus: TelemetryBus, host: str = "127.0.0.1",
                 port: int = 0):
        self.bus = bus
        self.host = host
        self._requested_port = int(port)
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "StatusServer":
        if self._httpd is not None:
            return self
        httpd = ThreadingHTTPServer((self.host, self._requested_port),
                                    _Handler)
        httpd.daemon_threads = True
        httpd.bus = self.bus                         # type: ignore
        self._httpd = httpd
        self._thread = threading.Thread(target=httpd.serve_forever,
                                        name="repro-status-server",
                                        daemon=True)
        self._thread.start()
        return self

    @property
    def port(self) -> int:
        if self._httpd is None:
            raise RuntimeError("server not started")
        return int(self._httpd.server_address[1])

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self):
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
            if self._thread is not None:
                self._thread.join(timeout=5.0)
                self._thread = None

    def __enter__(self) -> "StatusServer":
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False


class ControlPlane:
    """Launcher bundle: bus + drift sentinel + status server.

    ``serve.py --status-port`` and ``train.py --status-port`` both need
    the same three objects wired the same way; this keeps them
    symmetric.  ``finish()`` prints the sentinel's alert table (if
    anything fired) and stops the server.
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1", *,
                 retune=None):
        from repro.telemetry.sentinel import DriftSentinel
        self.bus = TelemetryBus()
        self.sentinel = DriftSentinel(self.bus, retune=retune)
        self.server = StatusServer(self.bus, host=host, port=port)

    def start(self) -> "ControlPlane":
        self.server.start()
        print(f"[telemetry] status server on {self.server.url} "
              f"(/status /probes /mesh/skew /engine/phases /alerts "
              f"/metrics)", flush=True)
        return self

    def finish(self):
        events = self.sentinel.tripped()
        if events:
            from repro.core.report import telemetry_alert_table
            print("\n# sentinel drift events")
            print(telemetry_alert_table(events))
        self.server.stop()
