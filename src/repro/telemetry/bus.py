"""Telemetry bus: the one host-side aggregation point for live probes.

Before this module, three separate code paths each folded decoded cycle
rows into their own private statistics: ``ProbeSession`` (its
``StreamingSink`` worker), ``MeshProbeSession`` (window deltas into a
device-major aggregator), and ``InferenceEngine`` (per-phase /
per-request cycle bills).  None of that state could be observed from
outside the process.  The bus factors the aggregation out into one
pub/sub abstraction all three publish to:

- **streams** — named per-probe duration statistics.  A publisher
  registers a :class:`ProbeStream` (``bus.stream(name, paths)``) and
  feeds it per-call cycle durations; the stream owns a
  :class:`~repro.core.streaming.StreamAggregator`, so the served
  aggregates are *exactly* the in-process values (asserted by
  hypothesis tests).  Device-major streams (``n_devices > 1``) carry
  one row per (device, probe) — the mesh skew substrate.
- **windows** — publishers close sliding windows (``stream.roll()``);
  the bus emits a :class:`WindowFrame` holding the window's exact
  count/total/histogram deltas to every ``"window"`` subscriber.  The
  :class:`~repro.telemetry.sentinel.DriftSentinel` is such a
  subscriber.
- **engine topics** — per-phase step/cycle totals and bounded
  per-request bills (``publish_phase`` / ``publish_request``).
- **alerts** — structured :class:`~repro.telemetry.sentinel.DriftEvent`
  records (``publish_alert``), kept in a bounded ring and surfaced on
  the status server's ``/alerts`` endpoint.

Publishing is decode-side only: calls happen on the streaming sink's
worker thread, at window boundaries, and around engine phase steps —
never inside the jitted step — so the device hot path is untouched and
the host cost is a lock + a handful of numpy folds per ring row
(gated as ``bus_ns_per_row`` in ``benchmarks/bench_telemetry.py``).
Everything is thread-safe; every retained structure is bounded, so a
bus attached to a months-long serving process stays constant-size.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.streaming import HIST_BUCKETS, StreamAggregator, _bucket_rep

# topics a subscriber may attach to
TOPICS = ("window", "alert", "phase", "request")


def hist_quantile(hist: np.ndarray, q: float,
                  count: Optional[int] = None) -> int:
    """q-quantile (bucket-midpoint estimate) of a log₂-bucket histogram
    — the same estimator as ``StreamAggregator.quantile``, usable on a
    raw window-delta histogram."""
    h = np.asarray(hist, np.int64)
    n = int(h.sum()) if count is None else int(count)
    if n <= 0:
        return 0
    target = max(1, int(np.ceil(q * n)))
    b = int(np.searchsorted(np.cumsum(h), target))
    return _bucket_rep(min(b, HIST_BUCKETS - 1))


@dataclass(frozen=True)
class WindowFrame:
    """One closed sliding window of a stream: exact deltas since the
    previous roll.  Arrays are device-major ``(n_devices * n_probes,)``
    rows — row ``d * n_probes + p`` is probe ``p`` on device ``d``
    (single-device streams simply have ``n_devices == 1``)."""
    stream: str
    index: int                      # 0-based window ordinal
    start_step: int
    end_step: int
    paths: Tuple[str, ...]
    n_devices: int
    counts: np.ndarray              # (D*n,) samples folded in the window
    totals: np.ndarray              # (D*n,) cycle total delta
    hist: np.ndarray                # (D*n, HIST_BUCKETS) histogram delta
    exact_totals: Optional[np.ndarray] = None   # device-counter delta

    @property
    def n_probes(self) -> int:
        return len(self.paths)

    def per_device(self, arr: Optional[np.ndarray] = None) -> np.ndarray:
        """View a row-major array as ``(n_devices, n_probes)``."""
        a = self.totals if arr is None else arr
        return np.asarray(a).reshape(self.n_devices, self.n_probes)

    def p99(self, row: int, q: float = 0.99) -> int:
        return hist_quantile(self.hist[row], q, count=int(self.counts[row]))


class ProbeStream:
    """Named per-probe duration statistics + sliding-window rolls.

    ``add(pid, durations)`` folds per-call cycle durations into the
    stream's :class:`StreamAggregator` — the identical code path the
    sessions used before the refactor, so served aggregates stay
    bit-equal to in-process ones.  ``roll()`` closes the current window
    and hands its exact deltas to the bus's window subscribers.
    """

    def __init__(self, name: str, paths: Sequence[str], *,
                 n_devices: int = 1, ema_alpha: float = 0.1,
                 on_window: Optional[Callable[[WindowFrame], None]] = None):
        self.name = name
        self.paths = tuple(paths)
        self.n_devices = int(n_devices)
        self.agg = StreamAggregator(self.n_devices * len(self.paths),
                                    ema_alpha=ema_alpha)
        self._on_window = on_window
        self.rows_published = 0
        self.windows = 0
        self._lock = threading.Lock()
        n = self.agg.n
        self._mark_count = np.zeros(n, np.int64)
        self._mark_total = np.zeros(n, np.int64)
        self._mark_hist = np.zeros((n, HIST_BUCKETS), np.int64)

    @property
    def n_rows(self) -> int:
        return self.agg.n

    def add(self, pid: int, durations: np.ndarray):
        """Fold per-call cycle durations for row ``pid`` (device-major
        index for mesh streams)."""
        self.agg.add(pid, durations)
        with self._lock:
            self.rows_published += 1

    def roll(self, start_step: int = 0, end_step: int = 0,
             exact_totals: Optional[np.ndarray] = None) -> WindowFrame:
        """Close the current window: emit the exact aggregate deltas
        since the previous roll to the bus's window subscribers."""
        snap = self.agg.copy()
        with self._lock:
            frame = WindowFrame(
                stream=self.name, index=self.windows,
                start_step=int(start_step), end_step=int(end_step),
                paths=self.paths, n_devices=self.n_devices,
                counts=snap.count - self._mark_count,
                totals=snap.total - self._mark_total,
                hist=snap.hist - self._mark_hist,
                exact_totals=None if exact_totals is None
                else np.asarray(exact_totals, np.int64).reshape(-1))
            self._mark_count = snap.count
            self._mark_total = snap.total
            self._mark_hist = snap.hist
            self.windows += 1
        if self._on_window is not None:
            self._on_window(frame)
        return frame

    def rows(self) -> List[Dict[str, Any]]:
        """Per-row served aggregates — exactly the ``StreamAggregator``
        values (ints exact; floats survive JSON round-trips bit-exact)."""
        snap = self.agg.copy()
        out = []
        for row in range(snap.n):
            d, p = divmod(row, len(self.paths))
            cnt = int(snap.count[row])
            out.append({
                "path": self.paths[p],
                "device": d,
                "calls": cnt,
                "total_cycles": int(snap.total[row]),
                "mean": float(snap.total[row]) / cnt if cnt else 0.0,
                "ema": float(snap.ema[row]),
                "min": int(snap.min[row]) if cnt else 0,
                "p50": snap.quantile(row, 0.50),
                "p99": snap.quantile(row, 0.99),
                "max": int(snap.max[row]),
            })
        return out

    def skew(self) -> np.ndarray:
        """Per-probe max−min of total cycles across devices."""
        return self.agg.skew(self.n_devices)


@dataclass
class _EngineStats:
    phases: Dict[str, Dict[str, int]] = field(default_factory=dict)
    buckets: Dict[int, int] = field(default_factory=dict)
    requests_done: int = 0
    recent: deque = field(default_factory=lambda: deque(maxlen=64))


class TelemetryBus:
    """The process-wide pub/sub hub (see module docstring).

    Constructing one is cheap; pass the same instance to every session,
    engine, sentinel, and the status server.  All methods are
    thread-safe.
    """

    def __init__(self, *, max_alerts: int = 256, max_requests: int = 64):
        self._lock = threading.RLock()
        self._streams: Dict[str, ProbeStream] = {}
        self._subs: Dict[str, List[Callable]] = {t: [] for t in TOPICS}
        self._alerts: deque = deque(maxlen=max_alerts)
        self.alerts_total = 0
        self.engine = _EngineStats()
        self.engine.recent = deque(maxlen=max_requests)
        self._t0 = time.time()

    # -- streams ---------------------------------------------------------
    def stream(self, name: str, paths: Optional[Sequence[str]] = None, *,
               n_devices: int = 1, ema_alpha: float = 0.1) -> ProbeStream:
        """Get or create the named stream.  Re-registering with a
        different shape (new probe set after a retarget) replaces it."""
        with self._lock:
            st = self._streams.get(name)
            if st is not None and (paths is None or
                                   (st.paths == tuple(paths) and
                                    st.n_devices == int(n_devices))):
                return st
            if paths is None:
                raise KeyError(f"unknown stream {name!r} "
                               f"(known: {sorted(self._streams)})")
            st = ProbeStream(name, paths, n_devices=n_devices,
                             ema_alpha=ema_alpha,
                             on_window=self._emit_window)
            self._streams[name] = st
            return st

    def streams(self) -> Dict[str, ProbeStream]:
        with self._lock:
            return dict(self._streams)

    def publish(self, name: str, pid: int, durations: np.ndarray):
        """Fold durations into an existing stream (see
        :meth:`ProbeStream.add`)."""
        self.stream(name).add(pid, durations)

    def _emit_window(self, frame: WindowFrame):
        for fn in self._snapshot_subs("window"):
            fn(frame)

    # -- engine topics ---------------------------------------------------
    def publish_phase(self, phase: str, *, cycles: int = 0, steps: int = 1,
                      batch: Optional[int] = None):
        """Accumulate one engine phase step (prefill/cache/decode)."""
        with self._lock:
            st = self.engine.phases.setdefault(phase,
                                               {"steps": 0, "cycles": 0})
            st["steps"] += int(steps)
            st["cycles"] += int(cycles)
            if batch is not None:
                b = int(batch)
                self.engine.buckets[b] = self.engine.buckets.get(b, 0) + 1
        for fn in self._snapshot_subs("phase"):
            fn(phase, cycles, steps)

    def publish_request(self, info: Dict[str, Any]):
        """Record one finished request's phase bill (bounded history)."""
        with self._lock:
            self.engine.requests_done += 1
            self.engine.recent.append(dict(info))
        for fn in self._snapshot_subs("request"):
            fn(info)

    # -- alerts ----------------------------------------------------------
    def publish_alert(self, event: Any):
        with self._lock:
            self.alerts_total += 1
            self._alerts.append(event)
        for fn in self._snapshot_subs("alert"):
            fn(event)

    def alerts(self) -> List[Any]:
        with self._lock:
            return list(self._alerts)

    # -- subscriptions ---------------------------------------------------
    def subscribe(self, topic: str, fn: Callable) -> Callable:
        """Attach ``fn`` to a topic (``window``/``alert``/``phase``/
        ``request``); returns ``fn`` for symmetry with unsubscribe."""
        if topic not in self._subs:
            raise ValueError(f"unknown topic {topic!r}; "
                             f"expected one of {TOPICS}")
        with self._lock:
            self._subs[topic].append(fn)
        return fn

    def unsubscribe(self, topic: str, fn: Callable):
        with self._lock:
            if fn in self._subs.get(topic, ()):
                self._subs[topic].remove(fn)

    def _snapshot_subs(self, topic: str) -> List[Callable]:
        with self._lock:
            return list(self._subs[topic])

    # -- views -----------------------------------------------------------
    def status(self) -> Dict[str, Any]:
        """The ``/status`` document: bounded summary of everything the
        bus has seen (full per-probe rows live on ``/probes``)."""
        with self._lock:
            streams = dict(self._streams)
            phases = {p: dict(v) for p, v in self.engine.phases.items()}
            requests_done = self.engine.requests_done
            alerts_total = self.alerts_total
        return {
            "schema": 1,
            "uptime_s": round(time.time() - self._t0, 3),
            "streams": {
                name: {
                    "n_probes": len(st.paths),
                    "n_devices": st.n_devices,
                    "rows_published": st.rows_published,
                    "windows": st.windows,
                    "samples": int(st.agg.count.sum()),
                    "total_cycles": int(st.agg.total.sum()),
                } for name, st in streams.items()},
            "engine": {"phases": phases, "requests": requests_done},
            "alerts": alerts_total,
        }
