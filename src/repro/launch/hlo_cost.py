"""Trip-count-aware cost extraction from compiled HLO text.

``compiled.cost_analysis()`` traverses while-loop bodies ONCE — for a
scan-over-layers model that undercounts FLOPs/bytes/collectives by the
layer count (measured 10-20x). This module parses the scheduled HLO,
builds the computation call graph, and multiplies while bodies by their
``known_trip_count`` backend config, giving honest per-device roofline
terms:

- flops:  dot ops exactly (2 * prod(out) * prod(contracting)), plus
  elementwise ops at 1 flop/elem (8 for transcendental);
- bytes:  per op, operands + result (fusion internals NOT counted — a
  fusion's traffic is its operands/result, which is the HBM model);
- collectives: per class, ring-model wire bytes (same formulas as
  ``collectives.py``), trip-count multiplied.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_TRANSCENDENTAL = {"exponential", "log", "tanh", "logistic", "rsqrt",
                   "sqrt", "power", "sine", "cosine", "erf", "atan2",
                   "expm1", "log-plus-one", "cbrt", "tan"}
_ELEMENTWISE = {"add", "subtract", "multiply", "divide", "maximum",
                "minimum", "negate", "abs", "and", "or", "xor", "not",
                "compare", "select", "clamp", "floor", "ceil",
                "round-nearest-afz", "round-nearest-even", "sign",
                "shift-left", "shift-right-logical",
                "shift-right-arithmetic", "remainder", "atan2",
                "is-finite"}
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")


def _parse_op_line(line: str) -> Optional[Tuple[str, str, str, str]]:
    """-> (name, type_str, opcode, rest) or None."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    n = len(line)
    if i < n and line[i] == "(":                 # tuple type: balanced parens
        depth = 0
        j = i
        while j < n:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i:j + 1]
        i = j + 1
    else:
        j = i
        while j < n and not line[j].isspace():
            j += 1
        type_str = line[i:j]
        i = j
    while i < n and line[i].isspace():
        i += 1
    j = i
    while j < n and (line[j].isalnum() or line[j] in "-_"):
        j += 1
    opcode = line[i:j]
    if j >= n or line[j] != "(":
        return None
    return name, type_str, opcode, line[j + 1:]
_CALLED_RE = re.compile(
    r"(?:calls=|body=|to_apply=|condition=)%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes_and_elems(type_str: str) -> Tuple[int, int]:
    total_b = 0
    total_e = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_e += n
    return total_b, total_e


class _Op:
    __slots__ = ("name", "type_str", "opcode", "rest", "operands")

    def __init__(self, name, type_str, opcode, rest):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.rest = rest
        # operands: %refs before the first '),' of the call args
        depth = 0
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        self.operands = _OPERAND_RE.findall(rest[:end])


def _parse_computations(hlo: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        if line.startswith("HloModule") or not line.strip():
            continue
        stripped = line.strip()
        if not line.startswith(" "):           # computation header
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m and stripped.endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            elif stripped == "}":
                cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed:
            comps[cur].append(_Op(*parsed))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry            # type: ignore
    return comps


_PARAM_RE = re.compile(r"^(\d+)\)")   # matched against _Op.rest of parameter ops


def _fusion_input_bytes(op: "_Op", comp_name: Optional[str],
                        comps: Dict[str, List["_Op"]],
                        caller_table: Dict[str, str]) -> int:
    """Operand bytes of a fusion call, counting sliced/gathered operands
    at their slice size."""
    full = []
    for o in op.operands:
        b = _shape_bytes_and_elems(caller_table[o])[0] \
            if o in caller_table else 0
        full.append(b)
    if comp_name is None or comp_name not in comps:
        return sum(full)
    # map internal parameter names -> operand index
    param_of: Dict[str, int] = {}
    for iop in comps[comp_name]:
        if iop.opcode == "parameter":
            m = _PARAM_RE.match(iop.rest)
            if m:
                param_of[iop.name] = int(m.group(1))
    by_name = {iop.name: iop for iop in comps[comp_name]}

    def resolve_param(name, depth=0):
        if name in param_of or depth > 8:
            return name if name in param_of else None
        iop = by_name.get(name)
        if iop is not None and iop.opcode in ("bitcast", "copy", "convert",
                                              "reshape", "transpose") \
                and iop.operands:
            return resolve_param(iop.operands[0], depth + 1)
        return None

    counted = list(full)
    dus_update_bytes = 0
    has_dus_on_param = False
    for iop in comps[comp_name]:
        if iop.opcode in ("dynamic-slice", "gather", "slice"):
            src = resolve_param(iop.operands[0]) if iop.operands else None
            if src is not None:
                idx = param_of[src]
                if idx < len(counted):
                    sb = _shape_bytes_and_elems(iop.type_str)[0]
                    counted[idx] = min(counted[idx], sb)
        elif iop.opcode == "dynamic-update-slice" and len(iop.operands) >= 2:
            # in-place stash update: reads/writes only the update slice
            upd = by_name.get(iop.operands[1])
            ub = (_shape_bytes_and_elems(upd.type_str)[0]
                  if upd is not None else 0)
            dus_update_bytes += ub
            src = resolve_param(iop.operands[0])
            if src is not None:
                has_dus_on_param = True
                idx = param_of[src]
                if idx < len(counted):
                    counted[idx] = min(counted[idx], ub)
    return sum(counted), (dus_update_bytes if has_dus_on_param else None)


def _group_size(rest: str) -> int:
    gm = _GROUPS_RE.search(rest)
    if gm:
        return max(len([x for x in gm.group(1).split(",") if x.strip()]), 1)
    gi = _GROUPS_IOTA_RE.search(rest)
    if gi:
        return max(int(gi.group(2)), 1)
    return 1


def analyze(hlo: str) -> Dict[str, object]:
    comps = _parse_computations(hlo)
    entry = comps.pop("__entry_name__")        # type: ignore
    comps.pop("__entry__")
    shapes: Dict[str, Dict[str, str]] = {
        c: {op.name: op.type_str for op in ops} for c, ops in comps.items()}
    memo: Dict[str, Dict] = {}

    def comp_cost(cname: str) -> Dict:
        if cname in memo:
            return memo[cname]
        flops = 0.0
        bytes_ = 0.0
        coll = defaultdict(lambda: {"count": 0.0, "wire_bytes": 0.0})
        table = shapes.get(cname, {})
        for op in comps.get(cname, []):
            oc = op.opcode
            out_b, out_e = _shape_bytes_and_elems(op.type_str)
            in_b = 0
            for o in op.operands:
                if o in table:
                    b, _ = _shape_bytes_and_elems(table[o])
                    in_b += b
            if oc == "dot":
                lhs = op.operands[0] if op.operands else None
                lhs_contract = 1
                mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                if lhs and lhs in table and mm and mm.group(1):
                    sm = _SHAPE_RE.search(table[lhs])
                    if sm and sm.group(2):
                        dims = [int(x) for x in sm.group(2).split(",")]
                        for di in mm.group(1).split(","):
                            idx = int(di)
                            if idx < len(dims):
                                lhs_contract *= dims[idx]
                flops += 2.0 * out_e * lhs_contract
                bytes_ += out_b + in_b   # dots genuinely stream operands
            elif oc == "fusion":
                called = _CALLED_RE.findall(op.rest)
                for c in called:
                    sub = comp_cost(c)
                    flops += sub["flops"]      # dots inside fusions count
                # slice-aware traffic: a fusion that dynamic-slices into a
                # big (stacked/loop-carried) operand only reads the slice;
                # a fusion whose root dynamic-update-slices into a param
                # only writes the slice. Charging full operands/results was
                # measured to overcount HBM traffic ~4x on scan-heavy HLO.
                fin, out_over = _fusion_input_bytes(
                    op, called[0] if called else None, comps, table)
                bytes_ += fin + (out_over if out_over is not None else out_b)
            elif oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                for c in _CALLED_RE.findall(op.rest):
                    sub = comp_cost(c)
                    flops += sub["flops"] * trip
                    bytes_ += sub["bytes"] * trip
                    for k, v in sub["coll"].items():
                        coll[k]["count"] += v["count"] * trip
                        coll[k]["wire_bytes"] += v["wire_bytes"] * trip
            elif oc == "conditional":
                branches = _BRANCHES_RE.search(op.rest)
                names = (_OPERAND_RE.findall(branches.group(1))
                         if branches else _CALLED_RE.findall(op.rest))
                if names:
                    subs = [comp_cost(c) for c in names]
                    # max over branches (can't know which is taken)
                    best = max(subs, key=lambda s: s["flops"])
                    flops += best["flops"]
                    bytes_ += best["bytes"]
                bytes_ += out_b + in_b
            elif oc in ("call", "custom-call"):
                for c in _CALLED_RE.findall(op.rest):
                    sub = comp_cost(c)
                    flops += sub["flops"]
                    bytes_ += sub["bytes"]
                bytes_ += out_b
            elif any(oc.startswith(c) for c in _COLL):
                if oc.endswith("-done"):
                    continue
                g = _group_size(op.rest)
                base = oc.replace("-start", "")
                nb = out_b
                if base == "all-gather":
                    wire = nb * (g - 1) / g
                elif base == "reduce-scatter":
                    wire = nb * (g - 1)
                elif base == "all-reduce":
                    wire = 2.0 * nb * (g - 1) / g
                elif base == "all-to-all":
                    wire = nb * (g - 1) / g
                else:
                    wire = float(nb)
                coll[base]["count"] += 1
                coll[base]["wire_bytes"] += wire
                bytes_ += out_b + in_b
            elif oc in ("reduce", "reduce-window", "sort", "scatter",
                        "map", "select-and-scatter"):
                for c in _CALLED_RE.findall(op.rest):
                    comp_cost(c)               # tiny; flops ignored
                flops += max(in_b // 4, out_e)
                bytes_ += out_b + in_b
            elif oc in ("parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast", "after-all"):
                pass
            elif oc in _TRANSCENDENTAL or oc.startswith("exponential"):
                flops += 8.0 * out_e
                bytes_ += out_b          # standalone (unfused) op: rare
            elif oc in _ELEMENTWISE or oc == "convert":
                flops += out_e
                bytes_ += out_b
            else:   # copy, broadcast, iota, slice, dus, gather, pad, ...
                bytes_ += out_b
        res = {"flops": flops, "bytes": bytes_,
               "coll": {k: dict(v) for k, v in coll.items()}}
        memo[cname] = res
        return res

    top = comp_cost(entry)
    return {
        "flops": top["flops"],
        "bytes": top["bytes"],
        "collectives": top["coll"],
        "collective_wire_bytes": sum(v["wire_bytes"]
                                     for v in top["coll"].values()),
    }
