"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and everything else sees the real device count.

Axis sizes are validated eagerly: jax's own mesh builders silently
construct a mesh over a *subset* of the devices when the requested
shape's product merely fits under ``jax.device_count()`` (e.g. a (3, 2)
request on 8 devices yields a 6-device mesh with 2 chips idle — or, at
worst, a 1-device mesh). Production meshes must cover the machine, so a
shape whose product does not divide the device count raises with the
factorizations that would.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax

try:                                    # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                     # 0.4.x
    AxisType = None


def _factorizations(n: int, k: int) -> Tuple[Tuple[int, ...], ...]:
    """All ordered k-tuples of positive ints whose product is n."""
    if k == 1:
        return ((n,),)
    out = []
    for d in range(1, n + 1):
        if n % d == 0:
            out.extend((d,) + rest for rest in _factorizations(n // d, k - 1))
    return tuple(out)


def validate_mesh_shape(shape: Sequence[int], axes: Sequence[str]) -> None:
    """Raise unless ``prod(shape)`` exactly divides the device count."""
    shape = tuple(int(s) for s in shape)
    if len(shape) != len(axes):
        raise ValueError(f"mesh shape {shape} has {len(shape)} dims but "
                         f"{len(axes)} axis names {tuple(axes)}")
    n = 1
    for s in shape:
        if s < 1:
            raise ValueError(f"mesh axis sizes must be >= 1, got {shape}")
        n *= s
    dc = jax.device_count()
    if n > dc or dc % n != 0:
        opts = _factorizations(dc, len(shape))
        raise ValueError(
            f"mesh shape {shape} needs {n} devices but jax.device_count() "
            f"is {dc}; pick a {len(shape)}-axis factorization of {dc}: "
            f"{list(opts[:16])}"
            + (" …" if len(opts) > 16 else ""))


def make_mesh(shape, axes):
    """Generic validated mesh (small CPU meshes for tests and probing)."""
    shape, axes = tuple(int(s) for s in shape), tuple(axes)
    validate_mesh_shape(shape, axes)
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    return Mesh(mesh_utils.create_device_mesh(shape), axes)


def probe_axis_names(shape) -> Tuple[str, ...]:
    """Axis names for a probing mesh: ('dev',) or ('dev0', 'dev1', …)."""
    return ("dev",) if len(shape) == 1 else \
        tuple(f"dev{i}" for i in range(len(shape)))


def parse_mesh_arg(arg) -> Tuple[int, ...]:
    """CLI mesh shape: '8' -> (8,), '2x4' or '2,4' -> (2, 4); None/''
    -> () (no mesh)."""
    if not arg:
        return ()
    parts = [p for p in str(arg).replace("x", ",").split(",") if p.strip()]
    try:
        return tuple(int(p) for p in parts)
    except ValueError:
        raise ValueError(f"bad --mesh {arg!r}: expected e.g. '8' or '2x4'")


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
