"""Collective-bytes extraction: compiled HLO text + traced-jaxpr views.

``cost_analysis()`` does not expose collective traffic, so we model it
ourselves. Every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute contributes per-device *wire bytes* under the
standard ring model, from its result size and the participating group
size G (:func:`ring_wire_bytes`):

    all-gather          out_bytes * (G-1)/G          (each device receives
                                                      everyone else's shard)
    reduce-scatter      out_bytes * (G-1)            (operand = out*G; ring
                                                      sends (G-1)/G of it)
    all-reduce          2 * bytes * (G-1)/G          (RS + AG phases)
    all-to-all          bytes * (G-1)/G
    collective-permute  bytes                        (point-to-point)

Two front-ends share the model: :func:`parse_collective_bytes` parses a
compiled HLO module (post-GSPMD ground truth, no scope information) and
:func:`jaxpr_collectives` walks a traced per-shard jaxpr (pre-compile,
knows the scope hierarchy — what the mesh probe joins cycle counters
against; see ``core/meshprobe.py``).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# jaxpr collective primitive -> HLO collective kind
PRIMITIVE_KINDS = {
    "psum": "all-reduce", "pmax": "all-reduce", "pmin": "all-reduce",
    "all_gather": "all-gather", "reduce_scatter": "reduce-scatter",
    "psum_scatter": "reduce-scatter", "all_to_all": "all-to-all",
    "ppermute": "collective-permute", "pbroadcast": "all-gather",
}

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def ring_wire_bytes(kind: str, nbytes: float, group_size: int) -> float:
    """Per-device wire bytes of one collective under the ring model.

    ``nbytes`` is the op's *result* size; ``group_size`` the number of
    participating devices. G == 1 collectives move nothing (except a
    self-permute, which still copies its payload).
    """
    g = max(int(group_size), 1)
    if kind == "all-gather":
        return nbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return nbytes * (g - 1)
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind == "all-to-all":
        return nbytes * (g - 1) / g
    if kind == "collective-permute":
        return float(nbytes)
    raise ValueError(f"unknown collective kind {kind!r}; "
                     f"expected one of {COLLECTIVE_KINDS}")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _tuple_bytes(tup: str) -> int:
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", tup):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def parse_replica_group_size(line: str) -> int:
    """Group size G from an HLO op line's ``replica_groups`` attribute.

    Handles the explicit form ``{{0,1},{2,3}}`` (G = size of the first
    group; an empty ``{{}}`` means a single all-devices group of unknown
    size -> 1) and the iota form ``[n,m]<=[...]`` (G = m). Lines without
    the attribute (e.g. ``collective-permute``) return 1.
    """
    gm = _GROUPS_RE.search(line)
    if gm:
        return max(len([x for x in gm.group(1).split(",")
                        if x.strip() != ""]), 1)
    gi = _GROUPS_IOTA_RE.search(line)
    if gi:
        return max(int(gi.group(2)), 1)
    return 1


def parse_collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Aggregate per-kind collective traffic from compiled HLO text."""
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tup, dtype, dims, kind = m.groups()
        if "-done" in line:
            continue
        nbytes = _tuple_bytes(tup) if tup else _shape_bytes(dtype, dims)
        g = parse_replica_group_size(line)
        rec = out[kind]
        rec["count"] += 1
        rec["result_bytes"] += float(nbytes)
        rec["wire_bytes"] += float(ring_wire_bytes(kind, nbytes, g))
    return dict(out)


# ------------------------------------------------- traced-jaxpr view

@dataclass(frozen=True)
class CollectiveSite:
    """One collective equation in a traced per-shard program."""
    path: str                 # scope path (hierarchy join key)
    primitive: str            # jaxpr primitive name
    kind: str                 # HLO collective kind (ring-model key)
    axes: Tuple[str, ...]     # mesh axes it runs over
    group_size: int           # participating devices G
    result_bytes: int         # per-shard result size
    wire_bytes: float         # ring-model per-device wire bytes


def collective_axes(eqn) -> Tuple[str, ...]:
    """Mesh axis names a collective eqn runs over (possibly several)."""
    axes = eqn.params.get("axes", eqn.params.get("axis_name"))
    if axes is None:
        return ()
    if isinstance(axes, (str, int)):
        return (str(axes),)
    return tuple(str(a) for a in axes)


def _aval_nbytes(aval) -> int:
    try:
        import numpy as np
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def jaxpr_collectives(jaxpr, axis_sizes: Dict[str, int],
                      eqn_paths: Optional[Dict[int, str]] = None,
                      _path: str = "") -> List[CollectiveSite]:
    """Walk a (per-shard) jaxpr and model every collective equation.

    ``axis_sizes`` maps mesh axis name -> size (``dict(mesh.shape)``).
    ``eqn_paths`` optionally maps ``id(eqn)`` -> scope path (the
    hierarchy's ``eqn_info``); unknown eqns inherit the walk prefix.
    Recurses into control flow / call sub-jaxprs, so sites inside scan
    bodies are attributed to their loop scope.
    """
    from repro.core import costmodel as cm
    sites: List[CollectiveSite] = []
    for eqn in jaxpr.eqns:
        path = (eqn_paths or {}).get(id(eqn), _path)
        kind = PRIMITIVE_KINDS.get(eqn.primitive.name)
        if kind is not None:
            axes = collective_axes(eqn)
            g = 1
            for a in axes:
                g *= int(axis_sizes.get(a, 1))
            nbytes = sum(_aval_nbytes(v.aval) for v in eqn.outvars)
            sites.append(CollectiveSite(
                path=path, primitive=eqn.primitive.name, kind=kind,
                axes=axes, group_size=g, result_bytes=nbytes,
                wire_bytes=ring_wire_bytes(kind, nbytes, g)))
        for sub in cm._sub_jaxprs(eqn):
            sites.extend(jaxpr_collectives(cm._as_jaxpr(sub), axis_sizes,
                                           eqn_paths, _path=path))
    return sites
