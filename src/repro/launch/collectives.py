"""Collective-bytes extraction from compiled HLO text.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled module: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op contributes per-device *wire bytes*
under the standard ring model, using its result shape and the replica
group size G parsed from the op:

    all-gather          out_bytes * (G-1)/G          (each device receives
                                                      everyone else's shard)
    reduce-scatter      out_bytes * (G-1)            (operand = out*G; ring
                                                      sends (G-1)/G of it)
    all-reduce          2 * bytes * (G-1)/G          (RS + AG phases)
    all-to-all          bytes * (G-1)/G
    collective-permute  bytes                        (point-to-point)
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
}

_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _tuple_bytes(tup: str) -> int:
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", tup):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tup, dtype, dims, kind = m.groups()
        if "-done" in line:
            continue
        nbytes = _tuple_bytes(tup) if tup else _shape_bytes(dtype, dims)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len([x for x in gm.group(1).split(",") if x.strip() != ""])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        g = max(g, 1)
        if kind == "all-gather":
            wire = nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = nbytes * (g - 1)
        elif kind == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif kind == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:   # collective-permute
            wire = float(nbytes)
        rec = out[kind]
        rec["count"] += 1
        rec["result_bytes"] += float(nbytes)
        rec["wire_bytes"] += float(wire)
    return dict(out)
