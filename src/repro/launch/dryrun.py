import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, and extract the roofline raw material.

MUST be run as a module (``python -m repro.launch.dryrun``) so the two
lines above execute before ANY other import initializes jax.

Per cell it records:
- ``compiled.memory_analysis()``  (per-device bytes — proves it fits),
- ``compiled.cost_analysis()``    (per-device HLO FLOPs / bytes),
- per-class collective bytes parsed from the compiled HLO text (ring-
  model per-device wire bytes; see ``collectives.py`` for the formulas),
- compile wall-time and the collective op census.

Results are cached as JSON under ``benchmarks/results/dryrun/`` keyed by
(arch, shape, mesh); completed cells are skipped on re-runs so the full
sweep is resumable (the fleet-scale version of checkpoint/restart).
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import CONFIGS, get_config
from repro.distributed import sharding as shd
from repro.distributed.steps import (build_decode_step, build_prefill_step,
                                     build_train_step)
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim import adamw

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _rules_for(shape_name: str, kind: str):
    if kind == "train" or kind == "prefill":
        return shd.TRAIN_RULES
    if shape_name == "long_500k":
        return shd.SERVE_LONG_RULES
    return shd.SERVE_RULES


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_pspec(specs, rules, cfg, mesh):
    def spec(path_key, s):
        if len(s.shape) == 0:
            return P()
        if cfg.pos_emb == "mrope" and len(s.shape) == 3 and s.shape[0] == 3:
            return shd.to_pspec((None, "batch", "seq"), rules,
                                shape=s.shape, mesh=mesh)
        parts = ["batch"] + [None] * (len(s.shape) - 1)
        return shd.to_pspec(tuple(parts), rules, shape=s.shape, mesh=mesh)
    return {k: spec(k, v) for k, v in specs.items()}


def abstract_opt_state(model, params_abs):
    return jax.eval_shape(
        lambda p: adamw.init(p, model.cfg.moment_dtype), params_abs)


def opt_shardings(mesh, pspecs, moment_dtype: str):
    """AdamWState shardings mirroring the param pspecs (int8 moments get
    trimmed scale specs)."""
    from repro.optim.quantized import QTensor

    def per_param(ps):
        if moment_dtype == "int8":
            parts = list(ps)
            s_spec = P(*(parts[:-1] + [None])) if parts else P()
            return QTensor(q=NamedSharding(mesh, ps),
                           s=NamedSharding(mesh, s_spec))
        return NamedSharding(mesh, ps)

    tree = jax.tree_util.tree_map(per_param, pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
    return adamw.AdamWState(step=NamedSharding(mesh, P()),
                            mu=tree, nu=tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               remat: str = None, extra_cfg: dict = None):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    if remat:
        cfg = cfg.replace(remat=remat)
    if extra_cfg:
        cfg = cfg.replace(**extra_cfg)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.filter_rules(_rules_for(shape_name, shape.kind), mesh)
    params_abs = model.abstract_params()
    param_sh = _named(mesh, shd.schema_pspecs(model.schema(), rules, mesh))
    in_specs = model.input_specs(shape)
    batch_sh = _named(mesh, _batch_pspec(in_specs, rules, cfg, mesh))

    t0 = time.time()
    with jax.set_mesh(mesh), shd.axis_rules(rules):
        if shape.kind == "train":
            tcfg = TrainConfig(microbatches=cfg.train_microbatches)
            step = build_train_step(model, tcfg)
            opt_abs = abstract_opt_state(model, params_abs)
            opt_sh = opt_shardings(
                mesh, shd.schema_pspecs(model.schema(), rules, mesh),
                cfg.moment_dtype)
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, in_specs)
        elif shape.kind == "prefill":
            step = build_prefill_step(model, shape)
            lowered = jax.jit(
                step, in_shardings=(param_sh, batch_sh),
            ).lower(params_abs, in_specs)
        else:   # decode
            step = build_decode_step(model)
            cache_abs, cache_axes = model.cache_specs(shape)
            cache_sh = _named(mesh, {
                k: shd.to_pspec(cache_axes[k], rules,
                                shape=cache_abs[k].shape, mesh=mesh)
                for k in cache_axes})
            lowered = jax.jit(
                step, in_shardings=(param_sh, cache_sh, batch_sh),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs, in_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    cost = hlo_analyze(hlo)          # trip-count-aware (scans multiplied)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": float(cost["flops"]),
        "bytes_per_device": float(cost["bytes"]),
        "collectives": cost["collectives"],
        "collective_bytes_per_device": float(cost["collective_wire_bytes"]),
        "raw_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_estimate_bytes": int(ma.argument_size_in_bytes +
                                       ma.output_size_in_bytes +
                                       ma.temp_size_in_bytes -
                                       ma.alias_size_in_bytes),
        },
        "param_count": model.param_count(),
    }
    return rec


def run(arch=None, shape=None, meshes=("16x16", "2x16x16"), force=False):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    results = []
    for a, cfg in CONFIGS.items():
        if arch and a != arch:
            continue
        for s in SHAPES.values():
            if shape and s.name != shape:
                continue
            skip = s.name == "long_500k" and not cfg.supports_long_context
            for mesh_name in meshes:
                key = f"{a}__{s.name}__{mesh_name}"
                out = RESULTS_DIR / f"{key}.json"
                if out.exists() and not force:
                    results.append(json.loads(out.read_text()))
                    print(f"[cached] {key}")
                    continue
                if skip:
                    rec = {"arch": a, "shape": s.name, "mesh": mesh_name,
                           "skipped": "full-attention arch at 500k ctx "
                                      "(sub-quadratic required; DESIGN.md)"}
                    out.write_text(json.dumps(rec, indent=1))
                    results.append(rec)
                    print(f"[skip]   {key}")
                    continue
                print(f"[run]    {key} ...", flush=True)
                try:
                    rec = lower_cell(a, s.name, mesh_name == "2x16x16")
                    out.write_text(json.dumps(rec, indent=1))
                    mem = rec["memory"]["peak_estimate_bytes"] / 2**30
                    print(f"         ok: compile {rec['compile_s']}s, "
                          f"flops/dev {rec['flops_per_device']:.3e}, "
                          f"mem/dev {mem:.2f} GiB", flush=True)
                except Exception as e:
                    rec = {"arch": a, "shape": s.name, "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    out.write_text(json.dumps(rec, indent=1))
                    print(f"         FAILED: {type(e).__name__}: {e}",
                          flush=True)
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    meshes = (args.mesh,) if args.mesh else ("16x16", "2x16x16")
    results = run(args.arch, args.shape, meshes, args.force)
    n_ok = sum(1 for r in results if "error" not in r and "skipped" not in r)
    n_err = sum(1 for r in results if "error" in r)
    n_skip = sum(1 for r in results if "skipped" in r)
    print(f"\ndry-run: {n_ok} ok, {n_err} failed, {n_skip} skipped")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
