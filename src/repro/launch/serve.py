"""Serving entry point, routed through the continuous-batching engine.

Engine-compatible models (attention family, token inputs) serve through
``repro.engine.InferenceEngine``: each batch row becomes one request,
decode runs at a pre-traced batch bucket over the paged KV pool, and
``--profile`` attributes model-clock cycles to prefill / cache / decode
per request (docs/serving.md). Outputs are bit-identical to the legacy
lock-step loop (asserted in tests/test_engine.py).

The legacy loop remains for frontend/SSM/hybrid models and for
``--mesh`` per-device probing, where ``--profile`` runs the decode step
under a live ``ProbeSession`` with streaming telemetry.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config, smoke_config
from repro.distributed.steps import build_decode_step, build_prefill_step
from repro.models.frontends import synth_frontend_batch
from repro.models.model import Model


def _mesh_decode_session(model, shape, mesh_shape, frontend: bool,
                         targets, max_probes, window_steps, bus=None):
    """Mesh-probed decode: batch (and every cache leaf's batch dim)
    sharded over the probing mesh, so the live session records one
    cycle-counter row per device (docs/distributed.md)."""
    from jax.sharding import PartitionSpec as P
    from repro.core import MeshProbeSession, ProbeConfig, mesh_probe
    from repro.launch.mesh import make_mesh, probe_axis_names
    axes = probe_axis_names(mesh_shape)
    pmesh = make_mesh(mesh_shape, axes)
    cspecs, caxes = model.cache_specs(shape)
    cache_spec = {k: P(*[axes if a == "batch" else None
                         for a in caxes[k]]) for k in cspecs}
    batch_spec = ({"embeds": P(axes)} if frontend else
                  {"tokens": P(axes)})
    batch_spec["pos"] = P()
    return MeshProbeSession(
        mesh_probe(build_decode_step(model), pmesh,
                   in_specs=(P(), cache_spec, batch_spec),
                   out_specs=(P(axes), cache_spec, P(axes)),
                   config=ProbeConfig(targets=targets,
                                      max_probes=max_probes)),
        window_steps=window_steps, bus=bus, source="serve/mesh")


def _engine_serve(model, params, key, *, batch: int, prompt_len: int,
                  max_new: int, profile: bool,
                  profile_targets: Tuple[str, ...],
                  profile_max_probes: int, engine_kernel: bool,
                  prefill_chunk: int = 0, donate: Optional[bool] = None,
                  bus=None):
    """Serve ``batch`` random prompts through the continuous-batching
    engine (one request per row, decode bucketed at the batch size)."""
    import math

    from repro.engine import EngineConfig, InferenceEngine
    cfg = model.cfg
    page = 16
    max_pages = max(1, math.ceil((prompt_len + max_new - 1) / page))
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=page, pool_pages=batch * max_pages + 2,
        max_pages=max_pages,
        buckets=(1, batch) if batch > 1 else (1,),
        use_kernel=engine_kernel, probe=profile,
        probe_targets=profile_targets,
        probe_max_probes=profile_max_probes,
        prefill_chunk_pages=prefill_chunk, donate=donate), bus=bus)
    tokens = jax.random.randint(key, (batch, prompt_len), 0,
                                cfg.vocab_size)
    prompts = np.asarray(tokens)
    t0 = time.time()
    for b in range(batch):
        eng.submit(prompts[b].tolist(), max_new)
    done = eng.run()
    t_serve = time.time() - t0
    toks = np.array([r.out_tokens for r in done], np.int32)
    st = eng.stats()
    print(f"engine: {batch} requests x {max_new} tokens in "
          f"{t_serve * 1e3:.1f} ms (pages peak {st['pages_peak']}, "
          f"retraces {st['retraces']})")
    if profile:
        print("\n# per-phase cycle attribution")
        print(eng.phase_table())
        if prefill_chunk:
            print("\n# per-chunk-shape prefill bill")
            print(eng.chunk_table())
        print("\n# per-request phase bill")
        print(eng.request_table(done))
    eng.drain()
    eng.close()
    return toks


def serve(arch: str = "tinyllama-1.1b", *, smoke: bool = True,
          batch: int = 4, prompt_len: int = 32, max_new: int = 16,
          cache_len: int = 128, profile: bool = False,
          profile_targets: Tuple[str, ...] = ("",),
          profile_every: int = 8, profile_max_probes: int = 16,
          profile_mesh: Tuple[int, ...] = (),
          autotune: bool = False, tune_cache: Optional[str] = None,
          engine: Optional[bool] = None, engine_kernel: bool = False,
          prefill_chunk: int = 0, donate: Optional[bool] = None,
          status_port: Optional[int] = None):
    if autotune:
        from repro.kernels import tuning
        tuning.load_cache(cache_dir=tune_cache, verbose=True)
    cfg = smoke_config(arch) if smoke else get_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    plane = None
    if status_port is not None:
        from repro.telemetry import ControlPlane
        plane = ControlPlane(status_port).start()
    bus = plane.bus if plane is not None else None

    if engine is None:
        from repro.engine import engine_compatible
        engine = engine_compatible(cfg) and not profile_mesh
    if engine:
        try:
            return _engine_serve(
                model, params, key, batch=batch, prompt_len=prompt_len,
                max_new=max_new, profile=profile,
                profile_targets=profile_targets,
                profile_max_probes=profile_max_probes,
                engine_kernel=engine_kernel,
                prefill_chunk=prefill_chunk, donate=donate, bus=bus)
        finally:
            if plane is not None:
                plane.finish()

    prefill = jax.jit(build_prefill_step(
        model, ShapeConfig("pf", cache_len, batch, "prefill")))
    profile_every = max(profile_every, 1)
    session = None
    mesh_session = False
    if profile and profile_mesh:
        session = _mesh_decode_session(
            model, ShapeConfig("pf", cache_len, batch, "decode"),
            profile_mesh, cfg.frontend != "none", profile_targets,
            profile_max_probes, max(profile_every, 1), bus=bus)
        decode = session.step
        mesh_session = True
    elif profile:
        from repro.core import ProbeConfig, ProbeSession
        session = ProbeSession(
            build_decode_step(model),
            ProbeConfig(targets=profile_targets, offload=1.0,
                        max_probes=profile_max_probes),
            window_steps=max(profile_every, 1),
            bus=bus, source="serve/decode")
        decode = session.step
    else:
        decode = jax.jit(build_decode_step(model), donate_argnums=(1,))

    if cfg.frontend != "none":
        fb = synth_frontend_batch(cfg, batch, prompt_len, jnp.bfloat16, key)
        pbatch = dict(fb)
    else:
        pbatch = {"tokens": jax.random.randint(key, (batch, prompt_len), 0,
                                               cfg.vocab_size)}
    t0 = time.time()
    logits, cache = prefill(params, pbatch)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [np.asarray(next_tok)]
    t0 = time.time()
    for i in range(max_new - 1):
        pos = jnp.int32(prompt_len + i)
        if cfg.frontend != "none":
            fb1 = synth_frontend_batch(cfg, batch, 1, jnp.bfloat16,
                                       jax.random.fold_in(key, i))
            dbatch = {"embeds": fb1["embeds"], "pos": pos}
        else:
            dbatch = {"tokens": next_tok[:, None], "pos": pos}
        logits, cache, next_tok = decode(params, cache, dbatch)
        out_tokens.append(np.asarray(next_tok))
        if session is not None and session.steps % profile_every == 0:
            snap = session.snapshot()
            if mesh_session:
                d, p = snap.record.straggler()
                print(f"[probe] decode step {session.steps:4d}: "
                      f"span(max)={snap.span} cycles over "
                      f"{snap.record.n_devices} devices, "
                      f"straggler=dev{d}:{p} "
                      f"(skew {int(snap.record.skew().max(initial=0))})",
                      flush=True)
            else:
                hot = snap.bottleneck()
                hot_s = (f"{hot.path} (ema {hot.ema:.1f} cyc/call)"
                         if hot else "-")
                print(f"[probe] decode step {session.steps:4d}: "
                      f"span={snap.span} cycles, "
                      f"state={snap.state_nbytes}B, "
                      f"hot={hot_s}", flush=True)
    t_decode = time.time() - t0
    toks = np.stack(out_tokens, axis=1)
    print(f"prefill {prompt_len} tokens x{batch}: {t_prefill * 1e3:.1f} ms; "
          f"decode {max_new} steps: {t_decode * 1e3:.1f} ms "
          f"({t_decode / max(max_new - 1, 1) * 1e3:.2f} ms/tok)")
    if session is not None:
        final = session.close()
        if final is not None:
            print("\n# streaming probe telemetry (decode loop)")
            print(final.table())
            if mesh_session:
                print("\n# per-device cycle records")
                print(final.device_table())
                print("\n# straggler heat view")
                print(final.heat())
            else:
                print("\n# bottleneck drift across windows")
                print(final.bump_chart())
    if plane is not None:
        plane.finish()
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--profile", action="store_true",
                    help="run the decode loop under a live ProbeSession")
    ap.add_argument("--mesh", default=None,
                    help="profile per device on an N-way mesh, e.g. '8' "
                         "(with --profile; batch must divide the mesh size)")
    ap.add_argument("--profile-targets", default="",
                    help="comma-separated probe subtree roots")
    ap.add_argument("--profile-every", type=int, default=8)
    ap.add_argument("--autotune", action="store_true",
                    help="load DSE-tuned kernel configs from the eval cache")
    ap.add_argument("--tune-cache", default=None,
                    help="eval cache dir (default .repro_cache/dse)")
    ap.add_argument("--no-engine", action="store_true",
                    help="force the legacy lock-step loop instead of the "
                         "continuous-batching engine")
    ap.add_argument("--engine-kernel", action="store_true",
                    help="decode through the paged_attention Pallas kernel")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prefill chunk quantum in pages (0 = whole-prompt "
                         "prefill; >0 interleaves prefill chunks with "
                         "decode rounds, killing head-of-line blocking)")
    ap.add_argument("--donate", action="store_true", default=None,
                    help="donate the paged KV pool to the cache/decode "
                         "steps (in-place pool updates; default: auto "
                         "on accelerators, off under --profile)")
    ap.add_argument("--status-port", type=int, default=None,
                    help="expose live telemetry over HTTP on this port "
                         "(0 = OS-assigned; prints the bound URL)")
    args = ap.parse_args()
    from repro.launch.mesh import parse_mesh_arg
    toks = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                 max_new=args.max_new, profile=args.profile,
                 profile_targets=tuple(args.profile_targets.split(",")),
                 profile_every=args.profile_every,
                 profile_mesh=parse_mesh_arg(args.mesh),
                 autotune=args.autotune, tune_cache=args.tune_cache,
                 engine=False if args.no_engine else None,
                 engine_kernel=args.engine_kernel,
                 prefill_chunk=args.prefill_chunk, donate=args.donate,
                 status_port=args.status_port)
    print("sampled token ids (first sequence):", toks[0].tolist())


if __name__ == "__main__":
    main()
