"""End-to-end trainer (example application + the serving ground for the
RealProbe integration: ``--probe`` runs the whole loop under a streaming
``ProbeSession`` and prints periodic telemetry snapshots).

Runs on anything from 1 CPU device (smoke configs) to the production
mesh; fault-tolerance wiring (atomic async checkpoints, SIGTERM hook,
exactly-once data accounting, elastic restore) is exercised by the test
suite on small meshes.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config, smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import sharding as shd
from repro.distributed.steps import build_train_step
from repro.models.model import Model
from repro.optim import adamw


def train(arch: str = "tinyllama-1.1b", *, smoke: bool = True,
          steps: int = 20, batch: int = 8, seq: int = 128,
          mesh_shape=None, probe_targets: Optional[tuple] = None,
          probe_mesh: Optional[tuple] = None,
          checkpoint_dir: Optional[str] = None, resume: bool = False,
          tcfg: Optional[TrainConfig] = None, log_every: int = 10,
          probe_every: int = 0, autotune: bool = False,
          tune_cache: Optional[str] = None,
          status_port: Optional[int] = None):
    if autotune:
        from repro.kernels import tuning
        tuning.load_cache(cache_dir=tune_cache, verbose=True)
    cfg = smoke_config(arch) if smoke else get_config(arch)
    model = Model(cfg)
    tcfg = tcfg or TrainConfig(total_steps=steps, warmup_steps=max(steps // 10, 1),
                               checkpoint_dir=checkpoint_dir or "/tmp/repro_ckpt")

    if mesh_shape:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(*mesh_shape)
    else:
        mesh = None
    rules = shd.filter_rules(shd.TRAIN_RULES, mesh) if mesh else None

    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                    global_batch=batch, seed=tcfg.seed))
    params = model.init(jax.random.PRNGKey(tcfg.seed))
    opt_state = adamw.init(params, cfg.moment_dtype)

    ckpt = None
    start_step = 0
    if checkpoint_dir:
        ckpt = Checkpointer(checkpoint_dir, keep=tcfg.keep_checkpoints,
                            async_save=tcfg.async_checkpoint)
        last = ckpt.latest()
        if resume and last is not None:
            (params, opt_state), extra = ckpt.restore(
                last, (params, opt_state))
            start_step = int(extra["step"])
            pipe.state.step = int(extra["data_step"])

    step_fn = build_train_step(model, tcfg)
    plane = None
    if status_port is not None:
        from repro.telemetry import ControlPlane
        plane = ControlPlane(status_port).start()
    bus = plane.bus if plane is not None else None
    session = None
    mesh_session = False
    if probe_targets is not None and probe_mesh:
        # mesh-aware probing: data-parallel per-shard step under a probed
        # shard_map — one cycle-counter row per device (docs/distributed.md)
        from jax.sharding import PartitionSpec as P
        from repro.core import MeshProbeSession, ProbeConfig, mesh_probe
        from repro.distributed.steps import build_dp_train_step
        from repro.launch.mesh import make_mesh, probe_axis_names
        axes = probe_axis_names(probe_mesh)
        pmesh = make_mesh(probe_mesh, axes)
        dp_step = build_dp_train_step(
            model, tcfg, axis=axes[0] if len(axes) == 1 else axes)
        session = MeshProbeSession(
            mesh_probe(dp_step, pmesh,
                       in_specs=(P(), P(), P(axes)),
                       out_specs=(P(), P(), P()),
                       config=ProbeConfig(targets=tuple(probe_targets),
                                          max_probes=16)),
            window_steps=max(probe_every or log_every, 1),
            bus=bus, source="train/mesh")
        run_jitted = session.step
        mesh_session = True
    elif probe_targets is not None:
        from repro.core import ProbeConfig, ProbeSession
        session = ProbeSession(
            step_fn, ProbeConfig(targets=tuple(probe_targets),
                                 offload=1.0, max_probes=16),
            window_steps=max(probe_every or log_every, 1),
            bus=bus, source="train/step")
        run_jitted = session.step
    else:
        run_jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    def run_step(params, opt_state, batch_np):
        b = {k: jnp.asarray(v) for k, v in batch_np.items()}
        return run_jitted(params, opt_state, b)

    ctx = shd.axis_rules(rules, mesh)
    history = []
    from repro.distributed import compat
    with compat.mesh_context(mesh), ctx:
        t0 = time.time()
        for step in range(start_step, steps):
            batch_np = pipe.batch_at(step)
            pipe.state.step = step + 1
            params, opt_state, metrics = run_step(params, opt_state,
                                                  batch_np)
            loss = float(metrics["loss"])
            history.append(loss)
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):7.3f} "
                      f"({dt:.1f}s)", flush=True)
            if session is not None and \
                    session.steps % (probe_every or log_every) == 0:
                snap = session.snapshot()
                print(f"[probe] {snap.steps} steps, span={snap.span} "
                      f"cycles, state={snap.state_nbytes}B", flush=True)
                print(snap.table(), flush=True)
            if ckpt and (step + 1) % tcfg.checkpoint_every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          extra={"step": step + 1,
                                 "data_step": pipe.state.step})
        if ckpt:
            ckpt.save(steps, (params, opt_state),
                      extra={"step": steps, "data_step": pipe.state.step})
            ckpt.wait()
    if session is not None:
        final = session.close()
        if final is not None:
            print("\n# final streaming probe telemetry")
            print(final.table())
            if mesh_session:
                print("\n# per-device cycle records")
                print(final.device_table())
                print("\n# straggler heat view")
                print(final.heat())
            else:
                print(final.bump_chart())
    if plane is not None:
        plane.finish()
    return params, opt_state, history


def main():
    from repro.launch.mesh import parse_mesh_arg
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs real hardware)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="profile the train step with a live ProbeSession")
    ap.add_argument("--mesh", default=None,
                    help="probe per device on an N-way mesh, e.g. '8' or "
                         "'2x4' (with --probe; batch must divide the mesh "
                         "size). Force devices on CPU via XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    ap.add_argument("--probe-targets", default="",
                    help="comma-separated probe subtree roots")
    ap.add_argument("--probe-every", type=int, default=0,
                    help="snapshot period in steps (default: log-every)")
    ap.add_argument("--autotune", action="store_true",
                    help="load DSE-tuned kernel configs from the eval cache")
    ap.add_argument("--tune-cache", default=None,
                    help="eval cache dir (default .repro_cache/dse)")
    ap.add_argument("--status-port", type=int, default=None,
                    help="expose live telemetry over HTTP on this port "
                         "(0 = OS-assigned; prints the bound URL)")
    args = ap.parse_args()
    train(args.arch, smoke=not args.full, steps=args.steps,
          batch=args.batch, seq=args.seq,
          probe_targets=(tuple(args.probe_targets.split(","))
                         if args.probe else None),
          probe_mesh=parse_mesh_arg(args.mesh),
          probe_every=args.probe_every,
          checkpoint_dir=args.checkpoint_dir, resume=args.resume,
          autotune=args.autotune, tune_cache=args.tune_cache,
          status_port=args.status_port)


if __name__ == "__main__":
    main()
