"""Roofline analysis from the dry-run artifacts (§Roofline).

Per (arch x shape x mesh) cell, from the trip-count-corrected per-device
HLO costs recorded by ``dryrun.py``:

    compute term    = HLO_FLOPs / peak_FLOP/s            (197 TF/s bf16)
    memory term     = HLO_bytes / HBM_bw                 (819 GB/s)
    collective term = collective_wire_bytes / link_bw    (50 GB/s ICI)

plus MODEL_FLOPS accounting (6*N*D train / 2*N*D inference; N_active for
MoE), the useful-compute ratio, the dominant bottleneck, and a one-line
recommendation. ``python -m repro.launch.roofline`` prints the table.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List

from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.models.model import Model

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def active_param_count(arch: str) -> int:
    """N_active: MoE expert params scaled by top_k/E."""
    cfg = get_config(arch)
    model = Model(cfg)
    total = model.param_count()
    if cfg.moe is None:
        return total
    import math
    expert_total = 0
    schema = model.schema()
    moe_schema = schema["stack"]["layers"].get("moe", {})
    for k in ("wi", "wg", "wo"):
        if k in moe_schema:
            expert_total += math.prod(moe_schema[k].shape)
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return total - expert_total + int(expert_total * frac)


def model_flops(arch: str, shape_name: str) -> float:
    shape = SHAPES[shape_name]
    n = active_param_count(arch)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def cell_terms(rec: Dict, chips: int) -> Dict:
    compute = rec["flops_per_device"] / PEAK_FLOPS
    memory = rec["bytes_per_device"] / HBM_BW
    collective = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["flops_per_device"] * chips
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "bound_step_s": max(terms.values()),
        "roofline_fraction": (compute / max(terms.values())
                              if max(terms.values()) else 0.0),
    }


_ADVICE = {
    "compute": "compute-bound: raise MXU utilization (layouts, bf16 paths,"
               " larger per-core tiles); already near the useful roofline.",
    "memory": "HBM-bound: cut activation round-trips (fuse mask/softmax"
              " chains, bf16 intermediates, larger attention chunks).",
    "collective": "ICI-bound: overlap collectives with compute, reshard to"
                  " cut all-gathers (SP boundaries), or compress payloads.",
}


def load_cells(mesh: str = "16x16") -> List[Dict]:
    out = []
    for f in sorted(RESULTS_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        out.append(rec)
    return out


def table(mesh: str = "16x16") -> str:
    chips = 512 if mesh == "2x16x16" else 256
    rows = [f"{'arch':<22}{'shape':<13}{'comp_s':>9}{'mem_s':>9}"
            f"{'coll_s':>9}{'domin':>7}{'useful':>8}{'mem_GiB':>9}"]
    for rec in load_cells(mesh):
        name = f"{rec['arch']:<22}{rec['shape']:<13}"
        if rec.get("skipped"):
            rows.append(name + "  SKIP (sub-quadratic-only shape)")
            continue
        if rec.get("error"):
            rows.append(name + f"  ERROR {rec['error'][:60]}")
            continue
        t = cell_terms(rec, chips)
        mem = rec["memory"]["peak_estimate_bytes"] / 2**30
        rows.append(
            f"{name}{t['compute_s']:>9.4f}{t['memory_s']:>9.4f}"
            f"{t['collective_s']:>9.4f}{t['dominant']:>7}"
            f"{t['useful_ratio']:>8.3f}{mem:>9.2f}")
    return "\n".join(rows)


def cell_report(arch: str, shape: str, mesh: str = "16x16") -> str:
    f = RESULTS_DIR / f"{arch}__{shape}__{mesh}.json"
    rec = json.loads(f.read_text())
    if rec.get("skipped") or rec.get("error"):
        return json.dumps(rec, indent=1)
    chips = 512 if mesh == "2x16x16" else 256
    t = cell_terms(rec, chips)
    lines = [
        f"{arch} x {shape} on {mesh} ({chips} chips)",
        f"  compute term    {t['compute_s']:.4f} s "
        f"({rec['flops_per_device']:.3e} flops/dev @197TF/s)",
        f"  memory term     {t['memory_s']:.4f} s "
        f"({rec['bytes_per_device']:.3e} B/dev @819GB/s)",
        f"  collective term {t['collective_s']:.4f} s "
        f"({rec['collective_bytes_per_device']:.3e} B/dev @50GB/s)",
        f"  dominant: {t['dominant']}   roofline fraction "
        f"(compute/bound): {t['roofline_fraction']:.3f}",
        f"  MODEL_FLOPS {t['model_flops']:.3e}  /  HLO_FLOPS "
        f"{t['hlo_flops_total']:.3e}  =  useful ratio "
        f"{t['useful_ratio']:.3f}",
        f"  -> {_ADVICE[t['dominant']]}",
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    if args.arch and args.shape:
        print(cell_report(args.arch, args.shape, args.mesh))
    else:
        print(table(args.mesh))


if __name__ == "__main__":
    main()
