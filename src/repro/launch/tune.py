"""``repro.tune`` — probe-guided kernel autotuning from the command line.

    PYTHONPATH=src python -m repro.tune --kernel flash_attention
    PYTHONPATH=src python -m repro.tune --kernel all --seq 512 \
        --cache-dir .repro_cache/dse --json tune.json

Runs the DSE engine (enumerate -> cost-model prune -> successive-halving
ProbeSession measurement -> incremental eval cache) for each requested
kernel at the given shapes, prints the leaderboard, and leaves the
winners in the on-disk cache where ``serve.py --autotune`` /
``train.py --autotune`` (and ``repro.kernels.tuning.load_cache``) pick
them up.

``--sweep`` switches to the trace-once sweep farm (``core.dse.
run_sweep``): dense config x shape candidate pools captured once as
``KernelTrace`` artifacts, simulator-priced in microseconds, with
device measurement reserved for the per-shape finalists:

    PYTHONPATH=src python -m repro.tune --kernel flash_attention \
        --sweep --sweep-seqs 128,256,512 --workers 4 --top-k 16
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, Optional

from repro.core import DeviceBudget, DSEEngine, EvalCache
from repro.core.dse import run_sweep
from repro.kernels import search_spaces

KERNELS = tuple(search_spaces.SPACES)


def _int_tuple(spec: str) -> tuple:
    return tuple(int(v) for v in spec.split(",") if v.strip())


def sweep_kernel(kernel: str, args: argparse.Namespace,
                 cache: EvalCache) -> Dict[str, Any]:
    shapes = None
    if args.sweep_seqs or args.sweep_heads:
        shapes = search_spaces.sweep_shapes(
            kernel, seqs=_int_tuple(args.sweep_seqs or ""),
            heads=_int_tuple(args.sweep_heads or ""))
    budget: Optional[DeviceBudget] = DeviceBudget(
        vmem_bytes=args.budget_vmem, hbm_bytes=args.budget_hbm,
        flops=args.budget_flops)
    result = run_sweep(
        kernel, shapes, workers=args.workers, top_k=args.top_k,
        steps=args.max_steps, budget=budget, cache=cache,
        calibrate=not args.no_calibrate, walk=args.walk,
        cycle_source=args.cycle_source, reuse_traces=not args.no_reuse)
    print(result.summary())
    return result.to_dict()


def build_space(kernel: str, args: argparse.Namespace):
    if kernel == "flash_attention":
        return search_spaces.flash_attention_space(
            B=args.batch, H=args.heads, S=args.seq, D=args.dim,
            seed=args.seed)
    if kernel == "ssd_scan":
        return search_spaces.ssd_scan_space(
            B=args.batch, H=args.heads, L=args.seq, seed=args.seed)
    if kernel == "paged_attention":
        return search_spaces.paged_attention_space(
            B=args.batch, n_pages=max(1, args.seq // 16), seed=args.seed)
    if kernel == "chunked_prefill":
        return search_spaces.chunked_prefill_space(
            prompt_pages=max(1, args.seq // 64), seed=args.seed)
    raise SystemExit(f"unknown kernel {kernel!r}; choose from "
                     f"{KERNELS + ('all',)}")


def tune_kernel(kernel: str, args: argparse.Namespace,
                cache: EvalCache) -> Dict[str, Any]:
    space = build_space(kernel, args)
    budget: Optional[DeviceBudget] = DeviceBudget(
        vmem_bytes=args.budget_vmem, hbm_bytes=args.budget_hbm,
        flops=args.budget_flops)
    engine = DSEEngine(space, budget=budget, cache=cache,
                       cycle_source=args.cycle_source, r0=args.r0,
                       eta=args.eta, max_steps=args.max_steps)
    result = engine.tune()
    print(result.leaderboard(top=args.top))
    best = result.best
    if best is not None and best.measured:
        print(f"-> best {kernel} config: {best.config} "
              f"({best.cycles_per_step:.0f} cyc/step, "
              f"{result.speedup:.2f}x vs default); cached for --autotune")
    return result.to_dict()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tune",
        description="probe-guided Pallas kernel autotuning (DSE engine)")
    ap.add_argument("--kernel", default="flash_attention",
                    help=f"one of {KERNELS} or 'all'")
    ap.add_argument("--seq", type=int, default=256,
                    help="sequence length to tune at (S / L)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--heads", type=int, default=2)
    ap.add_argument("--dim", type=int, default=64,
                    help="head dim (flash_attention)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache-dir", default=None,
                    help="eval cache dir (default .repro_cache/dse or "
                         "$REPRO_DSE_CACHE)")
    ap.add_argument("--clear-cache", action="store_true",
                    help="drop cached measurements for the kernel(s) first")
    ap.add_argument("--cycle-source", default="model",
                    choices=("model", "wallclock"))
    ap.add_argument("--r0", type=int, default=1,
                    help="successive-halving starting steps per candidate")
    ap.add_argument("--eta", type=int, default=2,
                    help="halving keep-fraction / step-growth factor")
    ap.add_argument("--max-steps", type=int, default=4,
                    help="steps the finalists run")
    ap.add_argument("--budget-vmem", type=int,
                    default=DeviceBudget().vmem_bytes,
                    help="VMEM budget per candidate, bytes")
    ap.add_argument("--budget-hbm", type=int, default=None,
                    help="HBM traffic budget per call, bytes")
    ap.add_argument("--budget-flops", type=int, default=None)
    ap.add_argument("--top", type=int, default=10,
                    help="leaderboard rows to print")
    ap.add_argument("--json", default=None,
                    help="write the full tune result(s) to this path")
    ap.add_argument("--sweep", action="store_true",
                    help="run the trace-once sweep farm instead of "
                         "successive halving")
    ap.add_argument("--workers", type=int, default=2,
                    help="sweep worker processes (<=1 runs inline)")
    ap.add_argument("--top-k", type=int, default=16,
                    help="sweep: total device-measured finalists across "
                         "shapes (>=2 per shape)")
    ap.add_argument("--sweep-seqs", default=None,
                    help="sweep: comma-separated sequence lengths "
                         "(S / L / n_pages)")
    ap.add_argument("--sweep-heads", default=None,
                    help="sweep: comma-separated head counts")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="sweep: skip the grid-step calibration run")
    ap.add_argument("--walk", action="store_true",
                    help="sweep: also capture walked (sim-mode) grid "
                         "totals per candidate (slower capture)")
    ap.add_argument("--no-reuse", action="store_true",
                    help="sweep: ignore stored trace artifacts")
    args = ap.parse_args(argv)

    kernels = list(KERNELS) if args.kernel == "all" else [args.kernel]
    cache = EvalCache(args.cache_dir)
    results = {}
    for kernel in kernels:
        if args.clear_cache:
            n = cache.clear(kernel)
            print(f"# cleared {n} cached entries for {kernel}")
        results[kernel] = (sweep_kernel(kernel, args, cache) if args.sweep
                          else tune_kernel(kernel, args, cache))
        print()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
