"""Conformance tooling: seeded random model graphs + invariant harness.

``graphgen`` turns integer seeds into jittable model graphs described
by JSON-round-trippable ``GraphSpec``s; ``conformance`` asserts the six
probe exactness invariants on any spec; ``sweep`` runs seed corpora and
prints ready-to-paste repro commands for failures; ``faults`` is the
deterministic fault-injection driver that locks the telemetry drift
sentinel's detection claims.
"""
from repro.testing.graphgen import (BlockSpec, GraphSpec, build,
                                    random_spec)
from repro.testing.conformance import (INVARIANTS, ConformanceError,
                                       repro_command, run_conformance)
from repro.testing.faults import (FakeClock, FaultDriver, RampFault,
                                  StepFault, StragglerFault)

__all__ = [
    "BlockSpec", "GraphSpec", "build", "random_spec",
    "INVARIANTS", "ConformanceError", "repro_command", "run_conformance",
    "FakeClock", "FaultDriver", "RampFault", "StepFault", "StragglerFault",
]
