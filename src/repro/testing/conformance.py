"""Single-call conformance harness: every exactness contract, one graph.

``run_conformance(spec)`` drives one :class:`~repro.testing.graphgen.
GraphSpec` through the full probe pipeline and asserts the six
invariants the suite otherwise enforces piecemeal:

1. **bit-identity** — probed outputs equal ``jax.jit(fn)`` outputs
   bit-for-bit (the paper's non-intrusiveness claim).
2. **telescoping** — decoded intervals nest: ``0 <= start <= end <=
   cycle``, every ring row has ``s <= e``, fully-observed histories sum
   exactly to the probe's total, ancestors bound descendants.
3. **oracle equality** — device counters equal the independent Python
   re-interpreter integer-for-integer (Table II, 100% accuracy).
4. **packed == legacy** — both state layouts decode to the same record.
5. **session exactness** — N identical ``ProbeSession`` steps aggregate
   to exactly N x the one-shot counters.
6. **overhead bound** — the fitted :class:`~repro.core.overhead.
   OverheadModel` predicts instrumented-eqn deltas within tolerance.

Failures raise :class:`ConformanceError` carrying the spec JSON and a
ready-to-paste repro command, so a CI line is a full reproduction.

CLI (the repro command format printed on failure)::

    PYTHONPATH=src python -m repro.testing.conformance --seed 1234
    PYTHONPATH=src python -m repro.testing.conformance --spec '<json>'
"""
from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.testing.graphgen import GraphSpec, build, random_spec

INVARIANTS = ("bit_identity", "telescoping", "oracle_equality",
              "packed_vs_legacy", "session_exactness", "overhead_bound")

# overhead-model tolerance: relative to the measured delta with an
# absolute floor (tiny graphs have single-digit extra-eqn counts)
OVERHEAD_REL_TOL = 0.15
OVERHEAD_ABS_TOL = 8.0
SESSION_STEPS = 3


def repro_command(spec: GraphSpec) -> str:
    return ("PYTHONPATH=src python -m repro.testing.conformance "
            f"--seed {spec.seed}")


class ConformanceError(AssertionError):
    """One invariant failed; message embeds seed, spec and repro cmd."""

    def __init__(self, spec: GraphSpec, invariant: str, detail: str):
        self.spec = spec
        self.invariant = invariant
        super().__init__(
            f"conformance invariant {invariant!r} failed for seed "
            f"{spec.seed}\n  detail: {detail}\n  spec: {spec.to_json()}\n"
            f"  repro: {repro_command(spec)}")


def _check(spec: GraphSpec, invariant: str, ok: bool, detail: str):
    if not ok:
        raise ConformanceError(spec, invariant, detail)


# ----------------------------------------------------------- invariants

def _full_durations(pf, dec, pid: int) -> Optional[List[int]]:
    """Per-call durations for probe ``pid`` when every call was observed
    (spilled rings reassembled from the sink + in-ring remainder; else
    only when the ring never wrapped). None = partially observed."""
    asg = pf.assignment
    calls = int(dec["calls"][pid])
    ring = np.asarray(dec["ring"][pid])
    if asg.spill[pid]:
        durs = [int(e) - int(s) for s, e in pf.sink.records(pid)]
        rem = calls % asg.depth
        durs += [int(e) - int(s) for s, e in ring[:rem]]
        return durs
    if calls <= asg.depth:
        return [int(e) - int(s) for s, e in ring[:calls]]
    return None


def check_bit_identity(spec: GraphSpec, fn, args, pf, out) -> None:
    import jax
    out0 = jax.jit(fn)(*args)
    leaves = jax.tree_util.tree_leaves(out)
    leaves0 = jax.tree_util.tree_leaves(out0)
    _check(spec, "bit_identity", len(leaves) == len(leaves0),
           f"leaf count {len(leaves)} != {len(leaves0)}")
    for i, (a, b) in enumerate(zip(leaves, leaves0)):
        _check(spec, "bit_identity",
               np.array_equal(np.asarray(a), np.asarray(b)),
               f"output leaf {i} differs: probed={a!r} unprobed={b!r}")


def check_telescoping(spec: GraphSpec, pf, dec) -> None:
    cycle = int(dec["cycle"])
    paths = pf.probe_paths()
    _check(spec, "telescoping", cycle >= 0, f"negative cycle {cycle}")
    for i, p in enumerate(paths):
        calls = int(dec["calls"][i])
        s, e, t = int(dec["starts"][i]), int(dec["ends"][i]), \
            int(dec["totals"][i])
        if calls == 0:
            _check(spec, "telescoping", (s, e, t) == (0, 0, 0),
                   f"{p}: uncalled probe has nonzero counters {(s, e, t)}")
            continue
        _check(spec, "telescoping", 0 <= s <= e <= cycle,
               f"{p}: interval [{s}, {e}] outside [0, {cycle}]")
        _check(spec, "telescoping", 0 <= t <= cycle,
               f"{p}: total {t} outside [0, {cycle}]")
        durs = _full_durations(pf, dec, i)
        ring = np.asarray(dec["ring"][i])
        for rs, re_ in ring[:min(calls, pf.assignment.depth)]:
            _check(spec, "telescoping", int(rs) <= int(re_),
                   f"{p}: ring row [{int(rs)}, {int(re_)}] reversed")
        if durs is not None:
            _check(spec, "telescoping", len(durs) == calls,
                   f"{p}: {len(durs)} observed durations != {calls} calls")
            _check(spec, "telescoping", sum(durs) == t,
                   f"{p}: observed durations sum {sum(durs)} != total {t}")
        # ancestors bound descendants (same clock, nested scopes)
        for j, q in enumerate(paths):
            if q.startswith(p + "/") and int(dec["calls"][j]) > 0:
                _check(spec, "telescoping",
                       int(dec["totals"][j]) <= t,
                       f"{q}: child total {int(dec['totals'][j])} > "
                       f"parent {p} total {t}")
                _check(spec, "telescoping",
                       int(dec["starts"][j]) >= s and
                       int(dec["ends"][j]) <= e,
                       f"{q}: child interval escapes parent {p}")


def check_oracle_equality(spec: GraphSpec, pf, dec, args) -> None:
    oc = pf.oracle(*args)
    for i, p in enumerate(pf.probe_paths()):
        for key, ov in (("totals", oc.totals[i]), ("calls", oc.calls[i]),
                        ("starts", oc.starts[i]), ("ends", oc.ends[i])):
            _check(spec, "oracle_equality", int(dec[key][i]) == ov,
                   f"{p}: device {key}={int(dec[key][i])} != oracle {ov}")
    _check(spec, "oracle_equality", int(dec["cycle"]) == oc.cycle,
           f"cycle: device {int(dec['cycle'])} != oracle {oc.cycle}")
    if spec.has_kernel:
        # KernelOracle view: grid rows must cover their kernel scope.
        # A saturated probe budget may legitimately prune the grid
        # candidate (the allocator prefers outer scopes); only when
        # slots remained free is a missing grid probe an instrumenter
        # gap rather than an allocation decision.
        grid_pids = [i for i, p in enumerate(pf.probe_paths())
                     if p.endswith("/grid")]
        budget_full = pf.assignment.n >= spec.max_probes
        _check(spec, "oracle_equality", grid_pids or budget_full,
               "kernel graph produced no grid probes despite free slots")
        for i in grid_pids:
            _check(spec, "oracle_equality", oc.calls[i] > 0,
                   f"{pf.probe_paths()[i]}: grid probe never entered")


def check_packed_vs_legacy(spec: GraphSpec, fn, args, dec) -> None:
    import jax
    from repro.core import probe
    from repro.core.instrument import decode_record
    pf2 = probe(fn, spec.probe_config().replace(layout="legacy"))
    _, rec2 = pf2(*args)
    dec2 = decode_record(jax.device_get(rec2))
    for key in ("cycle", "starts", "ends", "totals", "calls", "ring"):
        _check(spec, "packed_vs_legacy",
               np.array_equal(np.asarray(dec[key]), np.asarray(dec2[key])),
               f"decoded {key!r} differs between packed and legacy")


def check_session_exactness(spec: GraphSpec, fn, args, dec,
                            steps: int = SESSION_STEPS) -> None:
    from repro.core import ProbeSession
    from repro.core.streaming import StreamSnapshot  # noqa: F401 (doc)
    with ProbeSession(fn, spec.probe_config().replace(offload=1.0)) as s:
        for _ in range(steps):
            s.step(*args)
        snap = s.snapshot()
    for pid, path in enumerate(snap.paths):
        row = snap.rows[pid]
        want_calls = steps * int(dec["calls"][pid])
        want_total = steps * int(dec["totals"][pid])
        _check(spec, "session_exactness", row.calls == want_calls,
               f"{path}: session calls {row.calls} != "
               f"{steps} x one-shot {int(dec['calls'][pid])}")
        _check(spec, "session_exactness", row.total_cycles == want_total,
               f"{path}: session total {row.total_cycles} != "
               f"{steps} x one-shot {int(dec['totals'][pid])}")


def check_overhead_bound(spec: GraphSpec, fn, args) -> int:
    from repro.core.overhead import OverheadModel, measure_overhead
    base = spec.probe_config()
    variants = [base.replace(max_probes=m) for m in (2, 3, 4, 6)]
    variants.append(base.replace(max_probes=50, buffer_depth=2))
    variants.append(base)
    samples = [measure_overhead(fn, args, v) for v in variants]
    model = OverheadModel.fit(samples)
    for v, smp in zip(variants, samples):
        pred = model.predict_eqns(smp)
        actual = float(smp["extra_eqns"])
        tol = max(OVERHEAD_REL_TOL * abs(actual), OVERHEAD_ABS_TOL)
        _check(spec, "overhead_bound", abs(pred - actual) <= tol,
               f"max_probes={v.max_probes} depth={v.buffer_depth}: "
               f"predicted {pred:.1f} vs measured {actual:.0f} "
               f"(tol {tol:.1f})")
    return len(samples)


# -------------------------------------------------------------- harness

def run_conformance(spec: GraphSpec,
                    invariants: Sequence[str] = INVARIANTS
                    ) -> Dict[str, Any]:
    """Assert the selected invariants for one graph; returns summary
    stats (probe count, cycle span, invariants checked) on success."""
    import jax
    from repro.core import probe
    from repro.core.instrument import decode_record

    unknown = set(invariants) - set(INVARIANTS)
    if unknown:
        raise ValueError(f"unknown invariants: {sorted(unknown)}")
    fn, args = build(spec)
    pf = probe(fn, spec.probe_config())
    out, rec = pf(*args)
    dec = decode_record(jax.device_get(rec))
    checked: List[str] = []
    if "bit_identity" in invariants:
        check_bit_identity(spec, fn, args, pf, out)
        checked.append("bit_identity")
    if "telescoping" in invariants:
        check_telescoping(spec, pf, dec)
        checked.append("telescoping")
    if "oracle_equality" in invariants:
        check_oracle_equality(spec, pf, dec, args)
        checked.append("oracle_equality")
    if "packed_vs_legacy" in invariants:
        check_packed_vs_legacy(spec, fn, args, dec)
        checked.append("packed_vs_legacy")
    if "session_exactness" in invariants:
        check_session_exactness(spec, fn, args, dec)
        checked.append("session_exactness")
    if "overhead_bound" in invariants:
        check_overhead_bound(spec, fn, args)
        checked.append("overhead_bound")
    return {
        "seed": spec.seed,
        "n_probes": pf.assignment.n,
        "cycle": int(dec["cycle"]),
        "has_kernel": spec.has_kernel,
        "invariants": tuple(checked),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    g = ap.add_mutually_exclusive_group(required=True)
    g.add_argument("--seed", type=int, help="run random_spec(seed)")
    g.add_argument("--spec", type=str, help="run an explicit GraphSpec "
                                            "JSON document")
    ap.add_argument("--invariants", type=str, default=",".join(INVARIANTS),
                    help="comma-separated subset to check")
    args = ap.parse_args(argv)
    spec = (GraphSpec.from_json(args.spec) if args.spec is not None
            else random_spec(args.seed))
    inv = tuple(s for s in args.invariants.split(",") if s)
    try:
        stats = run_conformance(spec, inv)
    except ConformanceError as e:
        print(e, file=sys.stderr)
        return 1
    print(f"seed {stats['seed']}: OK — {stats['n_probes']} probes, "
          f"{stats['cycle']} cycles, "
          f"invariants: {', '.join(stats['invariants'])}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
