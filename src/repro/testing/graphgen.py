"""Seeded random model-graph generator (the SPRING-style sweep subject).

RealProbe's evaluation hand-picks 28 designs; profiling *randomly
interconnected* networks is what exposes the topologies hand-picked
benchmarks miss. This module turns a single integer seed into a
jittable function whose structure is drawn from the real model building
blocks (``models/attention.py``, ``models/ssm.py``, ``models/moe.py``,
``models/layers.py``) composed under randomized control flow
(``lax.scan``, ``jax.checkpoint``, ``lax.cond``, ``lax.while_loop``,
nested ``jax.jit`` and optionally probed ``pallas_call`` kernels from
``kernels/ops.py``).

Every graph is fully described by a :class:`GraphSpec` that round-trips
through JSON, so any conformance failure is reproducible from its seed:

    spec = random_spec(1234)
    fn, args = build(spec)
    assert GraphSpec.from_json(spec.to_json()) == spec
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.pragma import ProbeConfig

# block families drawn from the real model code (KERNEL_KINDS execute a
# pallas_call in interpret mode and force kernel grid-step probing)
BLOCK_KINDS = ("mlp", "attn", "ssm", "moe", "elementwise")
KERNEL_KINDS = ("flash_kernel", "ssd_kernel")
WRAPPERS = ("none", "scan", "remat", "cond", "jit", "while", "scan_cond")
# wrappers safe around a pallas_call (kept conservative: the kernel body
# is itself a grid loop; scan/while around it multiply interpret cost)
KERNEL_WRAPPERS = ("none", "jit")


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One randomly drawn block: a building-block kind plus the control
    flow construct wrapped around it (``length`` = scan/while trips)."""
    kind: str
    wrapper: str = "none"
    length: int = 1

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    """Complete, JSON-serializable description of one random graph.

    ``seed`` drives both the structure draw (``random_spec``) and the
    parameter/input values (``build``), so the spec alone reproduces the
    exact program AND the exact data of a failing conformance run.
    """
    seed: int
    batch: int = 2
    seq: int = 16
    d_model: int = 16
    blocks: Tuple[BlockSpec, ...] = ()
    buffer_depth: int = 4
    offload: float = 0.0
    max_probes: int = 50

    @property
    def has_kernel(self) -> bool:
        return any(b.kind in KERNEL_KINDS for b in self.blocks)

    def probe_config(self) -> ProbeConfig:
        return ProbeConfig(inline="off_all",
                           buffer_depth=self.buffer_depth,
                           offload=self.offload,
                           max_probes=self.max_probes,
                           kernel_probes=("*",) if self.has_kernel else ())

    # ------------------------------------------------- JSON round-trip
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["blocks"] = [b.to_dict() for b in self.blocks]
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "GraphSpec":
        d = dict(d)
        d["blocks"] = tuple(BlockSpec(**b) for b in d.get("blocks", ()))
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "GraphSpec":
        return cls.from_dict(json.loads(s))


def random_spec(seed: int, *, max_blocks: int = 5,
                allow_kernels: bool = True) -> GraphSpec:
    """Deterministically draw a GraphSpec from an integer seed.

    Uses ``random.Random`` (not numpy / jax) so structure draws are
    stable across library versions. At most one kernel block per graph
    keeps interpret-mode pallas cost bounded.
    """
    rng = random.Random(int(seed))
    batch = rng.choice((1, 2))
    seq = rng.choice((16, 32))
    d_model = rng.choice((16, 32))
    n_blocks = rng.randint(2, max_blocks)
    blocks: List[BlockSpec] = []
    kernel_used = False
    for _ in range(n_blocks):
        if allow_kernels and not kernel_used and rng.random() < 0.2:
            kind = rng.choice(KERNEL_KINDS)
            kernel_used = True
            wrapper = rng.choice(KERNEL_WRAPPERS)
            length = 1
        else:
            kind = rng.choice(BLOCK_KINDS)
            wrapper = rng.choice(WRAPPERS)
            length = rng.randint(2, 3) if wrapper in ("scan", "while",
                                                      "scan_cond") else 1
        blocks.append(BlockSpec(kind=kind, wrapper=wrapper, length=length))
    return GraphSpec(
        seed=int(seed), batch=batch, seq=seq, d_model=d_model,
        blocks=tuple(blocks),
        buffer_depth=rng.choice((2, 4)),
        offload=rng.choice((0.0, 1.0)),
        max_probes=rng.choice((16, 50)),
    )


# ------------------------------------------------------------ builders

def _moe_cfg(d_model: int):
    """Tiny MoE ModelConfig for the standalone `_moe_local` body (the
    capacity impl with generous capacity so no token is dropped)."""
    from repro.configs.registry import smoke_config
    cfg = smoke_config("granite-moe-1b-a400m")
    return cfg.replace(
        d_model=d_model,
        moe=dataclasses.replace(cfg.moe, impl="capacity",
                                capacity_factor=8.0, dense_residual=False))


def _block_params(spec: GraphSpec, i: int, kind: str, key) -> Dict[str, Any]:
    D = spec.d_model
    F = 2 * D
    ks = jax.random.split(key, 8)
    s = 1.0 / jnp.sqrt(jnp.float32(D))

    def w(k, shape, scale=None):
        sc = s if scale is None else scale
        return jax.random.normal(k, shape, jnp.float32) * sc

    if kind == "mlp":
        return {"wi": w(ks[0], (D, F)), "wg": w(ks[1], (D, F)),
                "wo": w(ks[2], (F, D), 1.0 / jnp.sqrt(jnp.float32(F)))}
    if kind in ("attn", "flash_kernel"):
        return {"wq": w(ks[0], (D, D)), "wk": w(ks[1], (D, D)),
                "wv": w(ks[2], (D, D)), "wo": w(ks[3], (D, D))}
    if kind in ("ssm", "ssd_kernel"):
        N = 8
        return {"wx": w(ks[0], (D, D)), "wa": w(ks[1], (D, 2)),
                "wb": w(ks[2], (D, N)), "wc": w(ks[3], (D, N)),
                "wo": w(ks[4], (D, D))}
    if kind == "moe":
        E, FF = 4, 16
        return {"router": w(ks[0], (D, E)),
                "wi": w(ks[1], (E, D, FF)), "wg": w(ks[2], (E, D, FF)),
                "wo": w(ks[3], (E, FF, D),
                        1.0 / jnp.sqrt(jnp.float32(FF)))}
    if kind == "elementwise":
        return {"scale": jnp.zeros((D,), jnp.float32),
                "gate": w(ks[0], (D, D))}
    raise ValueError(f"unknown block kind {kind!r}")


def _apply_block(kind: str, p: Dict[str, Any], x, spec: GraphSpec):
    """x: (B, S, D) -> (B, S, D), contractive (bounded activations +
    damped residual) so stacked/looped blocks stay numerically tame."""
    B, S, D = x.shape
    if kind == "mlp":
        from repro.models.layers import mlp_apply
        return x + 0.5 * mlp_apply(p, jnp.tanh(x))
    if kind == "attn":
        from repro.models.attention import causal_flash_xla
        H, HD = 2, D // 2
        q = (x @ p["wq"]).reshape(B, S, H, HD)
        k = (x @ p["wk"]).reshape(B, S, H, HD)
        v = (x @ p["wv"]).reshape(B, S, H, HD)
        o = causal_flash_xla(q, k, v, S // 2, S // 2)
        return x + 0.5 * (o.reshape(B, S, D) @ p["wo"])
    if kind == "flash_kernel":
        from repro.kernels import ops as kops
        H, HD = 2, D // 2
        q = (x @ p["wq"]).reshape(B, S, H, HD).transpose(0, 2, 1, 3)
        k = (x @ p["wk"]).reshape(B, S, H, HD).transpose(0, 2, 1, 3)
        v = (x @ p["wv"]).reshape(B, S, H, HD).transpose(0, 2, 1, 3)
        o = kops.flash_attention(q, k, v, causal=True, block_q=S // 2,
                                 block_k=S // 2, pipeline=1, interpret=True)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, D)
        return x + 0.5 * (o @ p["wo"])
    if kind == "ssm":
        from repro.models.ssm import ssd_chunked_xla
        H, P = 2, D // 2
        xs = jnp.tanh(x @ p["wx"]).reshape(B, S, H, P)
        a = -jnp.abs(x @ p["wa"]) * 0.2                      # (B, S, H)
        b = (x @ p["wb"])[:, :, None, :] * 0.5               # (B, S, 1, N)
        c = (x @ p["wc"])[:, :, None, :] * 0.5
        y = ssd_chunked_xla(xs, a, b, c, chunk=S // 2, h_per_g=H)
        return x + 0.5 * (y.reshape(B, S, D) @ p["wo"])
    if kind == "ssd_kernel":
        from repro.kernels import ops as kops
        H, P = 2, D // 2
        xs = jnp.tanh(x @ p["wx"]).reshape(B, S, H, P)
        a = -jnp.abs(x @ p["wa"]) * 0.2
        b = (x @ p["wb"])[:, :, None, :] * 0.5
        c = (x @ p["wc"])[:, :, None, :] * 0.5
        y = kops.ssd_scan(xs, a, b, c, chunk=S // 2, pipeline=1,
                          interpret=True)
        return x + 0.5 * (y.reshape(B, S, D) @ p["wo"])
    if kind == "moe":
        from repro.models.moe import _moe_local
        cfg = _moe_cfg(D)
        out, aux = _moe_local(jnp.tanh(x), p["router"], p["wi"], p["wg"],
                              p["wo"], cfg)
        return x + 0.5 * out + 0.0 * aux
    if kind == "elementwise":
        from repro.models.layers import rmsnorm
        y = rmsnorm(x, p["scale"], 1e-6)
        return x + 0.5 * jnp.tanh(y @ p["gate"]) * jax.nn.sigmoid(y)
    raise ValueError(f"unknown block kind {kind!r}")


def _apply_wrapped(blk: BlockSpec, p: Dict[str, Any], x, spec: GraphSpec):
    def body(v):
        return _apply_block(blk.kind, p, v, spec)

    if blk.wrapper == "none":
        return body(x)
    if blk.wrapper == "scan":
        def sbody(c, _):
            with jax.named_scope("step"):
                return body(c), None
        y, _ = jax.lax.scan(sbody, x, None, length=blk.length)
        return y
    if blk.wrapper == "remat":
        return jax.checkpoint(body)(x)
    if blk.wrapper == "jit":
        return jax.jit(body)(x)
    if blk.wrapper == "cond":
        def heavy(v):
            with jax.named_scope("heavy"):
                return body(v)

        def light(v):
            with jax.named_scope("light"):
                return v * 1.01
        return jax.lax.cond(jnp.sum(x) > 0, heavy, light, x)
    if blk.wrapper == "while":
        def wcond(s):
            return s[1] < blk.length

        def wbody(s):
            with jax.named_scope("iter"):
                return body(s[0]), s[1] + 1
        y, _ = jax.lax.while_loop(wcond, wbody, (x, jnp.int32(0)))
        return y
    if blk.wrapper == "scan_cond":
        # the lax.cond-under-scan composition called out in the issue:
        # a per-iteration data-dependent branch inside a probed loop
        def sbody(c, _):
            def heavy(v):
                with jax.named_scope("heavy"):
                    return body(v)

            def light(v):
                with jax.named_scope("light"):
                    return v * 1.01
            with jax.named_scope("step"):
                c = jax.lax.cond(jnp.sum(c) > 0, heavy, light, c)
            return c, None
        y, _ = jax.lax.scan(sbody, x, None, length=blk.length)
        return y
    raise ValueError(f"unknown wrapper {blk.wrapper!r}")


def build(spec: GraphSpec):
    """Materialize ``spec`` into ``(fn, args)``: a jittable function
    plus deterministic concrete inputs. ``fn(x, params)`` returns a
    scalar so probed-vs-unprobed bit-identity is a one-leaf compare of
    the full dataflow."""
    key = jax.random.PRNGKey(spec.seed)
    params = [_block_params(spec, i, b.kind, jax.random.fold_in(key, i))
              for i, b in enumerate(spec.blocks)]
    x0 = (jax.random.normal(jax.random.fold_in(key, 10_007),
                            (spec.batch, spec.seq, spec.d_model),
                            jnp.float32) * 0.1)

    def fn(x, params):
        for i, blk in enumerate(spec.blocks):
            with jax.named_scope(f"b{i}_{blk.kind}"):
                x = _apply_wrapped(blk, params[i], x, spec)
        with jax.named_scope("head"):
            return jnp.sum(x * x)

    return fn, (x0, params)
