"""Fault-injection harness for the telemetry drift sentinel.

A sentinel validated only on happy-path traffic is a sentinel that has
never been tested (SPRING's systematic-profiling framing, PAPERS.md):
the detection claims that matter are *injected-fault* claims — every
planted drift is caught, named correctly, within a bounded number of
windows, and stationary traffic never alerts.  This module provides
the deterministic traffic driver those claims are asserted against
(``tests/test_telemetry.py``):

- :class:`FakeClock` — a manually advanced cycle clock, so runs are
  time-independent and replayable.
- Fault specs — :class:`StepFault` (sudden sustained shift),
  :class:`RampFault` (compounding multiplicative creep), and
  :class:`StragglerFault` (one device of a device-major stream slows).
- :class:`FaultDriver` — generates seeded synthetic per-call cycle
  durations window by window, applies the active fault factors,
  publishes them to a :class:`~repro.telemetry.bus.ProbeStream`, and
  rolls the window.  Same seed ⇒ identical durations, regardless of
  the publishing ``chunk`` size (the sentinel chunking-invariance
  property rides on this).

Baseline durations default to bucket-interior values (the uniform
jitter band stays inside one log₂ bucket), making the zero-false-
positive sweep exact rather than probabilistic; pass ``base`` values
near a power of two to exercise edge-straddling traffic too.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.telemetry.bus import TelemetryBus, WindowFrame


class FakeClock:
    """Deterministic cycle clock: advances only when told to."""

    def __init__(self, start: int = 0):
        self.cycles = int(start)

    def now(self) -> int:
        return self.cycles

    def advance(self, cycles: int) -> int:
        self.cycles += int(cycles)
        return self.cycles


@dataclass(frozen=True)
class StepFault:
    """From ``at_window`` on, ``path``'s durations are ``factor``×."""
    path: str
    at_window: int
    factor: float = 3.0

    def scale(self, path: str, device: int, window: int) -> float:
        return self.factor if path == self.path \
            and window >= self.at_window else 1.0


@dataclass(frozen=True)
class RampFault:
    """From ``start_window`` on, ``path``'s durations compound by
    ``rate``× every window — the slow-creep regression."""
    path: str
    start_window: int
    rate: float = 1.25

    def scale(self, path: str, device: int, window: int) -> float:
        if path != self.path or window < self.start_window:
            return 1.0
        return self.rate ** (window - self.start_window + 1)


@dataclass(frozen=True)
class StragglerFault:
    """From ``at_window`` on, every probe on ``device`` runs
    ``factor``× slow (device-major streams only)."""
    device: int
    at_window: int
    factor: float = 3.0
    path: Optional[str] = None        # restrict to one probe if set

    def scale(self, path: str, device: int, window: int) -> float:
        if device != self.device or window < self.at_window:
            return 1.0
        return self.factor if self.path in (None, path) else 1.0


Fault = Union[StepFault, RampFault, StragglerFault]


class FaultDriver:
    """Seeded synthetic traffic generator over one bus stream.

    Each window publishes ``samples_per_window`` per-call durations per
    (device, probe) row — ``base[path] × fault factors × uniform
    jitter`` — then rolls the window, waking every bus window
    subscriber (the sentinel).  Fully deterministic in ``seed``.
    """

    def __init__(self, bus: TelemetryBus, *, source: str = "drive",
                 paths: Sequence[str] = ("attn", "mlp"),
                 n_devices: int = 1, seed: int = 0,
                 samples_per_window: int = 64, jitter: float = 0.1,
                 base: Optional[Dict[str, int]] = None,
                 faults: Sequence[Fault] = (), chunk: Optional[int] = None,
                 clock: Optional[FakeClock] = None):
        self.bus = bus
        self.paths = tuple(paths)
        self.n_devices = int(n_devices)
        self.stream = bus.stream(source, self.paths, n_devices=n_devices)
        self.rng = np.random.default_rng(seed)
        self.samples = int(samples_per_window)
        self.jitter = float(jitter)
        # defaults sit mid-bucket: base*(1±jitter) spans no log₂ edge,
        # so stationary traffic is *exactly* stationary bucket-wise
        self.base = dict(base) if base else {
            p: 700 * (3 ** i) for i, p in enumerate(self.paths)}
        self.faults = tuple(faults)
        self.chunk = chunk
        self.clock = clock or FakeClock()
        self.windows_run = 0
        self.frames: List[WindowFrame] = []

    def factor(self, path: str, device: int, window: int) -> float:
        f = 1.0
        for fault in self.faults:
            f *= fault.scale(path, device, window)
        return f

    def _durations(self, path: str, device: int, window: int) -> np.ndarray:
        base = self.base[path] * self.factor(path, device, window)
        jit = self.rng.uniform(1.0 - self.jitter, 1.0 + self.jitter,
                               self.samples)
        return np.maximum(1, np.round(base * jit)).astype(np.int64)

    def run(self, n_windows: int) -> List[WindowFrame]:
        """Drive ``n_windows`` windows; returns their frames (also
        accumulated on ``self.frames``)."""
        out = []
        for _ in range(n_windows):
            w = self.windows_run
            for d in range(self.n_devices):
                for p, path in enumerate(self.paths):
                    durs = self._durations(path, d, w)
                    row = d * len(self.paths) + p
                    step = self.chunk or len(durs)
                    for i in range(0, len(durs), step):
                        self.stream.add(row, durs[i:i + step])
                    self.clock.advance(int(durs.sum()))
            frame = self.stream.roll(w * self.samples,
                                     (w + 1) * self.samples)
            out.append(frame)
            self.windows_run += 1
        self.frames.extend(out)
        return out
