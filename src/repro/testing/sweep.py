"""Seeded conformance sweep runner (the nightly 200-graph corpus).

Runs ``run_conformance`` over a contiguous seed range and prints one
line per graph; every failure ends with a ready-to-paste repro command
so a red nightly log is a complete bug report::

    PYTHONPATH=src python -m repro.testing.sweep --start 0 --count 200
    PYTHONPATH=src python -m repro.testing.sweep --count 8 \
        --invariants bit_identity,oracle_equality

Exit status is the number of failing seeds (capped at 99), so CI can
gate directly on the process result.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.testing.conformance import (INVARIANTS, ConformanceError,
                                       repro_command, run_conformance)
from repro.testing.graphgen import random_spec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--start", type=int, default=0,
                    help="first seed (default 0)")
    ap.add_argument("--count", type=int, default=200,
                    help="number of consecutive seeds (default 200)")
    ap.add_argument("--invariants", type=str, default=",".join(INVARIANTS),
                    help="comma-separated invariant subset")
    ap.add_argument("--max-blocks", type=int, default=5,
                    help="max blocks per generated graph")
    ap.add_argument("--keep-going", action="store_true", default=True,
                    help="run every seed even after failures (default)")
    args = ap.parse_args(argv)
    inv = tuple(s for s in args.invariants.split(",") if s)
    failures = []
    t0 = time.time()
    for seed in range(args.start, args.start + args.count):
        spec = random_spec(seed, max_blocks=args.max_blocks)
        t = time.time()
        try:
            stats = run_conformance(spec, inv)
            print(f"seed {seed}: OK — {stats['n_probes']} probes, "
                  f"{stats['cycle']} cycles ({time.time() - t:.1f}s)",
                  flush=True)
        except ConformanceError as e:
            failures.append(seed)
            print(f"seed {seed}: FAIL [{e.invariant}]\n{e}", flush=True)
    n = args.count
    print(f"\n{n - len(failures)}/{n} graphs passed "
          f"({time.time() - t0:.1f}s total)")
    if failures:
        print("failing seeds and repro commands:")
        for seed in failures:
            print(f"  seed {seed}: {repro_command(random_spec(seed))}")
    return min(len(failures), 99)


if __name__ == "__main__":
    sys.exit(main())
