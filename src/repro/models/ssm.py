"""Mamba-2 (SSD — state-space duality) block. [arXiv:2405.21060]

Chunked SSD algorithm: intra-chunk quadratic ("attention-like") term plus
an inter-chunk linear state recurrence (lax.scan over chunks). The XLA
path below is the lowering/dry-run implementation; the Pallas kernel in
``repro.kernels.ssd_scan`` implements the intra-chunk hot loop with VMEM
tiling and is validated against ``kernels.ref`` in interpret mode.

Layout:
    x (b, l, h, p)   h = heads, p = head_dim
    A (b, l, h)      discretized log-decay (dt * A)
    B (b, l, g, n)   g = groups (GQA-style shared B/C), n = d_state
    C (b, l, g, n)
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import Param, rmsnorm


def ssm_dims(cfg: ModelConfig) -> Dict[str, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + heads
    return dict(d_inner=d_inner, heads=heads, conv_dim=conv_dim,
                d_in_proj=d_in_proj, d_state=s.d_state, groups=s.n_groups,
                head_dim=s.head_dim, conv_kernel=s.conv_kernel,
                chunk=s.chunk_size)


def ssm_schema(cfg: ModelConfig) -> Dict[str, Param]:
    d = ssm_dims(cfg)
    return {
        "in_proj": Param((cfg.d_model, d["d_in_proj"]), ("embed", "ssm_inner")),
        "conv_w": Param((d["conv_kernel"], d["conv_dim"]), ("conv", "ssm_inner")),
        "conv_b": Param((d["conv_dim"],), ("ssm_inner",), init="zeros"),
        "a_log": Param((d["heads"],), ("ssm_heads",), init="ssm_a"),
        "d_skip": Param((d["heads"],), ("ssm_heads",), init="ones"),
        "dt_bias": Param((d["heads"],), ("ssm_heads",), init="ssm_dt"),
        "norm": Param((d["d_inner"],), ("ssm_inner",), init="zeros"),
        "out_proj": Param((d["d_inner"], cfg.d_model), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv along seq via K static shifts.

    x: (B, S, C); w: (K, C); b: (C,). Cheap (K<=4) and layout-friendly.
    """
    K = w.shape[0]
    out = x * w[-1]
    for k in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :-k]
        out = out + shifted * w[-1 - k]
    return out + b


def _segsum_exp(a_cs):
    """a_cs: (..., q) inclusive cumsum -> exp lower-tri decay (..., q, q)."""
    q = a_cs.shape[-1]
    seg = a_cs[..., :, None] - a_cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(seg), 0.0)


def ssd_chunked_xla(x, a, b, c, chunk: int, h_per_g: int,
                    initial_state=None, return_final_state: bool = False):
    """Chunked SSD scan (pure XLA).

    x: (B, L, h, p) — already discretized (x * dt)
    a: (B, L, h)    — discretized log decay (A * dt), <= 0
    b, c: (B, L, g, n) with h = g * h_per_g
    Returns y (B, L, h, p) [, final_state (B, g, e, p, n)].
    """
    B, L, H, Pd = x.shape
    G, N = b.shape[2], b.shape[3]
    E = h_per_g
    if L % chunk:
        raise ValueError(f"L {L} % chunk {chunk}")
    C_ = L // chunk
    xe = x.reshape(B, C_, chunk, G, E, Pd)
    ae = a.reshape(B, C_, chunk, G, E).transpose(0, 3, 4, 1, 2)  # (B,G,E,C,Q)
    be = b.reshape(B, C_, chunk, G, N)
    ce = c.reshape(B, C_, chunk, G, N)

    ae32 = ae.astype(jnp.float32)
    a_cs = jnp.cumsum(ae32, axis=-1)                             # (B,G,E,C,Q)

    with jax.named_scope("intra"):
        cb = jnp.einsum("bcqgn,bckgn->bcgqk", ce, be,
                        preferred_element_type=jnp.float32)
        decay = _segsum_exp(a_cs)                                # (B,G,E,C,Q,Q)
        decay = shard(decay, "batch", None, "ssm_heads", None, None, None)
        cbl = cb[:, :, :, None] * decay.transpose(0, 3, 1, 2, 4, 5)
        cbl = shard(cbl, "batch", None, None, "ssm_heads", None, None)
        y_diag = jnp.einsum("bcgeqk,bckgep->bcqgep",
                            cbl.astype(x.dtype), xe)

    with jax.named_scope("chunk_states"):
        decay_states = jnp.exp(a_cs[..., -1:] - a_cs)            # (B,G,E,C,Q)
        states = jnp.einsum("bckgn,bgeck,bckgep->bcgepn",
                            be, decay_states.astype(x.dtype), xe)
        states = shard(states, "batch", None, None, "ssm_heads", None, None)

    with jax.named_scope("state_pass"):
        chunk_decay = jnp.exp(a_cs[..., -1])                     # (B,G,E,C)

        def body(carry, inp):
            st, dec = inp                                        # (B,G,E,P,N)
            new = carry * dec[..., None, None].astype(carry.dtype) + st
            return new, carry

        init = (jnp.zeros((B, G, E, Pd, N), jnp.float32)
                if initial_state is None else initial_state.astype(jnp.float32))
        final, prev_states = jax.lax.scan(
            body, init,
            (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4, 5),
             chunk_decay.transpose(3, 0, 1, 2)))
        prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)    # (B,C,G,E,P,N)

    with jax.named_scope("inter"):
        state_decay_out = jnp.exp(a_cs)                          # (B,G,E,C,Q)
        y_off = jnp.einsum("bcqgn,bcgepn,bgecq->bcqgep",
                           ce, prev_states.astype(x.dtype),
                           state_decay_out.astype(x.dtype))

    y = (y_diag + y_off).reshape(B, L, H, Pd)
    if return_final_state:
        return y, final
    return y


def ssm_apply(params, x, cfg: ModelConfig, *, use_kernel: bool = False,
              return_state: bool = False):
    """Full-sequence Mamba2 block forward. x: (B, S, d_model).

    With ``return_state`` also returns (conv_state (B,K-1,conv_dim),
    ssd_state (B,h,p,n)) — the decode caches after consuming the prefix.
    """
    d = ssm_dims(cfg)
    B, S, _ = x.shape
    with jax.named_scope("in_proj"):
        zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
        zxbcdt = shard(zxbcdt, "batch", "seq", "ssm_inner")
    di, g, n, h = d["d_inner"], d["groups"], d["d_state"], d["heads"]
    z, xbc_raw, dt = jnp.split(zxbcdt, [di, di + d["conv_dim"]], axis=-1)
    with jax.named_scope("conv"):
        xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
        xbc = jax.nn.silu(xbc)
    xs, b, c = jnp.split(xbc, [di, di + g * n], axis=-1)
    xs = xs.reshape(B, S, h, d["head_dim"])
    b = b.reshape(B, S, g, n)
    c = c.reshape(B, S, g, n)
    with jax.named_scope("discretize"):
        dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
        a = -jnp.exp(params["a_log"].astype(jnp.float32))        # (h,)
        a_disc = (dt * a).astype(jnp.float32)                    # (B,S,h)
        x_disc = xs * dt[..., None].astype(xs.dtype)
    with jax.named_scope("ssd"):
        if use_kernel:
            from repro.kernels import ops as kops
            chunk = kops.resolve_ssd_chunk(S, d["chunk"])
        else:
            chunk = min(d["chunk"], S)
        pad = (-S) % chunk
        if pad:
            # zero-pad: a=0 (decay 1) with x=0 leaves state/output intact
            x_disc = jnp.pad(x_disc, ((0, 0), (0, pad), (0, 0), (0, 0)))
            a_disc = jnp.pad(a_disc, ((0, 0), (0, pad), (0, 0)))
            b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if use_kernel:
            from repro.kernels import ops as kops
            y = kops.ssd_scan(x_disc, a_disc, b, c, chunk=chunk,
                              h_per_g=h // g)
            final_state = None
        else:
            y, final_state = ssd_chunked_xla(
                x_disc, a_disc, b, c, chunk=chunk, h_per_g=h // g,
                return_final_state=True)
        if pad:
            y = y[:, :S]
    with jax.named_scope("out"):
        y = y + params["d_skip"][:, None].astype(xs.dtype) * xs
        y = y.reshape(B, S, di)
        y = rmsnorm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
        out = jnp.einsum("be,ed->bd", y.reshape(B * S, di),
                         params["out_proj"]).reshape(B, S, -1)
    out = shard(out, "batch", "seq", None)
    if return_state:
        K = d["conv_kernel"]
        conv_state = xbc_raw[:, S - (K - 1):, :]                 # (B,K-1,C)
        ssd_state = final_state.reshape(B, h, d["head_dim"], n)  # (B,h,p,n)
        return out, conv_state, ssd_state
    return out


def ssm_decode(params, x, conv_state, ssd_state, cfg: ModelConfig):
    """Single-token decode. x: (B,1,d); conv_state: (B,K-1,conv_dim);
    ssd_state: (B,h,p,n). Returns (out, new_conv_state, new_ssd_state)."""
    d = ssm_dims(cfg)
    B = x.shape[0]
    di, g, n, h, p = (d["d_inner"], d["groups"], d["d_state"], d["heads"],
                      d["head_dim"])
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])[:, 0]
    z, xbc, dt = jnp.split(zxbcdt, [di, di + d["conv_dim"]], axis=-1)
    with jax.named_scope("conv_step"):
        w = params["conv_w"]                                     # (K, C)
        hist = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B,K,C)
        y_conv = jnp.einsum("bkc,kc->bc", hist, w) + params["conv_b"]
        new_conv_state = hist[:, 1:]
        xbc = jax.nn.silu(y_conv)
    xs, b, c = jnp.split(xbc, [di, di + g * n], axis=-1)
    xs = xs.reshape(B, h, p)
    b = b.reshape(B, g, n)
    c = c.reshape(B, g, n)
    with jax.named_scope("state_update"):
        dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,h)
        a = -jnp.exp(params["a_log"].astype(jnp.float32))
        da = jnp.exp(dt * a)                                     # (B,h)
        e = h // g
        bx = jnp.einsum("bgn,bhp->bhpn",
                        b.astype(jnp.float32),
                        xs.astype(jnp.float32) * dt[..., None])
        new_state = ssd_state * da[..., None, None] + bx         # (B,h,p,n)
        ce = jnp.repeat(c, e, axis=1)                            # (B,h,n)
        y = jnp.einsum("bhpn,bhn->bhp", new_state, ce.astype(jnp.float32))
        y = y.astype(xs.dtype) + params["d_skip"][:, None].astype(xs.dtype) * xs
    with jax.named_scope("out"):
        y = y.reshape(B, di)
        y = rmsnorm(y, params["norm"], cfg.norm_eps) * jax.nn.silu(z)
        out = jnp.einsum("be,ed->bd", y, params["out_proj"])[:, None]
    return out, new_conv_state, new_state
