"""Parameter schema machinery + core layers (RMSNorm, RoPE/M-RoPE, MLP).

Parameters are described by a nested-dict *schema* of ``Param`` records
(shape, logical axes, initializer). The same schema yields:

- ``materialize(schema, key, dtype)``  -> concrete params (smoke tests, examples)
- ``abstract(schema, dtype)``          -> ShapeDtypeStruct tree (dry-run)
- ``axes_tree(schema)``                -> logical-axis tuples (sharding rules)

Logical axis names used across the code base:
  vocab, embed, q_heads, kv_heads, q_per_kv, head_dim, ff, expert,
  ssm_inner, ssm_state, ssm_heads, ssm_head_dim, conv, layers
(resolution to mesh axes lives in ``repro.distributed.sharding``).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


class Param(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Any, ...]          # logical axis names (len == len(shape))
    init: str = "normal"           # normal | zeros | ones | embed | ssm_a | ssm_dt
    scale: float = 1.0             # fan-in scaling multiplier


def _is_param(x) -> bool:
    return isinstance(x, Param)


def map_schema(fn, schema):
    """Map ``fn`` over every Param leaf of a nested-dict schema."""
    return jax.tree_util.tree_map(fn, schema, is_leaf=_is_param)


def abstract(schema, dtype) -> Any:
    return map_schema(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), schema)


def axes_tree(schema) -> Any:
    return map_schema(lambda p: p.axes, schema)


def _init_leaf(p: Param, key, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "ssm_a":
        # A_log init: log of uniform [1, 16] (mamba2 convention)
        u = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if p.init == "ssm_dt":
        # dt bias: inverse softplus of uniform-log [1e-3, 1e-1]
        u = jax.random.uniform(key, p.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    fan_in = p.shape[0] if p.init == "embed" else (
        int(jnp.prod(jnp.array(p.shape[:-1]))) if len(p.shape) > 1 else p.shape[0])
    std = p.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)


def materialize(schema, key, dtype) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(schema, is_leaf=_is_param)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def stack_schema(schema, n: int, axis_name="layers"):
    """Prepend a stacked (scan) dimension to every Param in a schema."""
    return map_schema(
        lambda p: Param((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale),
        schema)


# ---------------------------------------------------------------- layers

def rmsnorm(x, scale, eps: float):
    """RMSNorm with f32 statistics but an input-dtype multiply path.

    Multiplying in f32 (the common x.astype(f32) * rsqrt pattern) makes
    the BACKWARD cotangent of the residual stream f32 — every sequence-
    parallel boundary collective then moves 2x the bytes (§Perf
    iteration: the dominant all-gather/all-reduce class on train cells).
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def rmsnorm_schema(d: int) -> Param:
    return Param((d,), ("embed",), init="zeros")


# ------------------------------------------------------------------ RoPE

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, n_heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    sin = jnp.sin(angles)[..., None, :]                 # (..., seq, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Tuple[int, int, int]):
    """Qwen2-VL M-RoPE. x: (..., seq, n, hd); positions3: (3, ..., seq).

    The rotary half-dim is partitioned into (temporal, h, w) sections; each
    section rotates by its own position stream.
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(hd, theta)                       # (half,)
    # build per-frequency position selector
    section_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half)  # (half,)
    # angles_k for each stream k: (..., seq, half)
    angles = positions3[..., None].astype(jnp.float32) * freqs  # (3, ..., seq, half)
    sel = jax.nn.one_hot(section_id, 3, dtype=jnp.float32)      # (half, 3)
    angles = jnp.einsum("k...f,fk->...f", angles, sel)
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- MLP

def mlp_schema(d: int, ff: int, use_bias: bool) -> Dict[str, Param]:
    s: Dict[str, Param] = {
        "wi": Param((d, ff), ("embed", "ff")),
        "wg": Param((d, ff), ("embed", "ff")),
        "wo": Param((ff, d), ("ff", "embed")),
    }
    if use_bias:
        s["bi"] = Param((ff,), ("ff",), init="zeros")
        s["bg"] = Param((ff,), ("ff",), init="zeros")
        s["bo"] = Param((d,), ("embed",), init="zeros")
    return s


def mlp_apply(params, x):
    """SwiGLU MLP. x: (..., d)."""
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    g = jnp.einsum("...d,df->...f", x, params["wg"])
    if "bi" in params:
        h = h + params["bi"]
        g = g + params["bg"]
    h = jax.nn.silu(g) * h
    h = shard(h, "batch", "seq", "ff")
    out = jnp.einsum("...f,fd->...d", h, params["wo"])
    if "bo" in params:
        out = out + params["bo"]
    return out
