"""GQA attention: training/prefill (flash-style) + single-token decode.

TP layout strategy (per-dimension divisibility, resolved in
``distributed.sharding``):

- train/prefill fold GQA to full heads — kv is repeated to H = kv*q_per_kv
  and the *head* dimension is sharded over the model axis (H divides 16
  for 8/10 assigned archs; minicpm-2b's 36 and arctic-480b's 56 heads
  fall back to replicated attention — documented in DESIGN.md). Repeating
  kv costs bytes but keeps the O(S^2) score chunks sharded 16-way, which
  is what decides the memory roofline.
- decode keeps the compact grouped layout (kv cache is NOT repeated) and
  shards the KV cache on the *sequence* axis (model axis; plus data for
  long_500k) — distributed FlashDecoding-style split-KV: each shard
  computes partial softmax stats over its KV slice and GSPMD inserts the
  combine.

The causal core has a hand-written flash VJP: autodiff through the
forward scan would stash O(S^2/chunk) probability chunks per layer
(measured: 90 GiB/device for tinyllama train_4k — §Perf iteration 1).
"""
from __future__ import annotations

import functools
import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import Param, apply_mrope, apply_rope


def attention_schema(cfg: ModelConfig) -> Dict[str, Param]:
    d, kv, hd = cfg.d_model, cfg.num_kv_heads, cfg.resolved_head_dim
    Hp = cfg.resolved_padded_heads
    s = {
        "wq": Param((d, Hp, hd), ("embed", "q_heads", "head_dim")),
        "wk": Param((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": Param((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": Param((Hp, hd, d), ("q_heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        s["bq"] = Param((Hp, hd), ("q_heads", "head_dim"), init="zeros")
        s["bk"] = Param((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = Param((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


def _head_mask(cfg: ModelConfig, dtype):
    """(Hp,) mask zeroing padded q heads (exact semantics, dead weights)."""
    Hp, H = cfg.resolved_padded_heads, cfg.num_heads
    if Hp == H:
        return None
    return (jnp.arange(Hp) < H).astype(dtype)


def _project_qkv(params, x, cfg: ModelConfig, positions):
    """x: (B,S,d) -> q (B,S,H,hd), k,v (B,S,kv,hd) with RoPE applied."""
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_emb == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    # NOTE: no explicit constraint on q/k/v — the residual stream is
    # seq-sharded (act_seq) and forcing a head-shard here makes GSPMD
    # round-trip full-seq f32 activations through all-gather+all-reduce
    # (§Perf iteration: -2.1 s collective term on tinyllama train_4k).
    return q, k, v


def _repeat_kv(k, v, cfg: ModelConfig):
    """(B,S,kv,hd) -> (B,S,Hp,hd), sharded over the head/model axis."""
    if cfg.q_per_kv > 1:
        k = jnp.repeat(k, cfg.q_per_kv, axis=2)
        v = jnp.repeat(v, cfg.q_per_kv, axis=2)
    Hp, H = cfg.resolved_padded_heads, cfg.num_heads
    if Hp != H:
        pad = [(0, 0), (0, 0), (0, Hp - H), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    return k, v


# ------------------------------------------------------ flash core (XLA)

def _flash_row(q_blk, k_ctx, v_ctx, q_offset: int, kv_chunk: int,
               scale: float):
    """One q block against its (statically sliced) causal kv context.

    q_blk: (B, Sq, H, hd); k_ctx/v_ctx: (B, Skv, H, hd), Skv % kv_chunk
    == 0. Returns (out (B,Sq,H,hd) f32, m (B,H,Sq), l (B,H,Sq))."""
    B, Sq, H, HD = q_blk.shape
    Skv = k_ctx.shape[1]
    n_chunks = Skv // kv_chunk
    kc = k_ctx.reshape(B, n_chunks, kv_chunk, H, HD).transpose(1, 0, 2, 3, 4)
    vc = v_ctx.reshape(B, n_chunks, kv_chunk, H, HD).transpose(1, 0, 2, 3, 4)
    qb = q_blk.astype(jnp.bfloat16)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc, chunk_idx = carry
        k_c, v_c = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, k_c.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        k_pos = chunk_idx * kv_chunk + jnp.arange(kv_chunk)
        mask = q_pos[:, None] >= k_pos[None, :]             # (Sq, chunk)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(jnp.bfloat16),
                        v_c.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, chunk_idx + 1), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, HD), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, acc0, jnp.int32(0)),
                                     (kc, vc))
    l_safe = jnp.maximum(l, 1e-37)
    out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3)   # (B,Sq,H,hd)
    return out, m, l_safe


def _flash_row_bwd(q_blk, k_ctx, v_ctx, o_blk, do_blk, m, l,
                   q_offset: int, kv_chunk: int, scale: float):
    """Hand-written flash backward for one q block row (FA-2 style):
    recomputes p chunk-by-chunk from the saved (m, l) stats, so nothing
    O(S^2) is ever materialized. Returns (dq_blk, dk_ctx, dv_ctx)."""
    B, Sq, H, HD = q_blk.shape
    Skv = k_ctx.shape[1]
    n_chunks = Skv // kv_chunk
    kc = k_ctx.reshape(B, n_chunks, kv_chunk, H, HD).transpose(1, 0, 2, 3, 4)
    vc = v_ctx.reshape(B, n_chunks, kv_chunk, H, HD).transpose(1, 0, 2, 3, 4)
    qb = q_blk.astype(jnp.bfloat16)
    do = do_blk.transpose(0, 2, 1, 3).astype(jnp.float32)   # (B,H,Sq,hd)
    o = o_blk.transpose(0, 2, 1, 3).astype(jnp.float32)
    delta = jnp.sum(do * o, axis=-1)                        # (B,H,Sq)
    q_pos = q_offset + jnp.arange(Sq)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    do_b = do.astype(jnp.bfloat16)

    def body(dq_acc, inp):
        k_c, v_c, chunk_idx = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, k_c.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        k_pos = chunk_idx * kv_chunk + jnp.arange(kv_chunk)
        mask = q_pos[:, None] >= k_pos[None, :]
        p = jnp.where(mask[None, None],
                      jnp.exp(s - m_safe[..., None]) / l[..., None], 0.0)
        p_b = p.astype(jnp.bfloat16)
        dv_c = jnp.einsum("bhqk,bhqd->bkhd", p_b, do_b,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhqd,bkhd->bhqk", do_b, v_c.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[..., None]) * scale).astype(jnp.bfloat16)
        dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                     k_c.astype(jnp.bfloat16),
                                     preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bhqk,bqhd->bkhd", ds, qb,
                          preferred_element_type=jnp.float32)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((B, Sq, H, HD), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        body, dq0, (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32)))
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(B, Skv, H, HD)
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(B, Skv, H, HD)
    return dq, dk, dv


def _row_plan(S: int, q_block: int, kv_chunk: int):
    q_block = min(q_block, S)
    if S % q_block:
        q_block = math.gcd(S, q_block) or S
    rows = []
    for i in range(S // q_block):
        ctx = (i + 1) * q_block
        chunk = min(kv_chunk, ctx)
        chunk = math.gcd(ctx, chunk) if ctx % chunk else chunk
        rows.append((i * q_block, ctx, chunk))
    return q_block, rows


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def causal_flash_xla(q, k, v, q_block: int = 1024, kv_chunk: int = 1024):
    """Causal flash attention in pure XLA ops (q,k,v: (B,S,H,hd)) with a
    hand-written flash VJP. Python loop over q blocks with static causal
    kv slices — HLO compute is block-triangular (only the diagonal block
    carries masked waste)."""
    out, _ = _flash_fwd(q, k, v, q_block, kv_chunk)
    return out


def _flash_fwd(q, k, v, q_block: int, kv_chunk: int):
    B, S, H, HD = q.shape
    scale = 1.0 / math.sqrt(HD)
    qb, rows = _row_plan(S, q_block, kv_chunk)
    outs, ms, ls = [], [], []
    for (off, ctx, chunk) in rows:
        with jax.named_scope("qblk"):
            q_blk = jax.lax.slice_in_dim(q, off, off + qb, axis=1)
            k_ctx = jax.lax.slice_in_dim(k, 0, ctx, axis=1)
            v_ctx = jax.lax.slice_in_dim(v, 0, ctx, axis=1)
            o, m, l = _flash_row(q_blk, k_ctx, v_ctx, off, chunk, scale)
            outs.append(o.astype(q.dtype))
            ms.append(m)
            ls.append(l)
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    m = jnp.stack(ms)                        # (rows, B, H, qb)
    l = jnp.stack(ls)
    return out, (q, k, v, out, m, l)


def _flash_bwd(q_block: int, kv_chunk: int, res, dout):
    q, k, v, out, m, l = res
    B, S, H, HD = q.shape
    scale = 1.0 / math.sqrt(HD)
    qb, rows = _row_plan(S, q_block, kv_chunk)
    dq_rows = []
    # accumulate dk/dv in the INPUT dtype: full-seq f32 accumulators get
    # resharded by GSPMD at 2x the bytes (§Perf iteration: the f32
    # all-gather/all-reduce class around attention bwd). Each element
    # receives at most n_rows (<=32) additions — bf16-safe, verified by
    # the flash-vjp gradient tests.
    dk = jnp.zeros((B, S, H, HD), k.dtype)
    dv = jnp.zeros((B, S, H, HD), v.dtype)
    for ri, (off, ctx, chunk) in enumerate(rows):
        with jax.named_scope("qblk_bwd"):
            q_blk = jax.lax.slice_in_dim(q, off, off + qb, axis=1)
            k_ctx = jax.lax.slice_in_dim(k, 0, ctx, axis=1)
            v_ctx = jax.lax.slice_in_dim(v, 0, ctx, axis=1)
            o_blk = jax.lax.slice_in_dim(out, off, off + qb, axis=1)
            do_blk = jax.lax.slice_in_dim(dout, off, off + qb, axis=1)
            dq_r, dk_r, dv_r = _flash_row_bwd(
                q_blk, k_ctx, v_ctx, o_blk, do_blk, m[ri], l[ri],
                off, chunk, scale)
            dq_rows.append(dq_r.astype(q.dtype))
            pad = [(0, 0), (0, S - ctx), (0, 0), (0, 0)]
            dk = dk + jnp.pad(dk_r.astype(k.dtype), pad)
            dv = dv + jnp.pad(dv_r.astype(v.dtype), pad)
    dq = (jnp.concatenate(dq_rows, axis=1)
          if len(dq_rows) > 1 else dq_rows[0])
    return dq, dk, dv


causal_flash_xla.defvjp(_flash_fwd, _flash_bwd)


# ----------------------------------------------------------- public ops

def attn_train(params, x, positions, cfg: ModelConfig):
    """Full-sequence causal self-attention (training / prefill forward)."""
    with jax.named_scope("qkv"):
        q, k, v = _project_qkv(params, x, cfg, positions)
        kr, vr = _repeat_kv(k, v, cfg)
    with jax.named_scope("flash"):
        if cfg.attn_impl == "pallas":
            from repro.kernels import ops as kops
            B, S, H, HD = q.shape
            o = kops.flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
        else:
            o = causal_flash_xla(q, kr, vr, cfg.attn_chunk, cfg.attn_chunk)
    with jax.named_scope("out_proj"):
        o = o.astype(x.dtype)
        hm = _head_mask(cfg, o.dtype)
        if hm is not None:
            o = o * hm[None, None, :, None]
        o = shard(o, "batch", "seq", "q_heads", "head_dim")
        out = jnp.einsum("bsnh,nhd->bsd", o, params["wo"])
    return shard(out, "batch", "seq", None)


def attn_prefill(params, x, positions, cfg: ModelConfig, cache_len: int):
    """Like attn_train but also returns the (padded, UNrepeated) KV cache
    slabs, sequence-sharded for serving."""
    with jax.named_scope("qkv"):
        q, k, v = _project_qkv(params, x, cfg, positions)
        kr, vr = _repeat_kv(k, v, cfg)
    with jax.named_scope("flash"):
        o = causal_flash_xla(q, kr, vr, cfg.attn_chunk, cfg.attn_chunk)
    with jax.named_scope("out_proj"):
        o = o.astype(x.dtype)
        hm = _head_mask(cfg, o.dtype)
        if hm is not None:
            o = o * hm[None, None, :, None]
        out = jnp.einsum("bsnh,nhd->bsd", o, params["wo"])
    S = x.shape[1]
    pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
    kc = shard(jnp.pad(k.astype(cfg.kv_cache_dtype), pad),
               "batch", "kv_seq", "kv_heads", "head_dim")
    vc = shard(jnp.pad(v.astype(cfg.kv_cache_dtype), pad),
               "batch", "kv_seq", "kv_heads", "head_dim")
    return shard(out, "batch", "seq", None), (kc, vc)


def attn_decode(params, x, cache_k, cache_v, pos, cfg: ModelConfig):
    """Single-token decode against a sequence-sharded KV cache
    (distributed split-KV softmax; see module docstring).

    x: (B, 1, d); cache_k/v: (B, S_max, kv, hd); pos: scalar int32.
    Returns (out (B,1,d), new_cache_k, new_cache_v)."""
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    if cfg.pos_emb == "mrope":
        positions = jnp.broadcast_to(positions, (3,) + positions.shape)
    with jax.named_scope("qkv"):
        q, k_new, v_new = _project_qkv(params, x, cfg, positions)
        B, _, Hp, HD = q.shape
        H = cfg.num_heads
        if Hp != H:
            q = q[:, :, :H]      # decode: drop dead pad heads (tiny tensors)
        kv = cfg.num_kv_heads
        qg = q.reshape(B, 1, kv, cfg.q_per_kv, HD)
    with jax.named_scope("cache_update"):
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), pos, axis=1)
        cache_k = shard(cache_k, "batch", "kv_seq", "kv_heads", "head_dim")
        cache_v = shard(cache_v, "batch", "kv_seq", "kv_heads", "head_dim")
    with jax.named_scope("attend"):
        scale = 1.0 / math.sqrt(HD)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.bfloat16),
                       cache_k.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        S_max = cache_k.shape[1]
        mask = jnp.arange(S_max) <= pos
        s = jnp.where(mask[None, None, None, None, :], s, -jnp.inf)
        # max/sum over the (model/data-sharded) kv_seq axis: GSPMD inserts
        # the FlashDecoding-style partial-softmax combine collectives.
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = p.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskh->bkgqh", (p / l).astype(jnp.bfloat16),
                       cache_v.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    with jax.named_scope("out_proj"):
        o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, HD).astype(x.dtype)
        if Hp != H:
            o = jnp.pad(o, [(0, 0), (0, 0), (0, Hp - H), (0, 0)])
        out = jnp.einsum("bsnh,nhd->bsd", o, params["wo"])
    return shard(out, "batch", "seq", None), cache_k, cache_v
