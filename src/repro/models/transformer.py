"""Decoder blocks + scan-over-layers stacks for all assigned families.

Stacks use ``lax.scan`` over stacked layer params so compiled-HLO size is
O(1) in depth (critical for 40-80L dry-run compiles and for recompile
latency at production scale). The scan is also the "loop" node the
RealProbe hierarchy reports (with first-4-iteration truncation, like the
paper's loop capture).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (mlp_apply, mlp_schema, rmsnorm,
                                 rmsnorm_schema, stack_schema)


# ------------------------------------------------------------- schemas

def block_schema(cfg: ModelConfig) -> Dict[str, Any]:
    """Schema of ONE layer of the homogeneous (scanned) stack."""
    if cfg.family == "ssm":
        return {"ln": rmsnorm_schema(cfg.d_model),
                "ssm": ssm_mod.ssm_schema(cfg)}
    if cfg.family == "hybrid":
        return {"ln": rmsnorm_schema(cfg.d_model),
                "ssm": ssm_mod.ssm_schema(cfg)}
    s: Dict[str, Any] = {
        "ln1": rmsnorm_schema(cfg.d_model),
        "attn": attn.attention_schema(cfg),
        "ln2": rmsnorm_schema(cfg.d_model),
    }
    if cfg.moe is not None:
        s["moe"] = moe_mod.moe_schema(cfg)
    else:
        s["mlp"] = mlp_schema(cfg.d_model, cfg.d_ff, cfg.use_bias)
    return s


def shared_attn_schema(cfg: ModelConfig) -> Dict[str, Any]:
    """Zamba2's weight-shared transformer block (attn + MLP)."""
    return {
        "ln1": rmsnorm_schema(cfg.d_model),
        "attn": attn.attention_schema(cfg),
        "ln2": rmsnorm_schema(cfg.d_model),
        "mlp": mlp_schema(cfg.d_model, cfg.d_ff, cfg.use_bias),
    }


def stack_schemas(cfg: ModelConfig) -> Dict[str, Any]:
    """Full parameter schema for the layer stack of one architecture."""
    out: Dict[str, Any] = {"layers": stack_schema(block_schema(cfg),
                                                  cfg.num_layers)}
    if cfg.family == "hybrid":
        out["shared"] = shared_attn_schema(cfg)
    out["ln_f"] = rmsnorm_schema(cfg.d_model)
    return out


# ------------------------------------------------------- train forward

def _attn_mlp_block(lp, x, positions, cfg: ModelConfig):
    with jax.named_scope("attn"):
        h = attn.attn_train(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                            positions, cfg)
    x = x + h
    if cfg.moe is not None:
        h, aux = moe_mod.moe_apply(lp["moe"],
                                   rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
    else:
        with jax.named_scope("mlp"):
            h = mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
        aux = jnp.zeros((), jnp.float32)
    x = x + h
    return shard(x, "batch", "act_seq", None), aux


def _ssm_block(lp, x, cfg: ModelConfig):
    with jax.named_scope("ssm"):
        h = ssm_mod.ssm_apply(lp["ssm"], rmsnorm(x, lp["ln"], cfg.norm_eps),
                              cfg)
    return shard(x + h, "batch", "act_seq", None)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)           # "full": save nothing


def stack_apply(params, x, positions, cfg: ModelConfig):
    """Run the full layer stack (training / prefill-forward math).

    Returns (x, aux_loss_sum).
    """
    if cfg.family in ("ssm", "hybrid"):
        return _stack_apply_ssm(params, x, cfg, positions)

    def body(carry, lp):
        h, aux = carry
        with jax.named_scope("layer"):
            h, aux_i = _attn_mlp_block(lp, h, positions, cfg)
        return (h, aux + aux_i), None

    body = _remat(body, cfg)
    with jax.named_scope("layers"):
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    with jax.named_scope("final_norm"):
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, aux


def _stack_apply_ssm(params, x, cfg: ModelConfig, positions):
    if cfg.family == "ssm":
        def body(h, lp):
            with jax.named_scope("layer"):
                h = _ssm_block(lp, h, cfg)
            return h, None
        body = _remat(body, cfg)
        with jax.named_scope("layers"):
            x, _ = jax.lax.scan(body, x, params["layers"])
    else:  # hybrid: groups of SSM layers + weight-shared attn block
        every = cfg.shared_attn_every
        n_groups = cfg.num_layers // every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]),
            params["layers"])
        shared = params["shared"]

        def group_body(h, gp):
            def inner(h2, lp):
                with jax.named_scope("ssm_layer"):
                    return _ssm_block(lp, h2, cfg), None
            # nested remat: without it the inner scan stacks every SSM
            # layer's SSD intermediates inside the group's recompute
            inner = _remat(inner, cfg)
            h, _ = jax.lax.scan(inner, h, gp)
            with jax.named_scope("shared_attn"):
                h2, _ = _attn_mlp_block(
                    {"ln1": shared["ln1"], "attn": shared["attn"],
                     "ln2": shared["ln2"], "mlp": shared["mlp"]},
                    h, positions, cfg.replace(moe=None))
            return h2, None

        group_body = _remat(group_body, cfg)
        with jax.named_scope("groups"):
            x, _ = jax.lax.scan(group_body, x, grouped)
    with jax.named_scope("final_norm"):
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


# ------------------------------------------------------------- decode

def decode_block_attn(lp, x, ck, cv, pos, cfg: ModelConfig):
    with jax.named_scope("attn"):
        h, ck, cv = attn.attn_decode(lp["attn"],
                                     rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                     ck, cv, pos, cfg)
    x = x + h
    if cfg.moe is not None:
        h, _ = moe_mod.moe_apply(lp["moe"],
                                 rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg)
    else:
        with jax.named_scope("mlp"):
            h = mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return x + h, ck, cv


def decode_block_ssm(lp, x, conv_s, ssd_s, cfg: ModelConfig):
    with jax.named_scope("ssm"):
        h, conv_s, ssd_s = ssm_mod.ssm_decode(
            lp["ssm"], rmsnorm(x, lp["ln"], cfg.norm_eps), conv_s, ssd_s, cfg)
    return x + h, conv_s, ssd_s


def stack_decode(params, cache, x, pos, cfg: ModelConfig):
    """One decode step through the stack. Returns (x, new_cache).

    The KV cache rides in the scan CARRY with per-layer dynamic-update
    slices (passing it as scan xs/ys double-buffers the multi-GiB cache —
    measured +9 GiB/device on the decode_32k cells)."""
    if cfg.family in ("ssm", "hybrid"):
        return _stack_decode_ssm(params, cache, x, pos, cfg)

    def body(carry, inp):
        h, ck_all, cv_all = carry
        lp, li = inp
        with jax.named_scope("layer"):
            ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0, keepdims=False)
            h, ck, cv = decode_block_attn(lp, h, ck, cv, pos, cfg)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, li, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, li, 0)
        return (h, ck_all, cv_all), None

    L = cfg.num_layers
    with jax.named_scope("layers"):
        (x, ck, cv), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["layers"], jnp.arange(L, dtype=jnp.int32)))
    with jax.named_scope("final_norm"):
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, {"k": ck, "v": cv}


def _stack_decode_ssm(params, cache, x, pos, cfg: ModelConfig):
    if cfg.family == "ssm":
        def body(h, inp):
            lp, conv_s, ssd_s = inp
            with jax.named_scope("layer"):
                h, conv_s, ssd_s = decode_block_ssm(lp, h, conv_s, ssd_s, cfg)
            return h, (conv_s, ssd_s)
        with jax.named_scope("layers"):
            x, (conv_s, ssd_s) = jax.lax.scan(
                body, x, (params["layers"], cache["conv"], cache["ssd"]))
        new_cache = {"conv": conv_s, "ssd": ssd_s}
    else:
        every = cfg.shared_attn_every
        n_groups = cfg.num_layers // every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]),
            params["layers"])
        conv_g = cache["conv"].reshape((n_groups, every) + cache["conv"].shape[1:])
        ssd_g = cache["ssd"].reshape((n_groups, every) + cache["ssd"].shape[1:])
        shared = params["shared"]

        def group_body(h, inp):
            gp, conv_s, ssd_s, ck, cv = inp
            def inner(h2, inp2):
                lp, cs, ss = inp2
                with jax.named_scope("ssm_layer"):
                    h2, cs, ss = decode_block_ssm(lp, h2, cs, ss, cfg)
                return h2, (cs, ss)
            h, (conv_s, ssd_s) = jax.lax.scan(inner, h, (gp, conv_s, ssd_s))
            with jax.named_scope("shared_attn"):
                h, ck, cv = decode_block_attn(
                    {"ln1": shared["ln1"], "attn": shared["attn"],
                     "ln2": shared["ln2"], "mlp": shared["mlp"]},
                    h, ck, cv, pos, cfg.replace(moe=None))
            return h, (conv_s, ssd_s, ck, cv)

        with jax.named_scope("groups"):
            x, (conv_s, ssd_s, ck, cv) = jax.lax.scan(
                group_body, x, (grouped, conv_g, ssd_g, cache["k"], cache["v"]))
        new_cache = {
            "conv": conv_s.reshape(cache["conv"].shape),
            "ssd": ssd_s.reshape(cache["ssd"].shape),
            "k": ck, "v": cv,
        }
    with jax.named_scope("final_norm"):
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, new_cache


# ----------------------------------------------------------- prefill

def stack_prefill(params, x, positions, cfg: ModelConfig, cache_len: int):
    """Forward pass that also builds the serving cache (prefill_32k)."""
    if cfg.family in ("ssm", "hybrid"):
        return _stack_prefill_ssm(params, x, positions, cfg, cache_len)

    def body(h, lp):
        with jax.named_scope("layer"):
            with jax.named_scope("attn"):
                a, (ck, cv) = attn.attn_prefill(
                    lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps),
                    positions, cfg, cache_len)
            h = h + a
            if cfg.moe is not None:
                m, _ = moe_mod.moe_apply(
                    lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps), cfg)
            else:
                with jax.named_scope("mlp"):
                    m = mlp_apply(lp["mlp"], rmsnorm(h, lp["ln2"], cfg.norm_eps))
            h = shard(h + m, "batch", "act_seq", None)
        return h, (ck, cv)

    with jax.named_scope("layers"):
        x, (ck, cv) = jax.lax.scan(body, x, params["layers"])
    with jax.named_scope("final_norm"):
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, {"k": ck, "v": cv}


def _stack_prefill_ssm(params, x, positions, cfg: ModelConfig, cache_len: int):
    """SSM/hybrid prefill: chunked scan + capture decode caches
    (conv tail, final SSD state, and — for hybrid — shared-attn KV)."""

    def ssm_layer(lp, h):
        y, conv_s, ssd_s = ssm_mod.ssm_apply(
            lp["ssm"], rmsnorm(h, lp["ln"], cfg.norm_eps), cfg,
            return_state=True)
        return h + y, conv_s, ssd_s

    if cfg.family == "ssm":
        def body(h, lp):
            with jax.named_scope("layer"):
                h, conv_s, ssd_s = ssm_layer(lp, h)
            return h, (conv_s, ssd_s)
        with jax.named_scope("layers"):
            x, (conv_s, ssd_s) = jax.lax.scan(body, x, params["layers"])
        cache = {"conv": conv_s, "ssd": ssd_s}
    else:
        every = cfg.shared_attn_every
        n_groups = cfg.num_layers // every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]),
            params["layers"])
        shared = params["shared"]

        def group_body(h, gp):
            def inner(h2, lp):
                with jax.named_scope("ssm_layer"):
                    h2, conv_s, ssd_s = ssm_layer(lp, h2)
                return h2, (conv_s, ssd_s)
            h, (conv_s, ssd_s) = jax.lax.scan(inner, h, gp)
            with jax.named_scope("shared_attn"):
                a, (ck, cv) = attn.attn_prefill(
                    shared["attn"], rmsnorm(h, shared["ln1"], cfg.norm_eps),
                    positions, cfg, cache_len)
                h = h + a
                with jax.named_scope("mlp"):
                    m = mlp_apply(shared["mlp"],
                                  rmsnorm(h, shared["ln2"], cfg.norm_eps))
                h = shard(h + m, "batch", "seq", None)
            return h, (conv_s, ssd_s, ck, cv)

        with jax.named_scope("groups"):
            x, (conv_s, ssd_s, ck, cv) = jax.lax.scan(group_body, x, grouped)
        L = cfg.num_layers
        cache = {
            "conv": conv_s.reshape((L,) + conv_s.shape[2:]),
            "ssd": ssd_s.reshape((L,) + ssd_s.shape[2:]),
            "k": ck, "v": cv,
        }
    with jax.named_scope("final_norm"):
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, cache
