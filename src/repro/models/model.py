"""Model facade: schema/init, train loss, prefill, decode, input specs.

Everything is purely functional; parameters are nested dicts whose leaves
come from the schema machinery in ``layers.py`` (so the same schema
yields concrete params, abstract ShapeDtypeStructs for the dry-run, and
logical sharding axes).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import shard
from repro.models import transformer as tfm
from repro.models import ssm as ssm_mod
from repro.models.frontends import frontend_input_specs
from repro.models.layers import Param, abstract, axes_tree, materialize

Z_LOSS_WEIGHT = 1e-4


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_dtype_barrier(x, dtype_str: str):
    """Identity whose BACKWARD casts the cotangent to ``dtype_str``.

    The loss computes f32 logits (stability); without this barrier the
    f32 cotangent propagates down the ENTIRE residual stream — every
    boundary collective and stash in the backward pass moves f32 instead
    of bf16 (§Perf iteration: the all-reduce class halves).
    """
    return x


def _gdb_fwd(x, dtype_str):
    return x, None


def _gdb_bwd(dtype_str, _, g):
    return (g.astype(jnp.dtype(dtype_str)),)


_grad_dtype_barrier.defvjp(_gdb_fwd, _gdb_bwd)


def _dtype(name: str):
    return jnp.dtype(name)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ params
    def schema(self) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {"stack": tfm.stack_schemas(cfg)}
        V = cfg.padded_vocab_size
        if cfg.frontend == "none":
            s["embed"] = Param((V, cfg.d_model), ("vocab", "embed"),
                               init="embed")
        if cfg.frontend != "none" or not cfg.tie_embeddings:
            s["unembed"] = Param((cfg.d_model, V), ("embed", "vocab"))
        return s

    def init(self, key) -> Dict[str, Any]:
        return materialize(self.schema(), key, _dtype(self.cfg.param_dtype))

    def abstract_params(self) -> Dict[str, Any]:
        return abstract(self.schema(), _dtype(self.cfg.param_dtype))

    def logical_axes(self) -> Dict[str, Any]:
        return axes_tree(self.schema())

    def param_count(self) -> int:
        import math
        leaves = jax.tree_util.tree_leaves(self.abstract_params())
        return sum(math.prod(l.shape) for l in leaves)

    # ------------------------------------------------------------ pieces
    def _compute_cast(self, params):
        cd = _dtype(self.cfg.compute_dtype)
        return jax.tree_util.tree_map(
            lambda a: a.astype(cd) if a.dtype in (jnp.float32, jnp.bfloat16,
                                                  jnp.float16) else a, params)

    def _embed_in(self, params, batch):
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        if cfg.frontend == "none":
            with jax.named_scope("embed"):
                x = jnp.take(params["embed"], batch["tokens"], axis=0)
                x = x.astype(cd)
        else:
            x = batch["embeds"].astype(cd)
        return shard(x, "batch", "seq", None)

    def _positions(self, batch, seq: int, batch_size: int):
        cfg = self.cfg
        if cfg.pos_emb == "mrope":
            return batch["positions"]
        pos = jnp.arange(seq, dtype=jnp.int32)[None]
        return jnp.broadcast_to(pos, (batch_size, seq))

    def _mask_pad(self, logits):
        V = self.cfg.padded_vocab_size
        if V == self.cfg.vocab_size:
            return logits
        iota_v = jax.lax.iota(jnp.int32, V)
        return jnp.where(iota_v[None, :] >= self.cfg.vocab_size,
                         -jnp.inf, logits)

    def _unembed_weight(self, params):
        if "unembed" in params:
            return params["unembed"]                     # (d, V)
        return params["embed"].T                         # tied

    def _chunked_xent(self, params, x, labels):
        """Vocab-parallel, seq-chunked cross entropy (+ z-loss).

        Never materializes (B, S, V) logits; the chunk body is remat'd so
        the backward pass recomputes chunk logits instead of saving them.
        """
        cfg = self.cfg
        B, S, D = x.shape
        chunk = min(cfg.loss_chunk, S)
        if S % chunk:
            chunk = S            # fall back: no chunking on odd lengths
        nc = S // chunk
        w = self._unembed_weight(params)
        xc = x.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
        V = cfg.padded_vocab_size
        iota_v = jax.lax.iota(jnp.int32, V)
        pad_mask = iota_v >= cfg.vocab_size          # -inf'd pad columns

        @jax.checkpoint
        def body(carry, inp):
            x_, l_ = inp
            with jax.named_scope("logits"):
                logits = jnp.einsum("bsd,dv->bsv", x_, w.astype(x_.dtype),
                                    preferred_element_type=jnp.float32)
                logits = jnp.where(pad_mask[None, None, :], -jnp.inf, logits)
                logits = shard(logits, "batch", "seq", "vocab")
            with jax.named_scope("xent"):
                m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
                logz = jnp.log(jnp.sum(
                    jnp.where(pad_mask[None, None, :], 0.0,
                              jnp.exp(logits - m)), axis=-1)) + m[..., 0]
                hit = (l_[..., None] == iota_v[None, None, :])
                ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
                nll = jnp.sum(logz - ll)
                zl = jnp.sum(jnp.square(logz))
            c_nll, c_zl = carry
            return (c_nll + nll, c_zl + zl), None

        with jax.named_scope("loss"):
            (nll, zl), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                (xc, lc))
            n_tok = B * S
            return nll / n_tok, zl / n_tok

    # ------------------------------------------------------------- train
    def loss_fn(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        params = self._compute_cast(params)
        x = self._embed_in(params, batch)
        B, S, _ = x.shape
        positions = self._positions(batch, S, B)
        x, aux = tfm.stack_apply(params["stack"], x, positions, cfg)
        x = _grad_dtype_barrier(x, cfg.compute_dtype)
        nll, zl = self._chunked_xent(params, x, batch["labels"])
        loss = nll + Z_LOSS_WEIGHT * zl + aux
        return loss, {"nll": nll, "z_loss": zl, "aux_loss": aux}

    # ----------------------------------------------------------- serving
    def prefill(self, params, batch, cache_len: int):
        cfg = self.cfg
        params = self._compute_cast(params)
        x = self._embed_in(params, batch)
        B, S, _ = x.shape
        positions = self._positions(batch, S, B)
        x, cache = tfm.stack_prefill(params["stack"], x, positions, cfg,
                                     cache_len)
        with jax.named_scope("last_logits"):
            last = x[:, -1]
            logits = jnp.einsum("bd,dv->bv", last,
                                self._unembed_weight(params).astype(last.dtype),
                                preferred_element_type=jnp.float32)
            logits = self._mask_pad(logits)
        return logits, cache

    def decode_step(self, params, cache, batch):
        """One token for every sequence. batch: {tokens|embeds, pos}."""
        cfg = self.cfg
        params = self._compute_cast(params)
        cd = _dtype(cfg.compute_dtype)
        pos = batch["pos"]
        if cfg.frontend == "none":
            with jax.named_scope("embed"):
                x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cd)
        else:
            x = batch["embeds"].astype(cd)
        x, cache = tfm.stack_decode(params["stack"], cache, x, pos, cfg)
        with jax.named_scope("last_logits"):
            logits = jnp.einsum("bd,dv->bv", x[:, -1],
                                self._unembed_weight(params).astype(cd),
                                preferred_element_type=jnp.float32)
            logits = self._mask_pad(logits)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, cache, next_token

    # --------------------------------------------------------- dry specs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        cfg = self.cfg
        cd = _dtype(cfg.compute_dtype)
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            if cfg.frontend == "none":
                specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            else:
                specs = dict(frontend_input_specs(cfg, B, S, cd))
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            return specs
        if shape.kind == "prefill":
            if cfg.frontend == "none":
                return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
            return dict(frontend_input_specs(cfg, B, S, cd))
        # decode: one new token against a cache of length S
        if cfg.frontend == "none":
            specs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        else:
            specs = dict(frontend_input_specs(cfg, B, 1, cd))
            if cfg.pos_emb == "mrope":
                # decode positions derive from scalar pos; drop the stream
                specs.pop("positions")
        specs["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        return specs

    def cache_specs(self, shape: ShapeConfig) -> Tuple[Dict[str, Any],
                                                       Dict[str, Any]]:
        """(ShapeDtypeStruct tree, logical-axes tree) for the decode cache."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        kvd = _dtype(cfg.kv_cache_dtype)
        cd = _dtype(cfg.compute_dtype)
        specs: Dict[str, Any] = {}
        axes: Dict[str, Any] = {}
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        if cfg.family in ("ssm", "hybrid"):
            d = ssm_mod.ssm_dims(cfg)
            L = cfg.num_layers
            specs["conv"] = jax.ShapeDtypeStruct(
                (L, B, d["conv_kernel"] - 1, d["conv_dim"]), cd)
            axes["conv"] = ("layers", "batch", None, "ssm_inner")
            specs["ssd"] = jax.ShapeDtypeStruct(
                (L, B, d["heads"], d["head_dim"], d["d_state"]), jnp.float32)
            axes["ssd"] = ("layers", "batch", "ssm_heads", "ssm_head_dim",
                           "ssm_state")
            if cfg.family == "hybrid":
                n_inv = cfg.num_layers // cfg.shared_attn_every
                specs["k"] = jax.ShapeDtypeStruct((n_inv, B, S, kv, hd), kvd)
                specs["v"] = jax.ShapeDtypeStruct((n_inv, B, S, kv, hd), kvd)
                axes["k"] = axes["v"] = ("layers", "batch", "kv_seq",
                                         "kv_heads", "head_dim")
        else:
            L = cfg.num_layers
            specs["k"] = jax.ShapeDtypeStruct((L, B, S, kv, hd), kvd)
            specs["v"] = jax.ShapeDtypeStruct((L, B, S, kv, hd), kvd)
            axes["k"] = axes["v"] = ("layers", "batch", "kv_seq",
                                     "kv_heads", "head_dim")
        return specs, axes

    def init_cache(self, shape: ShapeConfig):
        specs, _ = self.cache_specs(shape)
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), specs)
