"""Mixture-of-Experts FFN: top-k routing + sort-based grouped GEMM.

Dispatch is MegaBlocks-style [arXiv:2211.15841]: flatten tokens, sort the
(token, expert) assignments by expert, run ``jax.lax.ragged_dot`` grouped
GEMMs, unsort, and combine with the routing weights. No token dropping,
and FLOPs are exactly the active-expert FLOPs (6·N_active·D accounting).

Sharding: expert weights keep all experts on every model shard but are
TP-sharded on the expert d_ff dimension ("ff" -> model axis) and
FSDP-sharded on d_model ("embed" -> data axis). The shard_map interior
all-gathers the FSDP shards (reduce-scatter in reverse on the backward
pass) and psums the down-projection partials over the model axis — the
same collective pattern as the dense TP MLP, so MoE adds **zero** extra
collective classes to the step. The token sort/argsort stays local to
each data shard (no global sort collective). An all-to-all EP variant is
the §Perf hillclimb alternative.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import compat
from repro.distributed import sharding as shd
from repro.models.layers import Param


def moe_schema(cfg: ModelConfig) -> Dict[str, Param]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    s = {
        "router": Param((d, E), (None, None)),  # small; replicated
        "wi": Param((E, d, ff), ("expert", "embed", "ff")),
        "wg": Param((E, d, ff), ("expert", "embed", "ff")),
        "wo": Param((E, ff, d), ("expert", "ff", "embed")),
    }
    if cfg.moe.dense_residual:
        rff = cfg.moe.residual_d_ff or ff
        s["res_wi"] = Param((d, rff), ("embed", "ff"))
        s["res_wg"] = Param((d, rff), ("embed", "ff"))
        s["res_wo"] = Param((rff, d), ("ff", "embed"))
    return s


def _route(x_flat, router_w, cfg: ModelConfig):
    """x_flat: (T, d) -> (weights (T,k), expert_idx (T,k), aux_loss)."""
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    top_p, top_i = jax.lax.top_k(probs, k)                      # (T, k)
    weights = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch [arXiv:2101.03961])
    T = x_flat.shape[0]
    assign = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    frac_assign = assign / (T * k)
    frac_prob = probs.mean(axis=0)
    aux = E * jnp.sum(frac_assign * frac_prob)
    return weights, top_i, aux


@jax.custom_vjp
def grouped_matmul(x, w, group_sizes):
    """ragged_dot with a sparse custom VJP.

    jax.lax.ragged_dot's builtin autodiff materializes DENSE per-expert
    gradients — (rows, E, d) and (rows, E*d) intermediates, measured at
    256 GiB/device on granite-moe train_4k (§Perf iteration log). The
    flash-style fix: both backward products are themselves grouped GEMMs:

        dx    = ragged_dot(dy, swapaxes(w, 1, 2), gs)
        dw[e] = x_e^T @ dy_e   (ragged_dot_general, ragged contracting)
    """
    return jax.lax.ragged_dot(x, w, group_sizes)


def _grouped_matmul_fwd(x, w, group_sizes):
    return jax.lax.ragged_dot(x, w, group_sizes), (x, w, group_sizes)


def _grouped_matmul_bwd(res, dy):
    x, w, gs = res
    dx = jax.lax.ragged_dot(dy, jnp.swapaxes(w, 1, 2), gs)
    dims = jax.lax.RaggedDotDimensionNumbers(
        dot_dimension_numbers=(((0,), (0,)), ((), ())),
        lhs_ragged_dimensions=(0,),
        rhs_group_dimensions=())
    dw = jax.lax.ragged_dot_general(x, dy, gs, dims,
                                    preferred_element_type=jnp.float32)
    return dx.astype(x.dtype), dw.astype(w.dtype), None


grouped_matmul.defvjp(_grouped_matmul_fwd, _grouped_matmul_bwd)


def _expert_gemms_ragged(xs, wi, wg, wo, group_sizes):
    """Grouped SwiGLU over expert-sorted rows. xs: (T*k, d)."""
    h = grouped_matmul(xs, wi, group_sizes)
    g = grouped_matmul(xs, wg, group_sizes)
    h = jax.nn.silu(g) * h
    return grouped_matmul(h, wo, group_sizes)


def _capacity(cfg: ModelConfig, T: int) -> int:
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    c = int(math.ceil(T * k / E * cfg.moe.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)


def _moe_local(x, router_w, wi, wg, wo, cfg: ModelConfig,
               fsdp_axis=None, model_axis=None, batch_axes=None):
    """Per-shard MoE body. x: (B_local, S, d). Collectives only when the
    corresponding mesh axis name is given (shard_map interior).

    Dispatch: sort assignments by expert, scatter rows into
    capacity-padded (E, C, d) blocks, run dense *batched* GEMMs, gather
    back. Batched-einsum fwd/bwd never materializes anything bigger than
    (E, C, ff_local) — ragged_dot's autodiff (and even
    ragged_dot_general's CPU lowering of the dW product) materializes
    dense (rows, E*d) intermediates, measured at 260 GiB/device on
    granite-moe train_4k (§Perf iteration log). Overflowing tokens are
    dropped (GShard-style, capacity_factor=1.25); the aux loss keeps
    routing balanced. ``impl="ragged"`` keeps the dropless grouped-GEMM
    path (custom sparse VJP) for TPU megablox-class backends.
    """
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    B, S, D = x.shape
    if fsdp_axis is not None:   # FSDP all-gather of the embed shards
        wi = jax.lax.all_gather(wi, fsdp_axis, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
    x_flat = x.reshape(B * S, D)
    T = B * S
    with jax.named_scope("router"):
        weights, top_i, aux = _route(x_flat, router_w, cfg)
    with jax.named_scope("dispatch"):
        flat_expert = top_i.reshape(-1)                          # (T*k,)
        sort_idx = jnp.argsort(flat_expert)                      # local sort
        expert_sorted = jnp.take(flat_expert, sort_idx)
        token_of = sort_idx // k
        group_sizes = jnp.bincount(flat_expert, length=E).astype(jnp.int32)
    if cfg.moe.impl == "ragged":
        with jax.named_scope("expert_gemm"):
            xs = jnp.take(x_flat, token_of, axis=0)              # (T*k, d)
            out_sorted = _expert_gemms_ragged(xs, wi, wg, wo, group_sizes)
        with jax.named_scope("combine"):
            inv = jnp.argsort(sort_idx)
            out = jnp.take(out_sorted, inv, axis=0).reshape(T, k, D)
            out = jnp.einsum("tkd,tk->td", out, weights.astype(out.dtype))
    else:
        C = _capacity(cfg, T)
        with jax.named_scope("dispatch_pad"):
            # gather-only dispatch: rows are expert-sorted, so block (e,c)
            # reads sorted row starts[e]+c. No scatter in the forward —
            # XLA:CPU scatter lowering materializes (rows, d)-wide u32
            # index planes (§Perf iteration log).
            starts = jnp.cumsum(group_sizes) - group_sizes       # (E,)
            c_iota = jnp.arange(C)
            blk_valid = c_iota[None, :] < group_sizes[:, None]   # (E, C)
            blk_sorted_idx = jnp.minimum(starts[:, None] + c_iota[None, :],
                                         T * k - 1)
            blk_token = jnp.take(token_of, blk_sorted_idx)       # (E, C)
            xs = jnp.take(x_flat, blk_token.reshape(-1), axis=0)
            xs = (xs.reshape(E, C, D) *
                  blk_valid[..., None].astype(x_flat.dtype))
        with jax.named_scope("expert_gemm"):
            h = jnp.einsum("ecd,edf->ecf", xs, wi)
            g = jnp.einsum("ecd,edf->ecf", xs, wg)
            h = jax.nn.silu(g) * h
            out_blocks = jnp.einsum("ecf,efd->ecd", h, wo)
        with jax.named_scope("combine"):
            pos = jnp.arange(T * k) - jnp.take(starts, expert_sorted)
            keep = pos < C
            flat_blk = expert_sorted * C + jnp.minimum(pos, C - 1)
            gathered = jnp.take(out_blocks.reshape(E * C, D), flat_blk,
                                axis=0)
            gathered = jnp.where(keep[:, None], gathered, 0.0)
            inv = jnp.argsort(sort_idx)
            out = jnp.take(gathered, inv, axis=0).reshape(T, k, D)
            out = jnp.einsum("tkd,tk->td", out, weights.astype(out.dtype))
    with jax.named_scope("reduce"):
        if model_axis is not None:   # partial d_ff contributions
            out = jax.lax.psum(out, model_axis)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        if model_axis is not None:
            aux = jax.lax.pmean(aux, model_axis)
    return out.reshape(B, S, D), aux


def moe_apply(params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN. x: (B, S, d) -> (out, aux_loss).

    With active sharding rules, runs the dispatch/grouped-GEMM interior
    under shard_map (local sort, TP-sharded d_ff, FSDP-gathered weights);
    otherwise runs the plain local path (single device / smoke tests).
    """
    rules = shd.current_rules()
    with jax.named_scope("moe"):
        if rules is None:
            out, aux = _moe_local(x, params["router"], params["wi"],
                                  params["wg"], params["wo"], cfg)
        else:
            mesh = compat.get_mesh()
            rules = shd.filter_rules(rules, mesh)
            batch = rules.get("batch")
            batch_axes = ((batch,) if isinstance(batch, str) else
                          tuple(batch) if batch else ())
            fsdp = rules.get("embed")
            model = rules.get("ff")
            x_spec = P(batch, None, None)
            w_spec = P(None, fsdp, model)       # (E, d, ff)
            wo_spec = P(None, model, fsdp)      # (E, ff, d) — embed stays FSDP
            body = functools.partial(
                _moe_local, cfg=cfg, fsdp_axis=fsdp, model_axis=model,
                batch_axes=batch_axes)
            # wo's embed-dim FSDP shards: gather inside to keep memory flat
            def wrapped(x_, rw, wi_, wg_, wo_):
                if fsdp is not None:
                    wo_f = jax.lax.all_gather(wo_, fsdp, axis=2, tiled=True)
                else:
                    wo_f = wo_
                return body(x_, rw, wi_, wg_, wo_f)
            out, aux = compat.shard_map(
                wrapped, mesh=mesh,
                in_specs=(x_spec, P(None, None), w_spec, w_spec, wo_spec),
                out_specs=(x_spec, P()),
                check_vma=False,
            )(x, params["router"], params["wi"], params["wg"], params["wo"])
        if cfg.moe.dense_residual:
            with jax.named_scope("dense_residual"):
                from repro.models.layers import mlp_apply
                res = mlp_apply({"wi": params["res_wi"], "wg": params["res_wg"],
                                 "wo": params["res_wo"]}, x)
            out = out + res
    return out, aux * cfg.moe.aux_loss_weight
