"""Modality-frontend STUBS (per the assignment).

``[audio]`` (musicgen) and ``[vlm]`` (qwen2-vl) entries specify the
transformer BACKBONE only — the EnCodec / vision-patch frontend is a stub
whose job is to define the *input contract*: ``input_specs()`` provides
precomputed frame/patch embeddings of shape (B, S, d_model) plus, for
M-RoPE, the 3-stream position ids.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_input_specs(cfg: ModelConfig, batch: int, seq: int,
                         compute_dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the stubbed frontend outputs."""
    specs: Dict[str, jax.ShapeDtypeStruct] = {
        "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), compute_dtype),
    }
    if cfg.pos_emb == "mrope":
        specs["positions"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
    return specs


def synth_frontend_batch(cfg: ModelConfig, batch: int, seq: int,
                         compute_dtype, key) -> Dict[str, jax.Array]:
    """Concrete synthetic frontend outputs (smoke tests / examples)."""
    k1, _ = jax.random.split(key)
    out = {
        "embeds": (jax.random.normal(k1, (batch, seq, cfg.d_model), jnp.float32)
                   * 0.02).astype(compute_dtype),
    }
    if cfg.pos_emb == "mrope":
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, None],
                               (3, batch, seq))
        out["positions"] = pos
    return out
