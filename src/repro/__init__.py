"""repro — RealProbe (Kim & Hao, 2025) adapted to TPU/JAX.

A production-style JAX LM training/serving framework whose first-class
feature is a non-intrusive, hierarchical, on-device performance profiler:

- ``repro.core``        the paper's contribution (probe pragma, hierarchy
                        extraction, cycle counters, buffer/offload, oracle,
                        overhead model, DSE, incremental re-instrumentation)
- ``repro.models``      LM substrate (dense/GQA/MoE/SSM/hybrid + stubs)
- ``repro.kernels``     Pallas TPU kernels (flash attention, SSD scan)
- ``repro.distributed`` DP/FSDP/TP/EP/SP sharding, pipeline, compression
- ``repro.configs``     the 10 assigned architectures × 4 input shapes
- ``repro.launch``      production mesh, multi-pod dry-run, train/serve
"""

__version__ = "0.1.0"
