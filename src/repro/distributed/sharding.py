"""Logical-axis sharding: rules mapping logical axes -> mesh axes.

Model code annotates activations with ``shard(x, "batch", "seq", "ff")``
and parameters carry logical axes in their schema. A *rule set* (a dict
``logical -> mesh axis | tuple | None``) resolves those names. When no
rule set is active (single-device smoke tests) everything is a no-op, so
the model code is mesh-agnostic — the same non-intrusiveness stance the
paper takes for its profiler.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Dict, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_RULES: contextvars.ContextVar[Optional[Dict[str, Any]]] = \
    contextvars.ContextVar("repro_axis_rules", default=None)


# Rule sets. ``pod`` only exists on the multi-pod mesh; resolution drops
# mesh axes that are absent from the active mesh.
TRAIN_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    # FSDP: weight embed-dim sharded over data AND pod (ZeRO-3 across
    # pods — param/optimizer state halves again on the multi-pod mesh;
    # the cross-DCI gathers are the price, and what int8_ef compression
    # and microbatch overlap are for). Single-pod meshes filter "pod"
    # out automatically.
    "embed": ("pod", "data"),
    "vocab": "model",
    "ff": "model",
    "q_heads": "model",
    "kv_heads": "model",
    "q_per_kv": None,
    "head_dim": None,
    "expert": None,           # experts replicated; expert d_ff TP-sharded
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_head_dim": None,
    "ssm_state": None,
    "conv": None,
    "layers": None,
    "kv_seq": None,
    # Megatron-style sequence parallelism for the residual stream: the
    # between-layer carry (what remat stashes per layer!) is sharded over
    # the model axis on seq; GSPMD inserts the all-gather before attention
    # and the reduce-scatter after per-token blocks. 16x smaller stash.
    "act_seq": "model",
}

# Serving: batch over (pod, data); KV cache sequence-sharded over the
# model axis (distributed split-KV decode — always divisible, unlike
# kv_heads which is < 16 on most assigned archs).
SERVE_RULES: Dict[str, Any] = dict(TRAIN_RULES)
SERVE_RULES.update({"batch": ("pod", "data"), "embed": "data",
                    "kv_seq": "model"})

# long_500k (global_batch=1): batch can't shard — spread the KV/state
# sequence over BOTH axes (524288 / 256 = 2048 per device).
SERVE_LONG_RULES: Dict[str, Any] = dict(SERVE_RULES)
SERVE_LONG_RULES.update({"batch": "pod", "kv_seq": ("model", "data")})


@contextlib.contextmanager
def axis_rules(rules: Optional[Dict[str, Any]], mesh: Optional[Mesh] = None):
    """Activate a rule set (optionally filtered to the mesh's axis names)."""
    if rules is not None and mesh is not None:
        rules = filter_rules(rules, mesh)
    tok = _ACTIVE_RULES.set(rules)
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(tok)


def filter_rules(rules: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    """Drop mesh axes that don't exist on ``mesh`` from every rule."""
    names = set(mesh.axis_names)

    def fix(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in names else None
        v = tuple(a for a in v if a in names)
        return v if len(v) > 1 else (v[0] if v else None)

    return {k: fix(v) for k, v in rules.items()}


def current_rules() -> Optional[Dict[str, Any]]:
    return _ACTIVE_RULES.get()


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    try:
        return dict(mesh.shape)
    except Exception:
        return {n: s for n, s in zip(mesh.axis_names, mesh.axis_sizes)}


def to_pspec(axes: Sequence[Any], rules: Dict[str, Any],
             shape: Optional[Sequence[int]] = None,
             mesh=None) -> P:
    """Resolve logical axis names to a PartitionSpec.

    - a mesh axis may shard at most one dimension (later dup dropped);
    - with ``shape``+``mesh``: any dimension NOT divisible by its mesh
      axis size falls back to replication (e.g. kv_heads=8 or q_heads=36
      on a model=16 mesh). This is the production divisibility rule —
      GSPMD input shardings must tile evenly.
    """
    sizes = _mesh_axis_sizes(mesh) if mesh is not None else None
    manual: set = set()
    if mesh is not None:
        try:
            from jax.sharding import AxisType
            manual = {n for n, t in zip(mesh.axis_names, mesh.axis_types)
                      if t == AxisType.Manual}
        except Exception:
            manual = set()
    used: set = set()
    parts = []
    for i, a in enumerate(axes):
        r = rules.get(a) if a is not None else None
        if r is None:
            parts.append(None)
            continue
        rt = (r,) if isinstance(r, str) else tuple(r)
        # axes already Manual (inside a partial shard_map) are implicit
        rt = tuple(x for x in rt if x not in used and x not in manual)
        if sizes is not None and shape is not None and rt:
            total = 1
            for x in rt:
                total *= sizes.get(x, 1)
            if total == 0 or shape[i] % total != 0:
                parts.append(None)
                continue
        used.update(rt)
        parts.append(rt if len(rt) > 1 else (rt[0] if rt else None))
    return P(*parts)


def shard(x, *axes):
    """Apply a sharding constraint by logical axis names (no-op w/o rules)."""
    rules = _ACTIVE_RULES.get()
    if rules is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank {x.ndim} vs axes {axes}")
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        mesh = None
    if mesh is None or not getattr(mesh, "axis_names", None):
        return x
    spec = to_pspec(axes, rules, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def _is_param(x):
    from repro.models.layers import Param
    return isinstance(x, Param)


def schema_pspecs(schema: Any, rules: Dict[str, Any], mesh) -> Any:
    """Param-schema tree -> divisibility-resolved PartitionSpec tree."""
    rules = filter_rules(rules, mesh)
    return jax.tree_util.tree_map(
        lambda p: to_pspec(p.axes, rules, shape=p.shape, mesh=mesh),
        schema, is_leaf=_is_param)


def param_shardings(schema: Any, mesh: Mesh, rules: Dict[str, Any]) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), schema_pspecs(schema, rules, mesh),
        is_leaf=lambda x: isinstance(x, P))
