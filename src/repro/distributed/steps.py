"""Train / prefill / decode step builders.

These close over a ``Model`` + ``TrainConfig`` and produce pure functions
ready for ``jax.jit`` with explicit in/out shardings — used identically
by the real trainer (``launch/train.py``), the multi-pod dry-run
(``launch/dryrun.py``), and the RealProbe integration tests (the probed
function IS the train step).

Features:
- microbatched gradient accumulation (``TrainConfig.microbatches``):
  lax.scan over microbatches so XLA's latency-hiding scheduler can
  overlap microbatch k's gradient reduce-scatter with k+1's compute;
- optional int8 error-feedback compression of the cross-pod gradient
  exchange (``grad_compression="int8_ef"``): gradients stay pod-local
  (partial-manual shard_map over the ``pod`` axis; data/model stay
  auto-sharded inside), get quantized to int8 with per-tensor scales, and
  ring-exchange across pods at 1 byte/element over DCI instead of 4,
  with the quantization error carried as error-feedback state;
- dtype policies handled by the model/optimizer (bf16 compute, fp32 or
  bf16 master+moments).
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeConfig, TrainConfig
from repro.distributed import compat
from repro.models.model import Model
from repro.optim import adamw, compression
from repro.optim.schedule import make_schedule


def _split_microbatches(batch: Dict[str, Any], k: int) -> Dict[str, Any]:
    def split(x):
        if x.ndim == 0:
            return x
        b = x.shape[0]
        if b % k:
            raise ValueError(f"batch {b} % microbatches {k}")
        return x.reshape((k, b // k) + x.shape[1:])
    return {key: split(v) for key, v in batch.items()}


def build_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch[, ef_residual])."""
    cfg = model.cfg
    schedule = make_schedule(cfg.schedule, tcfg)
    k = tcfg.microbatches

    def loss_fn(params, batch):
        if "positions" in batch and cfg.pos_emb == "mrope" and \
                batch["positions"].shape[0] != 3:
            batch = dict(batch)
            batch["positions"] = jnp.moveaxis(batch["positions"], 1, 0)
        with jax.named_scope("loss"):
            return model.loss_fn(params, batch)

    def grads_of(params, batch):
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        b = dict(batch)
        if cfg.pos_emb == "mrope" and "positions" in b:
            b["positions"] = jnp.moveaxis(b["positions"], 0, 1)  # (B,3,S)
        mb = _split_microbatches(b, k)

        acc_dt = jnp.dtype(cfg.grad_accum_dtype)

        def body(acc, micro):
            (loss, _metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, micro)
            gsum = jax.tree_util.tree_map(
                lambda a, gg: a + gg.astype(acc_dt), acc[0], g)
            return (gsum, acc[1] + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        with jax.named_scope("microbatches"):
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mb)
        grads = jax.tree_util.tree_map(lambda g: g / k, gsum)
        loss = loss_sum / k
        return loss, {"nll": loss}, grads

    def compressed_grads_of(params, batch, residual):
        """Pod-local grads + int8 error-feedback ring exchange over the
        pod axis. data/model axes stay auto-sharded inside."""
        mesh = compat.get_mesh()
        n_pods = mesh.shape["pod"]
        perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]

        def pod_local(params_, batch_, res_):
            loss, metrics, grads = grads_of(params_, batch_)
            with jax.named_scope("grad_compress"):
                payload, scales, new_res = compression.compress(grads, res_)

                def xchg(q8, s):
                    total = q8.astype(jnp.float32) * s
                    q_rot, s_rot = q8, s
                    for _ in range(n_pods - 1):     # int8 on the wire
                        q_rot = jax.lax.ppermute(q_rot, "pod", perm)
                        s_rot = jax.lax.ppermute(s_rot, "pod", perm)
                        total = total + q_rot.astype(jnp.float32) * s_rot
                    return total / n_pods

                grads = jax.tree_util.tree_map(xchg, payload, scales)
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(m, "pod"), metrics)
            return loss, metrics, grads, new_res

        def batch_spec(x):
            if x.ndim == 0:
                return P()
            if cfg.pos_emb == "mrope" and x.ndim == 3 and x.shape[0] == 3:
                return P(None, "pod")
            return P("pod")

        in_batch_specs = {kk: batch_spec(v) for kk, v in batch.items()}
        rep_p = jax.tree_util.tree_map(lambda _: P(), params)
        rep_r = jax.tree_util.tree_map(lambda _: P(), residual)
        metrics_spec = {"nll": P()} if k > 1 else \
            {"nll": P(), "z_loss": P(), "aux_loss": P()}
        return compat.shard_map(
            pod_local, mesh=mesh,
            in_specs=(rep_p, in_batch_specs, rep_r),
            out_specs=(P(), metrics_spec, rep_p, rep_r),
            axis_names={"pod"}, check_vma=False,
        )(params, batch, residual)

    def train_step(params, opt_state, batch, ef_residual=None):
        if ef_residual is not None and tcfg.grad_compression == "int8_ef":
            loss, metrics, grads, ef_residual = compressed_grads_of(
                params, batch, ef_residual)
        else:
            loss, metrics, grads = grads_of(params, batch)
        with jax.named_scope("optimizer"):
            params, opt_state, om = adamw.update(params, grads, opt_state,
                                                 tcfg, schedule)
        metrics = dict(metrics)
        metrics.update(loss=loss, **om)
        if ef_residual is not None:
            return params, opt_state, ef_residual, metrics
        return params, opt_state, metrics

    return train_step


def build_prefill_step(model: Model, shape: ShapeConfig) -> Callable:
    k = model.cfg.prefill_microbatches

    def prefill_step(params, batch):
        if k == 1:
            with jax.named_scope("prefill"):
                logits, cache = model.prefill(params, batch, shape.seq_len)
            return logits, cache

        # batch-chunked prefill: fwd activations scale with B/k while the
        # cache output stays identical (32k-prompt HBM lever; the serving
        # engine's request batching maps directly onto this).
        def split(key, v):
            if key == "positions" and v.ndim == 3 and v.shape[0] == 3:
                b = v.shape[1]
                return jnp.moveaxis(
                    v.reshape(3, k, b // k, v.shape[2]), 1, 0)
            return v.reshape((k, v.shape[0] // k) + v.shape[1:])

        mb = {key: split(key, v) for key, v in batch.items()}
        # keep the chunked batch data-sharded through the map reshape
        from repro.distributed import sharding as shd
        def respec(key, v):
            if key == "positions" and v.ndim == 4:
                return shd.shard(v, None, None, "batch", "seq")
            if v.ndim == 3:
                return shd.shard(v, None, "batch", "seq")
            return v
        mb = {key: respec(key, v) for key, v in mb.items()}

        def body(b):
            if "positions" in b and b["positions"].ndim == 3:
                pass
            with jax.named_scope("prefill_chunk"):
                return model.prefill(params, b, shape.seq_len)

        logits, cache = jax.lax.map(body, mb)
        logits = logits.reshape((-1,) + logits.shape[2:])
        # cache leaves: (k, L, B/k, ...) -> (L, B, ...)
        cache = jax.tree_util.tree_map(
            lambda a: jnp.moveaxis(a, 0, 1).reshape(
                (a.shape[1], a.shape[0] * a.shape[2]) + a.shape[3:]),
            cache)
        return logits, cache

    return prefill_step


def build_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, batch):
        with jax.named_scope("decode"):
            logits, cache, next_token = model.decode_step(params, cache,
                                                          batch)
        return logits, cache, next_token
    return decode_step


def build_eval_step(model: Model) -> Callable:
    """Forward-only eval step (loss + metrics, no optimizer). Probeable
    as-is on one device, or per shard via ``build_dp_eval_step``."""
    def eval_step(params, batch):
        with jax.named_scope("eval"):
            loss, metrics = model.loss_fn(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return loss, metrics
    return eval_step


# ---------------------------------------------------- per-shard bodies
#
# Explicit-collective SPMD bodies for `shard_map` — and therefore for
# `repro.core.mesh_probe`, which records a per-device cycle row for
# every probe inside them. Parameters/optimizer state are replicated,
# the batch is sharded over `axis` (pure data parallelism), and the
# gradient exchange is an explicit `psum`-mean that the probe attributes
# to the "grad_exchange" scope (ring wire-byte model; see
# launch/collectives.py). The auto-sharded `build_train_step` stays the
# production path — these exist so the *same* training math is
# observable per device.

def _pmean_tree(tree, axis):
    return jax.tree_util.tree_map(lambda x: jax.lax.pmean(x, axis), tree)


def build_dp_train_step(model: Model, tcfg: TrainConfig,
                        axis="dev") -> Callable:
    """Data-parallel per-shard train step: grads_local -> psum-mean over
    ``axis`` -> replicated AdamW update. Returns
    ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` with every output replicated."""
    schedule = make_schedule(model.cfg.schedule, tcfg)

    def loss_fn(params, batch):
        with jax.named_scope("loss"):
            return model.loss_fn(params, batch)

    def train_step(params, opt_state, batch):
        with jax.named_scope("grads"):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        with jax.named_scope("grad_exchange"):
            grads = _pmean_tree(grads, axis)
            loss = jax.lax.pmean(loss, axis)
            metrics = _pmean_tree(metrics, axis)
        with jax.named_scope("optimizer"):
            params, opt_state, om = adamw.update(params, grads, opt_state,
                                                 tcfg, schedule)
        metrics = dict(metrics)
        metrics.update(loss=loss, **om)
        return params, opt_state, metrics

    return train_step


def build_dp_eval_step(model: Model, axis="dev") -> Callable:
    """Data-parallel per-shard eval step (loss psum-meaned over ``axis``)."""
    base = build_eval_step(model)

    def eval_step(params, batch):
        loss, metrics = base(params, batch)
        with jax.named_scope("loss_exchange"):
            loss = jax.lax.pmean(loss, axis)
            metrics = _pmean_tree(metrics, axis)
        return loss, metrics

    return eval_step
