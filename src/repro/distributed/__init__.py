# NOTE: steps.py imports repro.models which imports repro.distributed.sharding;
# keep this __init__ free of step imports to avoid the cycle.
from repro.distributed import sharding

__all__ = ["sharding"]
