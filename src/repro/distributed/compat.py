"""Version compatibility for jax's sharding surface.

The repo targets the current jax API (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``) but must also run on the
0.4.x line the CI benchmark baselines are pinned to, where the same
machinery lives under ``jax.experimental.shard_map`` with a different
keyword surface and the mesh context is the legacy ``Mesh`` context
manager. Everything that touches that surface goes through here so the
rest of the codebase reads as if only one jax existed.
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Iterable, Optional, Tuple

import jax


def supports_partial_manual() -> bool:
    """True when this jax can run partial-manual ``shard_map`` (a
    subset of mesh axes manual, the rest auto-sharded inside). The
    0.4.x line cannot — its SPMD partitioner aborts on the resulting
    ``CustomCallSharding`` (a hard ``Check failed`` in XLA, not a
    catchable exception) — so callers get the full-manual fallback
    below instead."""
    return hasattr(jax, "shard_map")


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None):
    """``jax.shard_map`` with the new keyword surface on every jax.

    ``axis_names`` restricts manual axes (the rest stay auto-sharded);
    ``check_vma`` / ``check_rep`` are the new/old names for the same
    replication check.

    On jax 0.4.x a partial-manual request falls back to a FULL-manual
    region with the same in/out specs: the named collectives still see
    exactly the manual axes they ask for, and axes absent from a spec
    are simply replicated into the body instead of auto-partitioned —
    identical math, less automatic parallelism inside the region. GSPMD
    sharding constraints are meaningless inside a fully-manual region,
    so the repo's logical-axis rules are suspended while the body
    traces (they would otherwise emit constraints the old partitioner
    rejects)."""
    check = check_vma if check_vma is not None else check_rep
    if supports_partial_manual():        # the modern jax.shard_map path
        kw: Dict[str, Any] = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check is not None:
            kw["check_vma"] = bool(check)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    body = f
    partial = axis_names is not None and \
        frozenset(mesh.axis_names) - set(axis_names)
    if partial:
        from repro.distributed import sharding as _shd

        def body(*args, **kwargs):
            with _shd.axis_rules(None):
                return f(*args, **kwargs)
        # full-manual: replication of the formerly-auto axes cannot be
        # checked by the old rep machinery either, so force it off
        check = False
    kw = {}
    if check is not None:
        kw["check_rep"] = bool(check)
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               **kw)


def mesh_context(mesh):
    """Enter ``mesh`` as the ambient mesh (``jax.set_mesh`` analogue).

    Falls back to ``jax.sharding.use_mesh`` and finally to the legacy
    ``Mesh`` context manager on old jax. ``mesh=None`` is a no-op.
    """
    if mesh is None:
        return contextlib.nullcontext()
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh  # legacy: Mesh is itself a context manager


def get_mesh():
    """The ambient mesh (abstract on new jax, physical on old), or None."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and getattr(m, "axis_names", None):
            return m
    except AttributeError:
        pass
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


@contextlib.contextmanager
def extend_axis_env(sizes: Dict[str, int]):
    """Bind mesh axis names for out-of-``shard_map`` tracing.

    Lets ``jax.make_jaxpr`` trace a per-shard function that uses
    collectives (``lax.psum(x, "dev")`` …) without an enclosing
    ``shard_map`` — the mesh-probe builder traces the shard body once
    this way. No-op when the running jax needs no env (or the private
    helper moved); the caller then falls back to collective-free
    tracing errors surfacing naturally.
    """
    items: Iterable[Tuple[str, int]] = tuple(sizes.items())
    ext = None
    for modname in ("jax._src.core", "jax.core"):
        try:
            mod = __import__(modname, fromlist=["extend_axis_env_nd"])
            ext = getattr(mod, "extend_axis_env_nd", None)
        except ImportError:
            ext = None
        if ext is not None:
            break
    if ext is None:
        yield
        return
    with ext(list(items)):
        yield
