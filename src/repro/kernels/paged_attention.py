"""Paged-attention decode kernel — Pallas TPU, bit-exact by construction.

Single-token GQA decode over a paged KV cache: each sequence's cache
lives in non-contiguous fixed-size pages of a shared pool, addressed
through a per-request page table row. The page indices are **scalar
prefetch** operands (``pltpu.PrefetchScalarGridSpec``), so the
BlockSpec index maps chase the page table and the pipeline DMAs each
page of the pool directly into VMEM — the dense (B, S_max) gather that
the XLA fallback materializes in HBM never exists.

Exactness contract (the serving engine's bit-identity guarantee rests
on this): the kernel does NOT use streaming flash softmax. It stages
the pages into a VMEM scratch shaped exactly like the dense gather and
then runs the *same einsum shapes and the same global softmax* as the
reference ``models.attention.attn_decode`` — equal-length reductions
over equal values produce equal floats, so the output is bit-identical
to the unpaged reference (asserted in tests/test_engine.py). Slots
beyond ``pos`` contribute exact ``exp(-inf) = 0.0``, which also makes
stale contents of reused pool pages harmless.

RealProbe tie-in: the copy/attend phases sit under named scopes so
``ProbeConfig(kernel_probes=...)`` attributes per-grid-step cycles to
page staging vs attend math, and ``pages_per_step`` (pages DMA'd per
grid step — the pipelining depth) is a DSE axis tuned by
``kernels.search_spaces.paged_attention_space``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_PAGES_PER_STEP = 1

_SEMANTICS = ("parallel", "arbitrary")


def _compiler_params(interpret: bool):
    if interpret:
        return None
    if hasattr(pltpu, "CompilerParams"):             # jax >= 0.7 style
        return pltpu.CompilerParams(dimension_semantics=_SEMANTICS)
    return dict(mosaic=dict(dimension_semantics=_SEMANTICS))


def _paged_kernel(pages_ref, pos_ref, q_ref, *rest, pages_per_step: int,
                  page_size: int, n_pages: int, sm_scale: float):
    k_refs = rest[:pages_per_step]
    v_refs = rest[pages_per_step:2 * pages_per_step]
    o_ref = rest[2 * pages_per_step]
    k_scr, v_scr = rest[2 * pages_per_step + 1:]
    # NB: every program_id/num_programs read happens at the kernel's
    # top level — inside a pl.when body they are not substituted by the
    # interpret-mode evaluator (jax 0.4.x).
    b = pl.program_id(0)
    j = pl.program_id(1)
    n_steps = pl.num_programs(1)
    s_max = n_pages * page_size

    with jax.named_scope("copy_pages"):
        # one grid step stages `pages_per_step` pool pages into the
        # dense VMEM scratch (statically unrolled DMA group)
        for i in range(pages_per_step):
            k_scr[j * pages_per_step + i] = k_refs[i][...]
            v_scr[j * pages_per_step + i] = v_refs[i][...]

    with jax.named_scope("attend"):
        @pl.when(j == n_steps - 1)
        def _attend():
            # dense-shape global softmax: identical einsum shapes and
            # reduction lengths as the XLA reference — not flash
            kv, g, hd = q_ref.shape[1:]
            qg = q_ref[...][:, None]                 # (1, 1, kv, g, hd)
            kd = k_scr[...].reshape(1, s_max, kv, hd)
            vd = v_scr[...].reshape(1, s_max, kv, hd)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.bfloat16),
                           kd.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32) * sm_scale
            mask = jnp.arange(s_max)[None, :] <= pos_ref[b][None, None]
            s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
            m = s.max(axis=-1, keepdims=True)
            p = jnp.exp(s - m)
            l = p.sum(axis=-1, keepdims=True)
            o = jnp.einsum("bkgqs,bskh->bkgqh", (p / l).astype(jnp.bfloat16),
                           vd.astype(jnp.bfloat16),
                           preferred_element_type=jnp.float32)
            o_ref[...] = o[:, :, :, 0]


def paged_attention(q, pool_k, pool_v, pages, pos, *,
                    pages_per_step: int = DEFAULT_PAGES_PER_STEP,
                    interpret: bool = False):
    """Paged single-token GQA decode attention.

    q:       (B, kv_heads, q_per_kv, head_dim) — current-token queries
    pool_k:  (num_pool_pages, page_size, kv_heads, head_dim)
    pool_v:  same shape as pool_k
    pages:   (B, n_pages) int32 page-table rows into the pool
    pos:     (B,) int32 current position (slots > pos are masked)

    ``pages_per_step`` pages are fetched per grid step (the pool is
    bound once per page slot, so page-table rows stay arbitrary — no
    contiguity requirement on the allocator).

    Returns (B, kv_heads, q_per_kv, head_dim) float32 — bit-identical
    to the dense-gather reference over ``pool[pages]``.
    """
    B, kv, g, hd = q.shape
    page_size = pool_k.shape[1]
    n_pages = pages.shape[1]
    if pages_per_step < 1 or n_pages % pages_per_step:
        raise ValueError(f"pages_per_step {pages_per_step} must divide "
                         f"page-table width {n_pages}")
    n_steps = n_pages // pages_per_step
    sm_scale = 1.0 / math.sqrt(hd)

    def page_map(i):
        def index_map(b, j, pages_ref, pos_ref):
            del pos_ref
            return (pages_ref[b, j * pages_per_step + i], 0, 0, 0)
        return index_map

    def q_map(b, j, pages_ref, pos_ref):
        del pages_ref, pos_ref
        return (b, 0, 0, 0)

    page_block = (1, page_size, kv, hd)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_steps),
        in_specs=(
            [pl.BlockSpec((1, kv, g, hd), q_map)]
            + [pl.BlockSpec(page_block, page_map(i))
               for i in range(pages_per_step)]
            + [pl.BlockSpec(page_block, page_map(i))
               for i in range(pages_per_step)]
        ),
        out_specs=pl.BlockSpec((1, kv, g, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((n_pages, 1, page_size, kv, hd), pool_k.dtype),
            pltpu.VMEM((n_pages, 1, page_size, kv, hd), pool_v.dtype),
        ],
    )
    kernel = functools.partial(
        _paged_kernel, pages_per_step=pages_per_step, page_size=page_size,
        n_pages=n_pages, sm_scale=sm_scale)
    pools = [pool_k] * pages_per_step + [pool_v] * pages_per_step
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kv, g, hd), jnp.float32),
        compiler_params=_compiler_params(interpret),
        interpret=interpret,
    )(pages, pos, q, *pools)
