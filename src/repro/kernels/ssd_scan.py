"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

TPU-native adaptation of the SSD algorithm [arXiv:2405.21060]: the
sequence is tiled into VMEM-resident chunks; each grid step computes the
intra-chunk quadratic term on the MXU and carries the running SSM state
(P x N, f32) in VMEM scratch across the *sequential* chunk grid
dimension — the TPU analogue of the GPU kernel's cross-CTA state passing
(no TPU equivalent of grid-sync exists; the sequential-innermost-grid-dim
contract replaces it, as documented in DESIGN.md).

Grid: (B, H, n_chunks), chunk dim innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_SEMANTICS = ("parallel", "parallel", "arbitrary")


def _compiler_params(interpret: bool):
    if interpret:
        return None
    if hasattr(pltpu, "CompilerParams"):
        return pltpu.CompilerParams(dimension_semantics=_SEMANTICS)
    return dict(mosaic=dict(dimension_semantics=_SEMANTICS))


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_scratch,
                *, chunk: int, pipeline: int):
    ci = pl.program_id(2)

    # named scopes are RealProbe grid-step markers (trace metadata only;
    # identical equations with probing off) — see core.kernelprobe
    with jax.named_scope("init"):
        @pl.when(ci == 0)
        def _init():
            state_scratch[...] = jnp.zeros_like(state_scratch)

    # the VMEM tile is `chunk` long; the quadratic intra-chunk term is
    # evaluated over `pipeline` sub-chunks of length Q = chunk/pipeline,
    # carrying the SSM state across them — O(chunk^2)/pipeline FLOPs at
    # unchanged DMA granularity
    sub = chunk // pipeline
    for p in range(pipeline):
        with jax.named_scope("sub_chunk"):
            lo, hi = p * sub, (p + 1) * sub
            x = x_ref[0, 0, lo:hi].astype(jnp.float32)     # (Q, P)
            a = a_ref[0, 0, lo:hi].astype(jnp.float32)     # (Q,)
            b = b_ref[0, 0, lo:hi].astype(jnp.float32)     # (Q, N)
            c = c_ref[0, 0, lo:hi].astype(jnp.float32)     # (Q, N)

            a_cs = jnp.cumsum(a)                           # (Q,)
            # intra-chunk:
            #   y_diag[q] = sum_{k<=q} exp(a_cs[q]-a_cs[k]) (c_q.b_k) x_k
            cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            seg = a_cs[:, None] - a_cs[None, :]
            qi = jax.lax.broadcasted_iota(jnp.int32, (sub, sub), 0)
            ki = jax.lax.broadcasted_iota(jnp.int32, (sub, sub), 1)
            decay = jnp.where(qi >= ki, jnp.exp(seg), 0.0)
            y_diag = jax.lax.dot_general(cb * decay, x,
                                         (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
            # inter-chunk: y_off[q] = exp(a_cs[q]) * c_q . state ((P, N))
            state = state_scratch[...]
            y_off = jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32)
            y_off = y_off * jnp.exp(a_cs)[:, None]
            y_ref[0, 0, lo:hi] = (y_diag + y_off).astype(y_ref.dtype)
            # state': exp(a_cs[-1]) * state + sum_k d_k x_k b_k^T
            decay_states = jnp.exp(a_cs[-1] - a_cs)        # (Q,)
            xb = jax.lax.dot_general(x * decay_states[:, None], b,
                                     (((0,), (0,)), ((), ())),
                                     preferred_element_type=jnp.float32)
            state_scratch[...] = state * jnp.exp(a_cs[-1]) + xb


def ssd_scan(x, a, b, c, *, chunk: int = 256, pipeline: int = 1,
             interpret: bool = False):
    """x: (B, H, L, P); a: (B, H, L); b, c: (B, G, L, N), H % G == 0.

    ``pipeline`` subdivides each VMEM-resident chunk into that many
    sequentially-scanned sub-chunks (state carried in scratch), cutting
    the quadratic intra-chunk FLOPs without shrinking the DMA tile.

    Returns y (B, H, L, P) in x.dtype. L % chunk and chunk % pipeline
    must be 0.
    """
    B, H, L, P = x.shape
    G, N = b.shape[1], b.shape[3]
    if H % G:
        raise ValueError(f"H {H} % G {G}")
    e = H // G
    if L % chunk:
        raise ValueError(f"L {L} % chunk {chunk}")
    if pipeline < 1 or chunk % pipeline:
        raise ValueError(f"chunk {chunk} % pipeline {pipeline}")
    nc = L // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, pipeline=pipeline)
    grid = (B, H, nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, h, ci: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, h, ci: (bi, h, ci)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, h, ci: (bi, h // e, ci, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda bi, h, ci: (bi, h // e, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P),
                               lambda bi, h, ci: (bi, h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_compiler_params(interpret),
        interpret=interpret,
    )(x, a, b, c)
