"""Causal GQA flash attention — Pallas TPU kernel.

TPU-native adaptation (HBM->VMEM tiling, MXU-aligned 128x128 blocks,
f32 running-softmax state in VMEM scratch, sequential kv grid dim).

RealProbe tie-in: the kernel optionally emits a **decoupled probe
output** — per (batch, head, q-block) counters of kv blocks visited vs
actually computed (causal skip). Exactly like the paper's profiler IP,
the counters live in separate storage, are written on "control events"
only (block entry), and do not touch the datapath, so enabling them
cannot change the attention output.

Grid: (B, H, num_q_blocks, num_kv_blocks); the kv dim is innermost and
sequential ("arbitrary") so the scratch accumulator carries across it.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = float("-inf")

_SEMANTICS = ("parallel", "parallel", "parallel", "arbitrary")


def _compiler_params(interpret: bool):
    if interpret:
        return None
    if hasattr(pltpu, "CompilerParams"):             # jax >= 0.7 style
        return pltpu.CompilerParams(dimension_semantics=_SEMANTICS)
    return dict(mosaic=dict(dimension_semantics=_SEMANTICS))


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, probe_ref,
                  acc_ref, m_ref, l_ref,
                  *, block_q: int, block_k: int, pipeline: int, causal: bool,
                  sm_scale: float, with_probe: bool):
    iq = pl.program_id(2)
    ig = pl.program_id(3)            # kv DMA-group index (pipeline blocks)
    ng = pl.num_programs(3)
    nk = ng * pipeline               # total kv blocks

    # named scopes below are RealProbe grid-step markers: pure trace
    # metadata (the emitted equations are identical with probing off),
    # picked up by hierarchy extraction under ProbeConfig(kernel_probes)
    with jax.named_scope("init"):
        @pl.when(ig == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            if with_probe:
                probe_ref[...] = jnp.zeros_like(probe_ref)

    # each grid step fetches `pipeline` kv blocks in one DMA group and
    # runs the MXU tiles over them back to back (statically unrolled)
    for p in range(pipeline):
        with jax.named_scope("kv_block"):
            ik = ig * pipeline + p
            # causal skip decided by the q block's LAST row: any kv block
            # starting at or before it intersects the causal triangle
            should_compute = ((iq + 1) * block_q - 1 >= ik * block_k) \
                if causal else True

            if with_probe:
                # control-event counters: [0]=blocks visited,
                # [1]=blocks computed
                probe_ref[0, 0, 0, 0] += 1
                probe_ref[0, 0, 0, 1] += jnp.where(
                    should_compute, 1, 0).astype(probe_ref.dtype)

            @pl.when(should_compute)
            def _compute(p=p, ik=ik):
                q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
                k = k_ref[0, 0, p * block_k:(p + 1) * block_k].astype(
                    jnp.float32)                               # (bk, D)
                s = jax.lax.dot_general(
                    q, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * sm_scale
                if causal:
                    q_pos = iq * block_q + jax.lax.broadcasted_iota(
                        jnp.int32, (block_q, block_k), 0)
                    k_pos = ik * block_k + jax.lax.broadcasted_iota(
                        jnp.int32, (block_q, block_k), 1)
                    s = jnp.where(q_pos >= k_pos, s, NEG_INF)
                m_prev = m_ref[...]
                m_new = jnp.maximum(m_prev, s.max(axis=-1))
                m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
                p_ = jnp.exp(s - m_safe[:, None])
                corr = jnp.where(jnp.isneginf(m_prev), 0.0,
                                 jnp.exp(m_prev - m_safe))
                l_ref[...] = l_ref[...] * corr + p_.sum(axis=-1)
                v = v_ref[0, 0, p * block_k:(p + 1) * block_k].astype(
                    jnp.float32)                               # (bk, D)
                pv = jax.lax.dot_general(
                    p_, v, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                acc_ref[...] = acc_ref[...] * corr[:, None] + pv
                m_ref[...] = m_new

    with jax.named_scope("finalize"):
        # last group holding the causal diagonal of this q block — based
        # on the block's LAST row (its first row under-counts when
        # bq > bk)
        last_g = (jnp.minimum(((iq + 1) * block_q - 1) // block_k, nk - 1)
                  // pipeline) if causal else ng - 1

        @pl.when(ig == last_g)
        def _finalize():
            l = jnp.maximum(l_ref[...], 1e-37)
            o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    pipeline: int = 1,
                    with_probe: bool = False,
                    interpret: bool = False):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D), H % Hkv == 0.

    ``pipeline`` is the kv software-pipelining depth: each grid step
    DMAs ``pipeline`` consecutive kv blocks into VMEM and sweeps the
    MXU tiles over them (fewer, larger transfers; same math).

    Returns (B, H, S, D) [, probe (B, H, nq, 2) int32 if with_probe].
    """
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    if H % Hkv:
        raise ValueError(f"H {H} % Hkv {Hkv}")
    qpk = H // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    if S % block_q or S % block_k:
        raise ValueError(f"S {S} not divisible by blocks ({block_q},{block_k})")
    if pipeline < 1:
        raise ValueError(f"pipeline {pipeline} < 1")
    nq, nk = S // block_q, S // block_k
    if nk % pipeline:
        raise ValueError(f"kv blocks {nk} not divisible by pipeline "
                         f"{pipeline}")
    ng = nk // pipeline
    sm_scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, pipeline=pipeline,
        causal=causal, sm_scale=sm_scale, with_probe=with_probe)

    out_shape = [jax.ShapeDtypeStruct((B, H, S, D), q.dtype)]
    out_specs = [pl.BlockSpec((1, 1, block_q, D),
                              lambda b, h, i, j: (b, h, i, 0))]
    out_shape.append(jax.ShapeDtypeStruct((B, H, nq, 2), jnp.int32))
    out_specs.append(pl.BlockSpec((1, 1, 1, 2),
                                  lambda b, h, i, j: (b, h, i, 0)))

    grid = (B, H, nq, ng)
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k * pipeline, D),
                         lambda b, h, i, j: (b, h // qpk, j, 0)),
            pl.BlockSpec((1, 1, block_k * pipeline, D),
                         lambda b, h, i, j: (b, h // qpk, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),     # m
            pltpu.VMEM((block_q,), jnp.float32),     # l
        ],
        compiler_params=_compiler_params(interpret),
        interpret=interpret,
    )(q, k, v)
    out, probe = res
    if with_probe:
        return out, probe
    return out
