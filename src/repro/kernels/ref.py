"""Pure-jnp oracles for the Pallas kernels.

These are the cycle-level ground truth the kernels are validated against
(shape/dtype sweeps in tests/test_kernels.py) — the same role the ILA
plays for RealProbe in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Naive softmax attention.

    q: (B, H, S, D); k, v: (B, Hkv, S, D) with H % Hkv == 0.
    Returns (B, H, S, D) in q.dtype; f32 softmax internally.
    """
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    rep = H // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def ssd_ref(x, a, b, c):
    """Sequential (exact) SSD recurrence.

    x: (B, H, L, P) — discretized inputs (x * dt)
    a: (B, H, L)    — discretized log decay (A * dt)
    b, c: (B, G, L, N) with H % G == 0
    Returns y (B, H, L, P) f32, final_state (B, H, P, N) f32.
    """
    B, H, L, P = x.shape
    G, N = b.shape[1], b.shape[3]
    rep = H // G
    b = jnp.repeat(b, rep, axis=1)          # (B, H, L, N)
    c = jnp.repeat(c, rep, axis=1)

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp            # (B,H,P) (B,H) (B,H,N) (B,H,N)
        da = jnp.exp(a_t.astype(jnp.float32))[..., None, None]
        state = state * da + jnp.einsum("bhp,bhn->bhpn",
                                        x_t.astype(jnp.float32),
                                        b_t.astype(jnp.float32))
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t.astype(jnp.float32))
        return state, y_t

    init = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.transpose(2, 0, 1, 3), a.transpose(2, 0, 1),
          b.transpose(2, 0, 1, 3), c.transpose(2, 0, 1, 3))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 2, 0, 3), final
