"""Tuned kernel defaults — the bridge from DSE results to production.

The autotuner (``repro.core.dse.DSEEngine`` / the ``repro.tune`` CLI)
persists winning configs in the on-disk evaluation cache; this module
holds the process-wide "active" tuned configs that the ``ops`` wrappers
consult when the caller does not pin a value explicitly:

    from repro.kernels import tuning
    tuning.load_cache("flash_attention")     # or serve.py --autotune
    kops.flash_attention(q, k, v)            # uses the tuned blocks

Explicit keyword arguments always win over tuned defaults, and tuned
defaults win over the static module defaults — mirroring how RealProbe's
DSE feeds resource reallocations back into the next synthesis run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

# kernel id -> {axis name: value}
_TUNED: Dict[str, Dict[str, Any]] = {}

KERNEL_IDS = ("flash_attention", "ssd_scan")


def set_tuned(kernel_id: str, config: Dict[str, Any]) -> None:
    """Install ``config`` as the tuned defaults for ``kernel_id``."""
    _TUNED[kernel_id] = dict(config)


def clear_tuned(kernel_id: Optional[str] = None) -> None:
    if kernel_id is None:
        _TUNED.clear()
    else:
        _TUNED.pop(kernel_id, None)


def tuned(kernel_id: str) -> Dict[str, Any]:
    return dict(_TUNED.get(kernel_id, {}))


def tuned_value(kernel_id: str, axis: str, default):
    """Resolve one axis: explicit caller value (pass it, not this) >
    tuned default > static default."""
    return _TUNED.get(kernel_id, {}).get(axis, default)


def load_cache(kernel_id: Optional[str] = None, *,
               cache_dir: Optional[str] = None,
               verbose: bool = False) -> Dict[str, Dict[str, Any]]:
    """Pull best cached configs into the registry. Returns what loaded
    (kernel id -> config); kernels with no cache entries are left on
    static defaults. ``verbose`` prints what happened (the --autotune
    banner shared by serve.py / train.py)."""
    from repro.core.incremental import EvalCache
    cache = EvalCache(cache_dir)
    loaded = {}
    for kid in ([kernel_id] if kernel_id else KERNEL_IDS):
        best = cache.best_config(kid)
        if best is not None:
            set_tuned(kid, best)
            loaded[kid] = best
    if verbose:
        for kid, cfg in loaded.items():
            print(f"[autotune] {kid}: {cfg}")
        if not loaded:
            print("[autotune] no cached configs — run `python -m "
                  "repro.tune` first; using static defaults")
    return loaded
