"""Pallas TPU kernels for the framework's compute hot spots.

- flash_attention: causal GQA flash attention (+ decoupled probe counters)
- ssd_scan: Mamba-2 SSD chunked scan with VMEM-carried state
Each has a jit wrapper in ops.py and a pure-jnp oracle in ref.py.
"""
from repro.kernels import ops, ref  # noqa: F401
