"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends the kernels execute in ``interpret=True`` mode (the
kernel body runs as traced JAX ops — bit-identical math, CPU-validatable),
which is how the test suite sweeps shapes/dtypes against ``ref.py``.

Tile/pipeline arguments left as ``None`` resolve through the tuned-
defaults registry (``repro.kernels.tuning``), so a ``repro.tune`` run
(or ``serve.py --autotune``) transparently re-tiles the model's kernels.
"""
from __future__ import annotations

import functools
import math

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd
from repro.kernels import tuning


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _fit_block(size: int, want: int) -> int:
    """Largest usable tile <= ``want`` for an axis of length ``size``:
    clamp, then drop to gcd so the tile always divides the axis (tuned
    configs must stay usable at shapes they weren't tuned for)."""
    b = min(want, size)
    return b if size % b == 0 else math.gcd(size, b)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "pipeline", "with_probe",
                                             "interpret"))
def _flash_jit(q, k, v, *, causal, block_q, block_k, pipeline, with_probe,
               interpret):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, pipeline=pipeline,
                               with_probe=with_probe, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int | None = None, block_k: int | None = None,
                    pipeline: int | None = None, with_probe: bool = False,
                    interpret: bool | None = None):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D). See kernels.flash_attention.

    ``block_q``/``block_k``/``pipeline`` default to the tuned registry
    (falling back to 128/128/1). Registry-derived values are fitted to
    shapes they weren't tuned for (gcd tile, pipeline dropped); explicit
    arguments are passed through untouched, so an invalid combination
    still fails loudly in the kernel."""
    if interpret is None:
        interpret = _interpret_default()
    S = q.shape[2]
    if block_q is None:
        block_q = _fit_block(S, tuning.tuned_value(
            "flash_attention", "block_q", _fa.DEFAULT_BLOCK_Q))
    if block_k is None:
        block_k = _fit_block(S, tuning.tuned_value(
            "flash_attention", "block_k", _fa.DEFAULT_BLOCK_K))
    if pipeline is None:
        pipeline = tuning.tuned_value("flash_attention", "pipeline", 1)
        if (S // min(block_k, S)) % pipeline:
            pipeline = 1
    return _flash_jit(q, k, v, causal=causal, block_q=block_q,
                      block_k=block_k, pipeline=pipeline,
                      with_probe=with_probe, interpret=interpret)


def flash_attention_gqa(q, k, v, *, causal: bool = True,
                        interpret: bool | None = None):
    """Model-layout adapter: q (B,S,kv,qpk,hd); k,v (B,S,kv,hd)."""
    B, S, KV, G, HD = q.shape
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B, KV * G, S, HD)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    o = flash_attention(qf, kf, vf, causal=causal, interpret=interpret)
    return o.reshape(B, KV, G, S, HD).transpose(0, 3, 1, 2, 4)


@functools.partial(jax.jit, static_argnames=("chunk", "pipeline", "h_per_g",
                                             "interpret"))
def _ssd_jit(x, a, b, c, *, chunk, pipeline, h_per_g, interpret):
    xk = x.transpose(0, 2, 1, 3)
    ak = a.transpose(0, 2, 1)
    bk = b.transpose(0, 2, 1, 3)
    ck = c.transpose(0, 2, 1, 3)
    y = _ssd.ssd_scan(xk, ak, bk, ck, chunk=chunk, pipeline=pipeline,
                      interpret=interpret)
    return y.transpose(0, 2, 1, 3)


def resolve_ssd_chunk(L: int, default: int = 256) -> int:
    """Tuned-registry resolution for ``ssd_scan``'s chunk, clamped to
    the sequence — the single place the 'explicit > tuned > default'
    policy lives. Callers that pad to a multiple of the result (the
    model layer) use this directly; unpadded calls additionally fit it
    to divide ``L`` (see ``ssd_scan``)."""
    return min(tuning.tuned_value("ssd_scan", "chunk", default), L)


def ssd_scan(x, a, b, c, *, chunk: int | None = None,
             pipeline: int | None = None, h_per_g: int | None = None,
             interpret: bool | None = None):
    """Model-layout adapter: x (B,L,H,P); a (B,L,H); b,c (B,L,G,N).

    ``chunk``/``pipeline`` default to the tuned registry (256/1);
    registry-derived values are fitted to the sequence, explicit
    arguments pass through untouched (invalid ones fail loudly).
    Returns y (B, L, H, P).
    """
    if interpret is None:
        interpret = _interpret_default()
    L = x.shape[1]
    if chunk is None:
        chunk = _fit_block(L, resolve_ssd_chunk(L))
    if pipeline is None:
        pipeline = tuning.tuned_value("ssd_scan", "pipeline", 1)
        if chunk % pipeline:
            pipeline = 1
    return _ssd_jit(x, a, b, c, chunk=chunk, pipeline=pipeline,
                    h_per_g=h_per_g, interpret=interpret)
