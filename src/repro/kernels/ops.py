"""Jit'd public wrappers for the Pallas kernels.

On non-TPU backends the kernels execute in ``interpret=True`` mode (the
kernel body runs as traced JAX ops — bit-identical math, CPU-validatable),
which is how the test suite sweeps shapes/dtypes against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ssd_scan as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "with_probe", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, with_probe: bool = False,
                    interpret: bool | None = None):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D). See kernels.flash_attention."""
    if interpret is None:
        interpret = _interpret_default()
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, with_probe=with_probe,
                               interpret=interpret)


def flash_attention_gqa(q, k, v, *, causal: bool = True,
                        interpret: bool | None = None):
    """Model-layout adapter: q (B,S,kv,qpk,hd); k,v (B,S,kv,hd)."""
    B, S, KV, G, HD = q.shape
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B, KV * G, S, HD)
    kf = k.transpose(0, 2, 1, 3)
    vf = v.transpose(0, 2, 1, 3)
    o = flash_attention(qf, kf, vf, causal=causal, interpret=interpret)
    return o.reshape(B, KV, G, S, HD).transpose(0, 3, 1, 2, 4)


@functools.partial(jax.jit, static_argnames=("chunk", "h_per_g", "interpret"))
def ssd_scan(x, a, b, c, *, chunk: int = 256, h_per_g: int | None = None,
             interpret: bool | None = None):
    """Model-layout adapter: x (B,L,H,P); a (B,L,H); b,c (B,L,G,N).

    Returns y (B, L, H, P).
    """
    if interpret is None:
        interpret = _interpret_default()
    xk = x.transpose(0, 2, 1, 3)
    ak = a.transpose(0, 2, 1)
    bk = b.transpose(0, 2, 1, 3)
    ck = c.transpose(0, 2, 1, 3)
    y = _ssd.ssd_scan(xk, ak, bk, ck, chunk=chunk, interpret=interpret)
    return y.transpose(0, 2, 1, 3)
