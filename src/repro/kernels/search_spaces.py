"""Declarative DSE search spaces for the Pallas kernels.

Each factory builds a ``repro.core.dse.SearchSpace`` over the kernel's
tunable axes — MXU tile sizes and the software-pipelining depth — at a
concrete problem shape (tuning is shape-specific, like the paper's
per-design DSE). The ``bind`` closures call the raw kernels (not the
jitted ``ops`` wrappers) so the traced jaxpr exposes the ``pallas_call``
directly to the cost model and the probe instrumenter.

``chunked_prefill`` is the odd one out: it tunes a *schedule* (the
serving engine's prefill chunk quantum) rather than kernel tiles, so
its bind traces plain XLA steps — the cost model sees zero Pallas
resources and never prunes, and all pricing comes from probed cycles.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import ssd_scan as _ssd
from repro.kernels.ops import _interpret_default as _interpret


def flash_attention_space(*, B: int = 1, H: int = 2, S: int = 256,
                          D: int = 64, Hkv: int | None = None,
                          causal: bool = True,
                          dtype=jnp.float32,
                          blocks_q: Tuple[int, ...] = (64, 128, 256),
                          blocks_k: Tuple[int, ...] = (64, 128, 256),
                          pipelines: Tuple[int, ...] = (1, 2),
                          seed: int = 0):
    """Block/tile x pipeline space for the causal GQA flash kernel."""
    from repro.core.dse import SearchSpace
    Hkv = Hkv or H
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k0, (B, H, S, D)).astype(dtype)
    k = jax.random.normal(k1, (B, Hkv, S, D)).astype(dtype)
    v = jax.random.normal(k2, (B, Hkv, S, D)).astype(dtype)

    def is_valid(cfg):
        bq, bk, pp = cfg["block_q"], cfg["block_k"], cfg["pipeline"]
        return (bq <= S and bk <= S and S % bq == 0 and S % bk == 0
                and (S // bk) % pp == 0)

    def bind(cfg):
        bq, bk, pp = cfg["block_q"], cfg["block_k"], cfg["pipeline"]
        interp = _interpret()

        def fn(q, k, v):
            with jax.named_scope("flash_attention"):
                return _fa.flash_attention(
                    q, k, v, causal=causal, block_q=bq, block_k=bk,
                    pipeline=pp, interpret=interp)
        return fn

    return SearchSpace(
        kernel_id="flash_attention",
        axes={"block_q": blocks_q, "block_k": blocks_k,
              "pipeline": pipelines},
        bind=bind, args=(q, k, v),
        default={"block_q": min(_fa.DEFAULT_BLOCK_Q, S),
                 "block_k": min(_fa.DEFAULT_BLOCK_K, S), "pipeline": 1},
        is_valid=is_valid)


def ssd_scan_space(*, B: int = 1, H: int = 4, G: int = 2, L: int = 256,
                   P: int = 16, N: int = 32,
                   chunks: Tuple[int, ...] = (32, 64, 128, 256),
                   pipelines: Tuple[int, ...] = (1, 2, 4),
                   seed: int = 0):
    """Chunk x sub-chunk-pipeline space for the Mamba-2 SSD scan."""
    from repro.core.dse import SearchSpace
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, H, L, P)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (B, H, L))) * 0.3
    b = jax.random.normal(ks[2], (B, G, L, N)) * 0.5
    c = jax.random.normal(ks[3], (B, G, L, N)) * 0.5

    def is_valid(cfg):
        ch, pp = cfg["chunk"], cfg["pipeline"]
        return (ch <= L and L % ch == 0 and ch % pp == 0
                and ch // pp >= 8)

    def bind(cfg):
        ch, pp = cfg["chunk"], cfg["pipeline"]
        interp = _interpret()

        def fn(x, a, b, c):
            with jax.named_scope("ssd_scan"):
                return _ssd.ssd_scan(x, a, b, c, chunk=ch, pipeline=pp,
                                     interpret=interp)
        return fn

    return SearchSpace(
        kernel_id="ssd_scan",
        axes={"chunk": chunks, "pipeline": pipelines},
        bind=bind, args=(x, a, b, c),
        default={"chunk": min(256, L), "pipeline": 1},
        is_valid=is_valid)


def paged_attention_space(*, B: int = 4, KV: int = 4, G: int = 2,
                          HD: int = 64, page_size: int = 16,
                          n_pages: int = 8, pool_pages: int = 64,
                          kv_dtype=jnp.bfloat16,
                          pages_per_step: Tuple[int, ...] = (1, 2, 4, 8),
                          seed: int = 0):
    """Pipelining-depth space for the paged-attention decode kernel.

    The workload is a randomly permuted page table (the serving
    engine's steady state: pages are scattered by alloc/free churn),
    with per-request positions spread across the cache range.
    """
    from repro.core.dse import SearchSpace
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(k0, (B, KV, G, HD), jnp.float32)
    pool_k = jax.random.normal(
        k1, (pool_pages, page_size, KV, HD)).astype(kv_dtype)
    pool_v = jax.random.normal(
        k2, (pool_pages, page_size, KV, HD)).astype(kv_dtype)
    pages = jax.random.permutation(
        k3, pool_pages)[:B * n_pages].reshape(B, n_pages).astype(jnp.int32)
    s_max = page_size * n_pages
    pos = (jnp.arange(B, dtype=jnp.int32) * (s_max // max(B, 1))
           + page_size - 1) % s_max

    def is_valid(cfg):
        return n_pages % cfg["pages_per_step"] == 0

    def bind(cfg):
        pps = cfg["pages_per_step"]
        interp = _interpret()

        def fn(q, pool_k, pool_v, pages, pos):
            with jax.named_scope("paged_attention"):
                return _pa.paged_attention(q, pool_k, pool_v, pages, pos,
                                           pages_per_step=pps,
                                           interpret=interp)
        return fn

    return SearchSpace(
        kernel_id="paged_attention",
        axes={"pages_per_step": pages_per_step},
        bind=bind, args=(q, pool_k, pool_v, pages, pos),
        default={"pages_per_step": _pa.DEFAULT_PAGES_PER_STEP},
        is_valid=is_valid)


def chunked_prefill_space(*, arch: str = "tinyllama-1.1b",
                          prompt_pages: int = 4, page_size: int = 16,
                          chunks: Tuple[int, ...] | None = None,
                          seed: int = 0):
    """Chunk-size space for the engine's chunked-prefill schedule.

    The tunable axis is ``chunk_pages`` — how many pages of prompt one
    scheduler quantum prefills (the engine's
    ``EngineConfig.prefill_chunk_pages``), sitting next to the decode
    kernel's ``pages_per_step`` axis. Each candidate binds the full
    static chain the engine would run for a ``prompt_pages`` prompt:
    an opening prefill step, then continuation chunks against the pool
    (``build_chunk_prefill``), each followed by its page scatter. Every
    candidate computes bit-identical logits (chunking is a pure
    schedule change), so the DSE engine is pricing pure overhead:
    context re-gather and per-chunk dispatch vs head-of-line latency.
    """
    from repro.configs.registry import smoke_config
    from repro.core.dse import SearchSpace
    from repro.engine.step import (build_chunk_prefill,
                                   build_engine_prefill,
                                   build_page_scatter)
    from repro.models import Model

    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    pp, ps = prompt_pages, page_size
    if chunks is None:   # pow2 quanta plus the whole-prompt baseline
        chunks = tuple(sorted(set(_pow2_range(1, pp)) | {pp}))
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kvd = jnp.dtype(cfg.kv_cache_dtype)
    # identity page table: prompt page i lives at pool slot i+1 (slot 0
    # is the engine's pinned null page)
    pool_shape = (cfg.num_layers, pp + 2, ps, kv, hd)
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                (1, pp * ps), 0, cfg.vocab_size, jnp.int32)

    def is_valid(c):
        return 1 <= c["chunk_pages"] <= pp

    def bind(c):
        K = c["chunk_pages"]
        plan = []                        # (cs, n, step_fn, scatter_fn)
        cs = 0
        while cs < pp:
            n = min(K, pp - cs)
            step = (build_engine_prefill(model, n, ps) if cs == 0
                    else build_chunk_prefill(model, cs, n, ps))
            plan.append((cs, n, step, build_page_scatter(n)))
            cs += n

        def fn(params, pool_k, pool_v, tokens):
            with jax.named_scope("chunked_prefill"):
                logits = None
                for cs, n, step, scatter in plan:
                    batch = {
                        "tokens": tokens[:, cs * ps:(cs + n) * ps],
                        "last_idx": jnp.array([n * ps - 1], jnp.int32),
                    }
                    if cs == 0:
                        logits, k, v = step(params, batch)
                    else:
                        batch["ctx_pages"] = jnp.arange(
                            1, cs + 1, dtype=jnp.int32)
                        logits, k, v = step(params, pool_k, pool_v,
                                            batch)
                    ids = jnp.arange(cs + 1, cs + n + 1,
                                     dtype=jnp.int32)
                    pool_k, pool_v = scatter(pool_k, pool_v, k, v, ids)
                return logits, pool_k, pool_v
        return fn

    return SearchSpace(
        kernel_id="chunked_prefill",
        axes={"chunk_pages": chunks},
        bind=bind,
        args=(params, jnp.zeros(pool_shape, kvd),
              jnp.zeros(pool_shape, kvd), tokens),
        default={"chunk_pages": pp},
        is_valid=is_valid)


SPACES = {
    "flash_attention": flash_attention_space,
    "ssd_scan": ssd_scan_space,
    "paged_attention": paged_attention_space,
    "chunked_prefill": chunked_prefill_space,
}


# ------------------------------------------------- sweep-farm variants

def _pow2_range(lo: int, hi: int) -> Tuple[int, ...]:
    out = []
    v = 1
    while v <= hi:
        if v >= lo:
            out.append(v)
        v *= 2
    return tuple(out)


def sweep_space(kernel_id: str, **shape):
    """Dense sweep-farm variant of a registered space: same ``bind`` /
    validity / default, tile axes widened to every power of two up to
    the problem size. The floor ``max(8, S // 32)`` bounds the grid-step
    product per candidate, which keeps trace capture (the scalar-env
    grid walk enumerates the used ``program_id`` axes) cheap even for
    thousand-candidate sweeps. Rebuilt by name inside sweep workers —
    ``bind`` closures don't pickle across the spawn boundary."""
    if kernel_id == "flash_attention":
        S = int(shape.get("S", 256))
        blocks = _pow2_range(max(8, S // 32), S)
        return flash_attention_space(blocks_q=blocks, blocks_k=blocks,
                                     pipelines=(1, 2, 4, 8), **shape)
    if kernel_id == "ssd_scan":
        L = int(shape.get("L", 256))
        chunks = _pow2_range(max(8, L // 32), L)
        return ssd_scan_space(chunks=chunks, pipelines=(1, 2, 4, 8), **shape)
    if kernel_id == "paged_attention":
        n_pages = int(shape.get("n_pages", 8))
        return paged_attention_space(
            pages_per_step=_pow2_range(1, n_pages), **shape)
    if kernel_id == "chunked_prefill":
        pp = int(shape.get("prompt_pages", 4))
        chunks = tuple(sorted(set(_pow2_range(1, pp)) | {pp}))
        return chunked_prefill_space(chunks=chunks, **shape)
    raise KeyError(f"no sweep space for kernel {kernel_id!r}; "
                   f"known: {tuple(SPACES)}")


def sweep_shapes(kernel_id: str, *, seqs: Tuple[int, ...] = (),
                 heads: Tuple[int, ...] = ()) -> list:
    """Default (sequence x heads) shape grid a sweep iterates — the
    candidate pool is configs x shapes, with calibration transferred
    from the first shape to the rest."""
    if kernel_id == "flash_attention":
        return [{"S": s, "H": h, "D": 32}
                for s in (seqs or (128, 256, 512))
                for h in (heads or (2,))]
    if kernel_id == "ssd_scan":
        return [{"L": s, "H": h}
                for s in (seqs or (128, 256, 512))
                for h in (heads or (2,))]
    if kernel_id == "paged_attention":
        return [{"n_pages": n} for n in (seqs or (8, 16))]
    if kernel_id == "chunked_prefill":
        return [{"prompt_pages": n} for n in (seqs or (2, 4))]
    raise KeyError(f"no sweep shapes for kernel {kernel_id!r}; "
                   f"known: {tuple(SPACES)}")
