"""Paged KV-cache bookkeeping: refcounted page table + prefix tree.

Host-side metadata for the device-resident page pool. The pool itself
is a pair of ``(num_pages, page_size, kv_heads, head_dim)`` arrays held
by the engine; this module only tracks which pages are free, how many
requests reference each page, and which fully-written prompt pages can
be shared between requests with a common prompt prefix.

Page 0 is the **null page**: permanently reserved, never handed out.
Padded rows of a decode bucket point their whole page-table row at it,
so dummy lanes scatter their (identical, deterministic) writes into a
page no real request ever reads.

Sharing is storage-level deduplication: a prefix-tree node maps a
*full page of prompt tokens* (reached through its parent chain, so the
key is position-dependent) to the pool page holding its KV rows. With
causal attention, identical token prefixes produce bit-identical KV
rows regardless of what follows them, so a shared page read by request
A equals what A's own prefill would have written — bit-identity of
outputs is preserved (asserted in tests/test_engine.py).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

NULL_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """Raised by ``alloc`` when the free list cannot cover a request."""


class PageTable:
    """Free list + per-page reference counts over a fixed pool.

    Pages are shared by refcount: a page is returned to the free list
    only when its last reference drops. ``peak_used`` tracks the
    high-water occupancy (a bench-gated metric).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(f"num_pages {num_pages} < 2 (page 0 is "
                             "reserved as the null page)")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.refcount = [0] * num_pages
        self.refcount[NULL_PAGE] = 1          # pinned forever
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.peak_used = 0

    # -- capacity --------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Pages currently referenced (excluding the null page)."""
        return (self.num_pages - 1) - len(self._free)

    # -- alloc / share / free -------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` fresh pages (refcount 1 each) off the free list."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"need {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            assert self.refcount[p] == 0, p
            self.refcount[p] = 1
        self.peak_used = max(self.peak_used, self.used_pages)
        return out

    def share(self, page: int) -> int:
        """Add a reference to an already-live page."""
        if page == NULL_PAGE:
            return page
        if self.refcount[page] <= 0:
            raise ValueError(f"share of dead page {page}")
        self.refcount[page] += 1
        return page

    def free(self, page: int) -> None:
        """Drop one reference; recycle the page when none remain."""
        if page == NULL_PAGE:
            return
        if self.refcount[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)

    def balanced(self) -> bool:
        """True iff every non-null page is unreferenced and free —
        the drain invariant the hypothesis suite asserts."""
        live = [p for p in range(1, self.num_pages) if self.refcount[p]]
        return not live and len(self._free) == self.num_pages - 1


@dataclass
class _Node:
    page: int
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)
    parent: Optional["_Node"] = None
    key: Optional[Tuple[int, ...]] = None
    stamp: int = 0                        # last-matched LRU clock value


class PrefixTree:
    """Trie over full prompt pages for cross-request KV reuse.

    Each edge is labelled with one page's worth of tokens; each node
    (except the root) owns a reference on the pool page holding that
    edge's KV rows. ``match`` walks the longest shared prefix and takes
    a reference per matched page for the caller; ``insert`` registers a
    request's freshly-prefilled full pages for future requests.

    Under pool pressure the tree is an LRU victim set: every ``match``
    / ``insert`` stamps the touched path with a monotonic clock, and
    ``evict`` frees leaf pages held *only* by the tree (refcount 1) in
    least-recently-matched order — hot shared prefixes survive, pages a
    live request still reads are never victims. ``evict_all`` (engine
    drain) drops every tree-held reference in the same deterministic
    leaf-first LRU order.
    """

    def __init__(self, table: PageTable):
        self.table = table
        self.root = _Node(NULL_PAGE)
        self.hits = 0
        self.misses = 0
        self.nodes = 0
        self.evicted = 0                  # cumulative pages freed to pool
        self._clock = 0

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.stamp = self._clock

    def lookup(self, page_tokens: List[Tuple[int, ...]]) -> int:
        """Length of the longest shared prefix, in pages — no references
        taken, no hit/miss accounting (admission capacity checks)."""
        node = self.root
        n = 0
        for toks in page_tokens:
            child = node.children.get(toks)
            if child is None:
                break
            n += 1
            node = child
        return n

    def match(self, page_tokens: List[Tuple[int, ...]]
              ) -> List[int]:
        """Longest-prefix match; returns shared pages (ref'd for the
        caller) covering ``page_tokens[:len(result)]``."""
        node = self.root
        out: List[int] = []
        for toks in page_tokens:
            child = node.children.get(toks)
            if child is None:
                break
            out.append(self.table.share(child.page))
            self._touch(child)
            node = child
        self.hits += len(out)
        self.misses += len(page_tokens) - len(out)
        return out

    def insert(self, page_tokens: List[Tuple[int, ...]],
               pages: List[int]) -> int:
        """Register full prompt pages along one root path; the tree
        takes its own reference on each newly registered page. Returns
        the number of new nodes."""
        assert len(page_tokens) == len(pages)
        node = self.root
        added = 0
        for toks, page in zip(page_tokens, pages):
            child = node.children.get(toks)
            if child is None:
                child = _Node(self.table.share(page), parent=node, key=toks)
                node.children[toks] = child
                added += 1
            self._touch(child)
            node = child
        self.nodes += added
        return added

    # -- eviction --------------------------------------------------------
    def _leaf_heap(self) -> List[Tuple[int, int, _Node]]:
        """Min-heap of current leaves keyed (LRU stamp, insertion id)."""
        leaves = []
        stack = [self.root]
        while stack:
            nd = stack.pop()
            for ch in nd.children.values():
                if ch.children:
                    stack.append(ch)
                else:
                    leaves.append((ch.stamp, id(ch), ch))
        heapq.heapify(leaves)
        return leaves

    def _unlink(self, node: _Node) -> Optional[_Node]:
        """Detach a leaf from its parent; returns the parent if it just
        became an evictable (non-root) leaf itself."""
        assert not node.children
        parent = node.parent
        del parent.children[node.key]
        self.nodes -= 1
        if parent is not self.root and not parent.children:
            return parent
        return None

    def evict(self, n_pages: int,
              protect: Optional[List[Tuple[int, ...]]] = None) -> List[int]:
        """Free up to ``n_pages`` pool pages under pressure, in
        least-recently-matched leaf-first order.

        Only pages whose *sole* reference is the tree's (refcount 1) are
        victims — a page a live request shares is never evicted. Nodes on
        the ``protect`` path (the head request's own prefix) are spared
        so admission never cannibalizes the prefix it is about to match.
        Returns the freed page ids in eviction order."""
        protected = set()
        if protect:
            node = self.root
            for toks in protect:
                node = node.children.get(toks)
                if node is None:
                    break
                protected.add(id(node))
        heap = self._leaf_heap()
        freed: List[int] = []
        while heap and len(freed) < n_pages:
            _, _, node = heapq.heappop(heap)
            if id(node) in protected or self.table.refcount[node.page] != 1:
                continue                  # shared with a live request
            parent = self._unlink(node)
            self.table.free(node.page)
            freed.append(node.page)
            if parent is not None:
                heapq.heappush(heap, (parent.stamp, id(parent), parent))
        self.evicted += len(freed)
        return freed

    def evict_all(self) -> List[int]:
        """Drop every tree-held reference (engine drain), leaf-first in
        LRU order; returns the pages actually freed to the pool (pages a
        live request still references merely lose the tree's ref)."""
        heap = self._leaf_heap()
        freed: List[int] = []
        while heap:
            _, _, node = heapq.heappop(heap)
            parent = self._unlink(node)
            last = self.table.refcount[node.page] == 1
            self.table.free(node.page)
            if last:
                freed.append(node.page)
            if parent is not None:
                heapq.heappush(heap, (parent.stamp, id(parent), parent))
        return freed

    def clear(self) -> List[int]:
        """Release every tree-held page reference (legacy all-or-nothing
        eviction policy); returns the pages freed to the pool."""
        return self.evict_all()
