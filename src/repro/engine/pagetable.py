"""Paged KV-cache bookkeeping: refcounted page table + prefix tree.

Host-side metadata for the device-resident page pool. The pool itself
is a pair of ``(num_pages, page_size, kv_heads, head_dim)`` arrays held
by the engine; this module only tracks which pages are free, how many
requests reference each page, and which fully-written prompt pages can
be shared between requests with a common prompt prefix.

Page 0 is the **null page**: permanently reserved, never handed out.
Padded rows of a decode bucket point their whole page-table row at it,
so dummy lanes scatter their (identical, deterministic) writes into a
page no real request ever reads.

Sharing is storage-level deduplication: a prefix-tree node maps a
*full page of prompt tokens* (reached through its parent chain, so the
key is position-dependent) to the pool page holding its KV rows. With
causal attention, identical token prefixes produce bit-identical KV
rows regardless of what follows them, so a shared page read by request
A equals what A's own prefill would have written — bit-identity of
outputs is preserved (asserted in tests/test_engine.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

NULL_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """Raised by ``alloc`` when the free list cannot cover a request."""


class PageTable:
    """Free list + per-page reference counts over a fixed pool.

    Pages are shared by refcount: a page is returned to the free list
    only when its last reference drops. ``peak_used`` tracks the
    high-water occupancy (a bench-gated metric).
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(f"num_pages {num_pages} < 2 (page 0 is "
                             "reserved as the null page)")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.refcount = [0] * num_pages
        self.refcount[NULL_PAGE] = 1          # pinned forever
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.peak_used = 0

    # -- capacity --------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """Pages currently referenced (excluding the null page)."""
        return (self.num_pages - 1) - len(self._free)

    # -- alloc / share / free -------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` fresh pages (refcount 1 each) off the free list."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"need {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            assert self.refcount[p] == 0, p
            self.refcount[p] = 1
        self.peak_used = max(self.peak_used, self.used_pages)
        return out

    def share(self, page: int) -> int:
        """Add a reference to an already-live page."""
        if page == NULL_PAGE:
            return page
        if self.refcount[page] <= 0:
            raise ValueError(f"share of dead page {page}")
        self.refcount[page] += 1
        return page

    def free(self, page: int) -> None:
        """Drop one reference; recycle the page when none remain."""
        if page == NULL_PAGE:
            return
        if self.refcount[page] <= 0:
            raise ValueError(f"double free of page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)

    def balanced(self) -> bool:
        """True iff every non-null page is unreferenced and free —
        the drain invariant the hypothesis suite asserts."""
        live = [p for p in range(1, self.num_pages) if self.refcount[p]]
        return not live and len(self._free) == self.num_pages - 1


@dataclass
class _Node:
    page: int
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)


class PrefixTree:
    """Trie over full prompt pages for cross-request KV reuse.

    Each edge is labelled with one page's worth of tokens; each node
    (except the root) owns a reference on the pool page holding that
    edge's KV rows. ``match`` walks the longest shared prefix and takes
    a reference per matched page for the caller; ``insert`` registers a
    request's freshly-prefilled full pages for future requests.
    ``clear`` drops every tree-held reference (used at engine drain, so
    page refcounts balance to zero).
    """

    def __init__(self, table: PageTable):
        self.table = table
        self.root = _Node(NULL_PAGE)
        self.hits = 0
        self.misses = 0
        self.nodes = 0

    def lookup(self, page_tokens: List[Tuple[int, ...]]) -> int:
        """Length of the longest shared prefix, in pages — no references
        taken, no hit/miss accounting (admission capacity checks)."""
        node = self.root
        n = 0
        for toks in page_tokens:
            child = node.children.get(toks)
            if child is None:
                break
            n += 1
            node = child
        return n

    def match(self, page_tokens: List[Tuple[int, ...]]
              ) -> List[int]:
        """Longest-prefix match; returns shared pages (ref'd for the
        caller) covering ``page_tokens[:len(result)]``."""
        node = self.root
        out: List[int] = []
        for toks in page_tokens:
            child = node.children.get(toks)
            if child is None:
                break
            out.append(self.table.share(child.page))
            node = child
        self.hits += len(out)
        self.misses += len(page_tokens) - len(out)
        return out

    def insert(self, page_tokens: List[Tuple[int, ...]],
               pages: List[int]) -> int:
        """Register full prompt pages along one root path; the tree
        takes its own reference on each newly registered page. Returns
        the number of new nodes."""
        assert len(page_tokens) == len(pages)
        node = self.root
        added = 0
        for toks, page in zip(page_tokens, pages):
            child = node.children.get(toks)
            if child is None:
                child = _Node(self.table.share(page))
                node.children[toks] = child
                added += 1
            node = child
        self.nodes += added
        return added

    def clear(self) -> None:
        """Release every tree-held page reference."""
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            self.table.free(n.page)
            stack.extend(n.children.values())
        self.root = _Node(NULL_PAGE)
        self.nodes = 0
