"""Probe-attributed continuous-batching serving engine.

Serving counterpart of the one-shot probe machinery: a paged-KV
continuous-batching scheduler whose prefill / cache-management / decode
phases each run under the streaming cycle probes, bucketed so that no
request mix ever triggers a retrace. See docs/serving.md.
"""
from repro.engine.engine import (EngineConfig, InferenceEngine, PHASES,
                                 Request)
from repro.engine.pagetable import (NULL_PAGE, PagePoolExhausted, PageTable,
                                    PrefixTree)
from repro.engine.step import (build_chunk_prefill, build_engine_prefill,
                               build_page_scatter, build_paged_decode,
                               donation_argnums, engine_compatible)

__all__ = [
    "EngineConfig", "InferenceEngine", "PHASES", "Request",
    "NULL_PAGE", "PagePoolExhausted", "PageTable", "PrefixTree",
    "build_chunk_prefill", "build_engine_prefill", "build_page_scatter",
    "build_paged_decode", "donation_argnums", "engine_compatible",
]
