"""Engine soak: waves of random requests with flat-memory assertions.

The nightly CI runs this as ``python -m repro.engine.soak``: a long
random request trace (mixed prompt lengths, decode budgets, and shared
prefixes, in randomized arrival order) served wave after wave through
one :class:`~repro.engine.InferenceEngine`. After every wave the driver
asserts the steady-state invariants a long-lived server depends on:

- zero retraces — every step shape was traced during wave 1 and the
  compile caches never grow again;
- page accounting balances — after ``drain()`` the table returns to
  all-free (no leaked or double-freed pages);
- flat host memory — Python-side traced allocations after the last
  wave stay within a fixed slack of the first wave's high-water mark
  (finished requests are ``reap()``-ed per wave, aggregates are
  constant-size);
- flat device buffers — ``jax.live_arrays()`` after the last wave
  matches the first wave's count within a fixed slack (donated pools
  and per-step outputs are rebound, never accumulated).

``--pressure`` shrinks the page pool to ~60% of the trace's working
set so every wave must reclaim prefix-tree pages: the run additionally
asserts nonzero evictions, a prefix hit-rate floor (LRU keeps the hot
prefixes resident), and that ``PagePoolExhausted`` never fires — the
evictor alone absorbs the pressure. ``--chunk N`` serves the same
trace through the chunked-prefill scheduler (one more pinned trace per
chunk-shape bucket, still zero retraces after wave 1).
"""
from __future__ import annotations

import argparse
import tracemalloc
from typing import List

import numpy as np


def _wave(rng: np.random.Generator, eng, n_requests: int,
          vocab: int, prefixes: List[List[int]]) -> List[int]:
    ps = eng.config.page_size
    cap = eng.config.max_pages * ps
    rids = []
    for _ in range(n_requests):
        prompt: List[int] = []
        if rng.random() < 0.5:
            prompt += prefixes[int(rng.integers(len(prefixes)))]
        prompt += rng.integers(0, vocab,
                               int(rng.integers(1, 2 * ps))).tolist()
        max_new = int(rng.integers(1, ps))
        if len(prompt) + max_new - 1 > cap:
            prompt = prompt[:cap - max_new + 1 - ps]
        rids.append(eng.submit(prompt, max_new))
    return rids


def soak(*, arch: str = "tinyllama-1.1b", waves: int = 3,
         requests_per_wave: int = 8, seed: int = 0,
         use_kernel: bool = False, probe: bool = False,
         pressure: bool = False, chunk: int = 0,
         min_hit_rate: float = 0.15,
         mem_slack_bytes: int = 512 * 1024,
         buffer_slack: int = 16, verbose: bool = True) -> dict:
    import jax
    from repro.configs.registry import smoke_config
    from repro.engine import EngineConfig, InferenceEngine
    from repro.models.model import Model

    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # a wave's working set is ~4 pages per request (prefix + tail +
    # decode budget); under --pressure the pool holds ~60% of that, so
    # steady state is only reachable by evicting finished prefix pages
    pool = (max(12, int(0.6 * requests_per_wave * 4)) if pressure
            else 48)
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, pool_pages=pool, max_pages=8, buckets=(1, 2, 4),
        use_kernel=use_kernel, pages_per_step=2, probe=probe,
        prefill_chunk_pages=chunk, interpret=True))
    rng = np.random.default_rng(seed)
    # one full page each, so later waves hit the prefix cache
    prefixes = [rng.integers(0, cfg.vocab_size, 16).tolist()
                for _ in range(3)]

    eng.warmup()                     # compile caches filled before wave 0
    tracemalloc.start()
    marks, bufs, served = [], [], 0
    for w in range(waves):
        rids = _wave(rng, eng, requests_per_wave, cfg.vocab_size, prefixes)
        eng.run()
        done = eng.reap()
        assert sorted(r.rid for r in done) == sorted(rids), \
            f"wave {w}: starved requests"
        assert all(len(r.out_tokens) == r.max_new for r in done)
        served += len(done)
        st = eng.stats()
        assert st["retraces"] == 0, f"wave {w}: retraced: {st}"
        mem = tracemalloc.get_traced_memory()[0]
        marks.append(mem)
        bufs.append(len(jax.live_arrays()))
        if verbose:
            print(f"wave {w}: {len(done)} served, "
                  f"pages_peak={st['pages_peak']}, "
                  f"hit_rate={st['prefix_hit_rate']:.2f}, "
                  f"evictions={st['evictions']}, "
                  f"host_mem={mem / 1024:.0f}KiB, "
                  f"buffers={bufs[-1]}", flush=True)
    tracemalloc.stop()
    eng.drain()
    assert eng.table.balanced(), "page accounting out of balance at drain"
    assert marks[-1] <= marks[0] + mem_slack_bytes, \
        f"host memory grew {marks[-1] - marks[0]}B over " \
        f"{waves} waves (> {mem_slack_bytes}B slack)"
    assert bufs[-1] <= bufs[0] + buffer_slack, \
        f"device buffers grew {bufs[0]} -> {bufs[-1]} over {waves} waves"
    st = eng.stats()
    if pressure:
        assert st["evictions"] > 0, \
            "pressure pool never forced an eviction (pool too large?)"
        assert st["prefix_hit_rate"] >= min_hit_rate, \
            f"prefix hit rate {st['prefix_hit_rate']:.2f} fell below " \
            f"{min_hit_rate} under pressure (evictor dropping hot pages?)"
    eng.close()
    out = {"served": served, "mem_first": marks[0], "mem_last": marks[-1],
           "buffers_first": bufs[0], "buffers_last": bufs[-1], **st}
    if verbose:
        print(f"soak OK: {served} requests over {waves} waves, "
              f"mem {marks[0]} -> {marks[-1]} bytes")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--requests-per-wave", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel", action="store_true",
                    help="decode through the paged_attention Pallas kernel")
    ap.add_argument("--probe", action="store_true",
                    help="run every phase under a ProbeSession")
    ap.add_argument("--pressure", action="store_true",
                    help="shrink the page pool to ~60%% of the working "
                         "set; asserts evictions happen and the prefix "
                         "hit rate holds its floor")
    ap.add_argument("--chunk", type=int, default=0,
                    help="prefill chunk quantum in pages (0 = whole)")
    ap.add_argument("--min-hit-rate", type=float, default=0.15,
                    help="prefix hit-rate floor under --pressure")
    args = ap.parse_args()
    soak(arch=args.arch, waves=args.waves,
         requests_per_wave=args.requests_per_wave, seed=args.seed,
         use_kernel=args.kernel, probe=args.probe,
         pressure=args.pressure, chunk=args.chunk,
         min_hit_rate=args.min_hit_rate)


if __name__ == "__main__":
    main()
