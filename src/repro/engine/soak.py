"""Engine soak: waves of random requests with flat-memory assertions.

The nightly CI runs this as ``python -m repro.engine.soak``: a long
random request trace (mixed prompt lengths, decode budgets, and shared
prefixes, in randomized arrival order) served wave after wave through
one :class:`~repro.engine.InferenceEngine`. After every wave the driver
asserts the steady-state invariants a long-lived server depends on:

- zero retraces — every step shape was traced during wave 1 and the
  compile caches never grow again;
- page accounting balances — after ``drain()`` the table returns to
  all-free (no leaked or double-freed pages);
- flat host memory — Python-side traced allocations after the last
  wave stay within a fixed slack of the first wave's high-water mark
  (finished requests are ``reap()``-ed per wave, aggregates are
  constant-size).
"""
from __future__ import annotations

import argparse
import tracemalloc
from typing import List

import numpy as np


def _wave(rng: np.random.Generator, eng, n_requests: int,
          vocab: int, prefixes: List[List[int]]) -> List[int]:
    ps = eng.config.page_size
    cap = eng.config.max_pages * ps
    rids = []
    for _ in range(n_requests):
        prompt: List[int] = []
        if rng.random() < 0.5:
            prompt += prefixes[int(rng.integers(len(prefixes)))]
        prompt += rng.integers(0, vocab,
                               int(rng.integers(1, 2 * ps))).tolist()
        max_new = int(rng.integers(1, ps))
        if len(prompt) + max_new - 1 > cap:
            prompt = prompt[:cap - max_new + 1 - ps]
        rids.append(eng.submit(prompt, max_new))
    return rids


def soak(*, arch: str = "tinyllama-1.1b", waves: int = 3,
         requests_per_wave: int = 8, seed: int = 0,
         use_kernel: bool = False, probe: bool = False,
         mem_slack_bytes: int = 512 * 1024, verbose: bool = True) -> dict:
    import jax
    from repro.configs.registry import smoke_config
    from repro.engine import EngineConfig, InferenceEngine
    from repro.models.model import Model

    cfg = smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    eng = InferenceEngine(model, params, EngineConfig(
        page_size=16, pool_pages=48, max_pages=8, buckets=(1, 2, 4),
        use_kernel=use_kernel, pages_per_step=2, probe=probe,
        interpret=True))
    rng = np.random.default_rng(seed)
    # one full page each, so later waves hit the prefix cache
    prefixes = [rng.integers(0, cfg.vocab_size, 16).tolist()
                for _ in range(3)]

    eng.warmup()                     # compile caches filled before wave 0
    tracemalloc.start()
    marks, served = [], 0
    for w in range(waves):
        rids = _wave(rng, eng, requests_per_wave, cfg.vocab_size, prefixes)
        eng.run()
        done = eng.reap()
        assert sorted(r.rid for r in done) == sorted(rids), \
            f"wave {w}: starved requests"
        assert all(len(r.out_tokens) == r.max_new for r in done)
        served += len(done)
        st = eng.stats()
        assert st["retraces"] == 0, f"wave {w}: retraced: {st}"
        mem = tracemalloc.get_traced_memory()[0]
        marks.append(mem)
        if verbose:
            print(f"wave {w}: {len(done)} served, "
                  f"pages_peak={st['pages_peak']}, "
                  f"hit_rate={st['prefix_hit_rate']:.2f}, "
                  f"host_mem={mem / 1024:.0f}KiB", flush=True)
    tracemalloc.stop()
    eng.drain()
    assert eng.table.balanced(), "page accounting out of balance at drain"
    assert marks[-1] <= marks[0] + mem_slack_bytes, \
        f"host memory grew {marks[-1] - marks[0]}B over " \
        f"{waves} waves (> {mem_slack_bytes}B slack)"
    eng.close()
    out = {"served": served, "mem_first": marks[0], "mem_last": marks[-1],
           **eng.stats()}
    if verbose:
        print(f"soak OK: {served} requests over {waves} waves, "
              f"mem {marks[0]} -> {marks[-1]} bytes")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--waves", type=int, default=3)
    ap.add_argument("--requests-per-wave", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kernel", action="store_true",
                    help="decode through the paged_attention Pallas kernel")
    ap.add_argument("--probe", action="store_true",
                    help="run every phase under a ProbeSession")
    args = ap.parse_args()
    soak(arch=args.arch, waves=args.waves,
         requests_per_wave=args.requests_per_wave, seed=args.seed,
         use_kernel=args.kernel, probe=args.probe)


if __name__ == "__main__":
    main()
