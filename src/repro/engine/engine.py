"""Probe-attributed continuous-batching inference engine.

The serving analogue of the paper's always-on in-fabric profiler: a
request scheduler whose every phase — prefill, KV-cache management,
batched decode — runs under the same cycle-probe machinery as the rest
of the repo, so each request leaves with a per-phase cycle bill.

Scheduling model (all host-side; device work is the pre-traced steps
from :mod:`repro.engine.step`):

- **FCFS admission.** Requests wait in arrival order; the head of the
  queue is admitted as soon as its pages fit and a decode slot is open.
  Later requests never jump the head, so no request starves.
- **All pages up front.** Admission allocates every page the request
  will ever touch (prompt + ``max_new`` growth), so decode can never
  fail mid-request. Full prompt pages found in the prefix tree are
  shared by refcount instead of allocated.
- **Bucketed batching, zero retraces.** Decode runs at the smallest
  configured batch bucket covering the runnable set; padded lanes point
  at the null page. Each (phase, shape) step is traced exactly once —
  ``retraces()`` counts compile-cache growth beyond that and the test
  suite asserts it stays 0.
- **Per-phase attribution.** With ``probe=True`` each step family runs
  inside a :class:`~repro.core.streaming.ProbeSession`; the engine takes
  device model-clock deltas around every call. Prefill and cache cycles
  are exclusive to one request; a decode delta is shared by its batch
  (each rider logs the bucket width in ``decode_batches``).
- **Chunked prefill.** With ``prefill_chunk_pages=K`` a prompt wider
  than ``K`` pages prefills one page-aligned chunk per scheduler round,
  interleaved with decode rounds, so a long prompt never head-of-line
  blocks the running decode batch (``hol_blocked_steps`` counts the
  decode rounds a whole-prompt prefill *would* have displaced beyond
  one chunk quantum). Chunk continuations replay the whole-prompt flash
  row plan against pool-gathered context, so outputs stay bit-identical
  — see :func:`repro.engine.step.build_chunk_prefill`. Chunk traces are
  pinned per (ctx pages, chunk pages) pair at warmup.
- **Prefix-aware eviction.** Under pool pressure admission reclaims
  prefix-cache pages through :meth:`PrefixTree.evict` — leaf-first,
  least-recently-matched first, never a page a live request still
  references — so hot shared prefixes survive and
  :class:`PagePoolExhausted` is reachable only when live requests alone
  exceed the pool. ``evict_policy="clear"`` keeps the legacy
  all-or-nothing behavior for A/B benchmarking.
- **Donated pool buffers.** Off probe mode, steps that return an
  updated pool (cache scatter, decode) are jitted with
  ``donate_argnums`` so the paged KV pool updates in place instead of
  allocating a fresh copy per step. The engine immediately rebinds
  ``pool_k``/``pool_v`` to each step's outputs; the donated inputs are
  dead the moment the step is called and must never be re-read.

Outputs are bit-identical to the unbatched reference serving path
(asserted in tests/test_engine.py) — batching, paging, padding, and
prefix sharing are all exact-arithmetic-preserving transformations.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.pagetable import (NULL_PAGE, PagePoolExhausted, PageTable,
                                    PrefixTree)
from repro.engine.step import (build_chunk_prefill, build_engine_prefill,
                               build_page_scatter, build_paged_decode,
                               donation_argnums, engine_compatible)

PHASES = ("prefill", "cache", "decode")


@dataclass
class Request:
    """One serving request and its lifetime accounting."""
    rid: int
    prompt: List[int]
    max_new: int
    out_tokens: List[int] = field(default_factory=list)
    phase_cycles: Dict[str, int] = field(
        default_factory=lambda: {p: 0 for p in PHASES})
    decode_batches: List[int] = field(default_factory=list)
    shared_pages: int = 0
    # scheduler-internal
    pages: List[int] = field(default_factory=list)
    pos: int = -1                     # last cache position written
    last_tok: int = -1
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


@dataclass
class _PrefillJob:
    """An admitted request mid chunked-prefill: pages are allocated,
    ``next_page`` is the first prompt page the next chunk will write."""
    req: Request
    page_tokens: List[Tuple[int, ...]]
    pp: int                           # total prompt pages
    next_page: int


@dataclass(frozen=True)
class EngineConfig:
    """Engine shape/bucket/probe knobs (all trace-shape determining)."""
    page_size: int = 16
    pool_pages: int = 64              # device pool size incl. null page
    max_pages: int = 8                # page-table width per request
    buckets: Tuple[int, ...] = (1, 2, 4)
    use_kernel: bool = False          # paged_attention Pallas kernel
    pages_per_step: int = 1           # kernel pipelining depth (DSE axis)
    probe: bool = False
    probe_targets: Tuple[str, ...] = ("",)
    probe_max_probes: int = 16
    prefix_cache: bool = True
    interpret: Optional[bool] = None
    prefill_chunk_pages: int = 0      # 0 = whole-prompt prefill (DSE axis)
    evict_policy: str = "lru"         # "lru" | "clear" (legacy)
    donate: Optional[bool] = None     # None = auto (off probe / off CPU)


class InferenceEngine:
    """Continuous-batching engine over one model + parameter set.

    Usage::

        eng = InferenceEngine(model, params, EngineConfig(probe=True))
        eng.submit([1, 2, 3], max_new=8)
        done = eng.run()          # list of finished Requests, rid order
        print(eng.phase_table()); print(eng.request_table(done))
        eng.drain()               # release prefix-cache pages
    """

    def __init__(self, model, params, config: EngineConfig = EngineConfig(),
                 *, bus=None):
        cfg = model.cfg
        # optional telemetry bus: phase/request bills (and, with
        # probe=True, each step family's duration stream) publish to it
        # decode-side, making the engine observable over the status
        # server (docs/telemetry.md). None = exactly the old behavior.
        self.bus = bus
        if not engine_compatible(cfg):
            raise ValueError(
                f"engine requires an attention-family token model; got "
                f"family={cfg.family!r} frontend={cfg.frontend!r}")
        if tuple(sorted(config.buckets)) != tuple(config.buckets) \
                or not config.buckets:
            raise ValueError(f"buckets must be sorted non-empty, "
                             f"got {config.buckets}")
        if config.max_pages > config.pool_pages - 1:
            raise ValueError(f"max_pages {config.max_pages} exceeds pool "
                             f"capacity {config.pool_pages - 1}")
        if config.use_kernel and config.max_pages % config.pages_per_step:
            raise ValueError(f"max_pages {config.max_pages} not divisible "
                             f"by pages_per_step {config.pages_per_step}")
        if config.prefill_chunk_pages < 0:
            raise ValueError(f"prefill_chunk_pages must be >= 0, "
                             f"got {config.prefill_chunk_pages}")
        if config.prefill_chunk_pages and cfg.moe is not None \
                and cfg.moe.impl != "ragged":
            raise ValueError(
                "chunked prefill requires dropless (ragged) MoE routing; "
                f"impl={cfg.moe.impl!r} drops tokens by total count, which "
                "breaks chunk/whole-prompt bit-identity")
        if config.evict_policy not in ("lru", "clear"):
            raise ValueError(f"evict_policy must be 'lru' or 'clear', "
                             f"got {config.evict_policy!r}")
        if config.donate and config.probe:
            raise ValueError(
                "donate=True is incompatible with probe=True: probed steps "
                "run through ProbeSession's stateful wrapper, which shifts "
                "positional args and would donate probe state instead of "
                "the pool")
        self.model, self.params, self.config = model, params, config
        self._donate = (config.donate if config.donate is not None
                        else (not config.probe
                              and jax.default_backend() != "cpu"))
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        shape = (cfg.num_layers, config.pool_pages, config.page_size, kv, hd)
        kvd = jnp.dtype(cfg.kv_cache_dtype)
        self.pool_k = jnp.zeros(shape, kvd)
        self.pool_v = jnp.zeros(shape, kvd)
        self.table = PageTable(config.pool_pages, config.page_size)
        self.tree: Optional[PrefixTree] = \
            PrefixTree(self.table) if config.prefix_cache else None
        self._steps: Dict[Tuple[str, Any], Any] = {}
        self._waiting: deque = deque()
        self._active: List[Request] = []
        self._prefilling: deque = deque()     # _PrefillJob, FCFS
        self._finished: List[Request] = []
        self._next_rid = 0
        self.phase_stats: Dict[str, Dict[str, int]] = {
            p: {"steps": 0, "cycles": 0} for p in PHASES}
        self.bucket_hist: Dict[int, int] = {}
        self.chunk_stats: Dict[Tuple[int, int], Dict[str, int]] = {}
        self.evictions = 0                    # pages reclaimed from tree
        self.hol_blocked_steps = 0            # decode rounds displaced
        self.tokens_out = 0

    # -- step registry ---------------------------------------------------
    def _build(self, phase: str, size):
        c = self.config
        if phase == "prefill":
            fn = build_engine_prefill(self.model, size, c.page_size)
        elif phase == "cache":
            fn = build_page_scatter(size)
        elif phase == "chunkpf":
            fn = build_chunk_prefill(self.model, size[0], size[1],
                                     c.page_size)
        else:
            fn = build_paged_decode(
                self.model, size, c.max_pages, c.page_size,
                use_kernel=c.use_kernel, pages_per_step=c.pages_per_step,
                interpret=c.interpret)
        if c.probe:
            from repro.core import ProbeConfig, ProbeSession
            tag = size if isinstance(size, int) \
                else "x".join(str(s) for s in size)
            return ProbeSession(fn, ProbeConfig(
                targets=c.probe_targets, offload=1.0,
                max_probes=c.probe_max_probes),
                bus=self.bus, source=f"engine/{phase}x{tag}")
        dn = donation_argnums(phase) if self._donate else ()
        return jax.jit(fn, donate_argnums=dn)

    def _entry(self, phase: str, size):
        entry = self._steps.get((phase, size))
        if entry is None:
            entry = self._steps[(phase, size)] = self._build(phase, size)
        return entry

    def _invoke(self, entry, *args):
        return entry.step(*args) if self.config.probe else entry(*args)

    def _chunk_shapes(self) -> List[Tuple[int, int]]:
        """Every (ctx_pages, chunk_pages) continuation shape the chunked
        scheduler can reach: chunk starts are multiples of K, the final
        chunk covers the remainder (never padded past the prompt's own
        page-aligned length, so it replays the whole-prompt row plan)."""
        K = self.config.prefill_chunk_pages
        shapes = set()
        if K:
            for pp in range(K + 1, self.config.max_pages + 1):
                for cs in range(K, pp, K):
                    shapes.add((cs, min(K, pp - cs)))
        return sorted(shapes)

    def warmup(self):
        """Trace + compile every (phase, shape) step ahead of serving.

        Without donation the outputs are discarded (the pool is never
        assigned), so warmup leaves serving state untouched. With
        donation the pool buffers passed in are consumed, so the pool is
        rebound to each step's outputs; the null page picks up warmup
        writes, which no real request ever reads unmasked. Either way
        warmup only fills the compile caches, keeping wave-over-wave
        host memory flat (soak test)."""
        c, ps = self.config, self.config.page_size
        for pp in range(1, c.max_pages + 1):
            _, k, v = self._invoke(
                self._entry("prefill", pp), self.params,
                {"tokens": jnp.zeros((1, pp * ps), jnp.int32),
                 "last_idx": jnp.zeros((1,), jnp.int32)})
            out = self._invoke(self._entry("cache", pp), self.pool_k,
                               self.pool_v, k, v,
                               jnp.zeros((pp,), jnp.int32))
            if self._donate:
                self.pool_k, self.pool_v = out
        for (cs, n) in self._chunk_shapes():
            self._invoke(
                self._entry("chunkpf", (cs, n)), self.params, self.pool_k,
                self.pool_v,
                {"tokens": jnp.zeros((1, n * ps), jnp.int32),
                 "ctx_pages": jnp.zeros((cs,), jnp.int32),
                 "last_idx": jnp.zeros((1,), jnp.int32)})
        for b in c.buckets:
            out = self._invoke(
                self._entry("decode", b), self.params, self.pool_k,
                self.pool_v,
                {"tokens": jnp.zeros((b, 1), jnp.int32),
                 "pos": jnp.zeros((b,), jnp.int32),
                 "pages": jnp.zeros((b, c.max_pages), jnp.int32)})
            if self._donate:
                self.pool_k, self.pool_v = out[1], out[2]

    def _step(self, phase: str, size, *args):
        """Run one step, return (outputs, model-clock cycle delta)."""
        entry = self._entry(phase, size)
        if self.config.probe:
            c0 = entry.clock()
            out = entry.step(*args)
            delta = entry.clock() - c0
        else:
            out = entry(*args)
            delta = 0
        st = self.phase_stats.setdefault(phase, {"steps": 0, "cycles": 0})
        st["steps"] += 1
        st["cycles"] += delta
        if self.bus is not None:
            self.bus.publish_phase(phase, cycles=delta,
                                   batch=size if phase == "decode"
                                   else None)
        return out, delta

    def retraces(self) -> int:
        """Compile-cache entries beyond the one trace each step owns."""
        total = 0
        for (_, _), entry in self._steps.items():
            jf = entry.pf._jitted_stateful if self.config.probe else entry
            if jf is not None and hasattr(jf, "_cache_size"):
                total += max(0, jf._cache_size() - 1)
        return total

    # -- request lifecycle ----------------------------------------------
    def _pages_needed(self, prompt_len: int, max_new: int) -> int:
        # positions 0..prompt_len-1 (prefill) plus max_new-1 decode writes
        return max(1, math.ceil((prompt_len + max_new - 1)
                                / self.config.page_size))

    def submit(self, prompt: Sequence[int], max_new: int = 8) -> int:
        prompt = [int(t) for t in prompt]
        if not prompt or max_new < 1:
            raise ValueError("need a non-empty prompt and max_new >= 1")
        if self._pages_needed(len(prompt), max_new) > self.config.max_pages:
            raise ValueError(
                f"request needs {self._pages_needed(len(prompt), max_new)} "
                f"pages; page table holds {self.config.max_pages}")
        r = Request(rid=self._next_rid, prompt=prompt, max_new=max_new)
        self._next_rid += 1
        self._waiting.append(r)
        return r.rid

    def _page_tokens(self, r: Request) -> List[Tuple[int, ...]]:
        ps = self.config.page_size
        return [tuple(r.prompt[i * ps:(i + 1) * ps])
                for i in range(len(r.prompt) // ps)]

    def _reclaim(self, n_pages: int, n_shared: int,
                 page_tokens: List[Tuple[int, ...]]) -> int:
        """Evict prefix-cache pages until the head request's fresh-page
        need fits, per ``evict_policy``; returns the updated shared-page
        count (a "clear" drops the head's own match too)."""
        if self.tree is None or not self.tree.nodes:
            return n_shared
        if self.config.evict_policy == "clear":
            # legacy all-or-nothing: only safe once serving is idle
            if not self._active and not self._prefilling:
                self.evictions += len(self.tree.clear())
                n_shared = 0
            return n_shared
        while n_pages - n_shared > self.table.free_pages:
            shortfall = (n_pages - n_shared) - self.table.free_pages
            freed = self.tree.evict(shortfall, protect=page_tokens)
            if not freed:
                break                 # every remaining leaf is in use
            self.evictions += len(freed)
            n_shared = self.tree.lookup(page_tokens)
        return n_shared

    def _try_admit(self, r: Request) -> bool:
        n_pages = self._pages_needed(len(r.prompt), r.max_new)
        page_tokens = self._page_tokens(r)
        n_shared = self.tree.lookup(page_tokens) if self.tree else 0
        if n_pages - n_shared > self.table.free_pages:
            # prefix-cache pages are the only reclaimable slack: evict
            # when the pool alone is the blocker, else wait for drains
            n_shared = self._reclaim(n_pages, n_shared, page_tokens)
            if n_pages - n_shared > self.table.free_pages:
                return False
        shared = self.tree.match(page_tokens) if self.tree else []
        assert len(shared) == n_shared, (len(shared), n_shared)
        fresh = self.table.alloc(n_pages - len(shared))
        r.pages = shared + fresh
        r.shared_pages = len(shared)
        self._start_prefill(r, page_tokens)
        return True

    def _start_prefill(self, r: Request,
                       page_tokens: List[Tuple[int, ...]]):
        K = self.config.prefill_chunk_pages
        pp = math.ceil(len(r.prompt) / self.config.page_size)
        if not K or pp <= K:
            self._prefill(r, page_tokens)
            return
        # chunks start at multiples of K; fully prefix-shared leading
        # chunks are skipped (their pages already hold these exact KV
        # rows), but the final chunk always runs for the first token
        start = min((r.shared_pages // K) * K, ((pp - 1) // K) * K)
        self._prefilling.append(_PrefillJob(r, page_tokens, pp, start))

    def _prefill(self, r: Request, page_tokens: List[Tuple[int, ...]]):
        c = self.config
        P = len(r.prompt)
        pp = math.ceil(P / c.page_size)
        if self._active:
            # decode rounds this whole-prompt prefill displaces beyond
            # the one chunk quantum any prefill step costs
            q = max(c.prefill_chunk_pages, 1)
            self.hol_blocked_steps += max(0, math.ceil(pp / q) - 1)
        toks = np.zeros((1, pp * c.page_size), np.int32)
        toks[0, :P] = r.prompt
        (logits, k, v), d = self._step(
            "prefill", pp, self.params,
            {"tokens": jnp.asarray(toks),
             "last_idx": jnp.array([P - 1], jnp.int32)})
        r.phase_cycles["prefill"] += d
        ids = jnp.array(r.pages[:pp], jnp.int32)
        (self.pool_k, self.pool_v), d = self._step(
            "cache", pp, self.pool_k, self.pool_v, k, v, ids)
        r.phase_cycles["cache"] += d
        if self.tree is not None and page_tokens:
            self.tree.insert(page_tokens, r.pages[:len(page_tokens)])
        self._emit_first_token(r, logits)

    def _emit_first_token(self, r: Request, logits):
        tok = int(jnp.argmax(logits, axis=-1)[0])
        r.out_tokens.append(tok)
        self.tokens_out += 1
        r.last_tok = tok
        r.pos = len(r.prompt) - 1
        if len(r.out_tokens) >= r.max_new:
            self._complete(r)
        else:
            self._active.append(r)

    def _chunk_step(self):
        """Prefill the head job's next chunk (one scheduler quantum)."""
        c = self.config
        job = self._prefilling[0]
        r, ps = job.req, c.page_size
        P, pp, cs = len(r.prompt), job.pp, job.next_page
        n = min(c.prefill_chunk_pages, pp - cs)
        final = cs + n >= pp
        toks = np.zeros((1, n * ps), np.int32)
        seg = r.prompt[cs * ps:min(P, (cs + n) * ps)]
        toks[0, :len(seg)] = seg
        li = (P - 1 - cs * ps) if final else (n * ps - 1)
        batch = {"tokens": jnp.asarray(toks),
                 "last_idx": jnp.array([li], jnp.int32)}
        if cs == 0:
            (logits, k, v), d = self._step("prefill", n, self.params,
                                           batch)
        else:
            batch["ctx_pages"] = jnp.array(r.pages[:cs], jnp.int32)
            (logits, k, v), d = self._step(
                "chunkpf", (cs, n), self.params, self.pool_k, self.pool_v,
                batch)
        r.phase_cycles["prefill"] += d
        ids = jnp.array(r.pages[cs:cs + n], jnp.int32)
        (self.pool_k, self.pool_v), dc = self._step(
            "cache", n, self.pool_k, self.pool_v, k, v, ids)
        r.phase_cycles["cache"] += dc
        cst = self.chunk_stats.setdefault((cs, n),
                                          {"steps": 0, "cycles": 0})
        cst["steps"] += 1
        cst["cycles"] += d + dc
        job.next_page = cs + n
        # publish fully-written prompt pages incrementally so requests
        # arriving mid-prefill can already share the finished chunks
        if self.tree is not None and job.page_tokens:
            done_pages = min(cs + n, len(job.page_tokens))
            self.tree.insert(job.page_tokens[:done_pages],
                             r.pages[:done_pages])
        if final:
            self._prefilling.popleft()
            self._emit_first_token(r, logits)

    def _complete(self, r: Request):
        for p in r.pages:
            self.table.free(p)
        r.pages = []
        r.done = True
        self._finished.append(r)
        if self.bus is not None:
            self.bus.publish_request({
                "rid": r.rid, "prompt_len": r.prompt_len,
                "tokens": len(r.out_tokens),
                "shared_pages": r.shared_pages,
                "decode_batches": list(r.decode_batches),
                "phase_cycles": dict(r.phase_cycles)})

    def _admit(self):
        while self._waiting and (len(self._active) + len(self._prefilling)
                                 < self.config.buckets[-1]):
            if not self._try_admit(self._waiting[0]):
                break                   # FCFS: the head blocks the line
            self._waiting.popleft()

    def _decode_round(self):
        c = self.config
        sel = self._active[:c.buckets[-1]]
        bucket = next(b for b in c.buckets if b >= len(sel))
        self.bucket_hist[bucket] = self.bucket_hist.get(bucket, 0) + 1
        pages = np.zeros((bucket, c.max_pages), np.int32)
        pos = np.zeros(bucket, np.int32)
        toks = np.zeros((bucket, 1), np.int32)
        for i, r in enumerate(sel):
            pages[i, :len(r.pages)] = r.pages
            pos[i] = r.pos + 1
            toks[i, 0] = r.last_tok
        (_, self.pool_k, self.pool_v, next_tok), d = self._step(
            "decode", bucket, self.params, self.pool_k, self.pool_v,
            {"tokens": jnp.asarray(toks), "pos": jnp.asarray(pos),
             "pages": jnp.asarray(pages)})
        next_tok = np.asarray(next_tok)
        finished = []
        for i, r in enumerate(sel):
            r.pos += 1
            tok = int(next_tok[i])
            r.out_tokens.append(tok)
            self.tokens_out += 1
            r.last_tok = tok
            r.decode_batches.append(bucket)
            r.phase_cycles["decode"] += d
            if len(r.out_tokens) >= r.max_new:
                finished.append(r)
        for r in finished:
            self._active.remove(r)
            self._complete(r)

    def run(self) -> List[Request]:
        """Serve until every submitted request has finished; returns the
        requests completed by this call, in submission order."""
        start = len(self._finished)
        while self._waiting or self._active or self._prefilling:
            self._admit()
            progressed = False
            if self._prefilling:         # one chunk quantum per round,
                self._chunk_step()       # interleaved with decode below
                progressed = True
            if self._active:
                self._decode_round()
                progressed = True
            if not progressed and self._waiting:
                # head unadmittable with an otherwise idle engine
                r = self._waiting[0]
                raise PagePoolExhausted(
                    f"request {r.rid} needs "
                    f"{self._pages_needed(len(r.prompt), r.max_new)} pages "
                    f"with only {self.table.free_pages} free")
        return sorted(self._finished[start:], key=lambda r: r.rid)

    def reap(self) -> List[Request]:
        """Pop every finished request. Long-lived servers call this per
        wave so engine-held state stays constant-size (the soak test's
        flat-memory assertion)."""
        out, self._finished = self._finished, []
        return out

    # -- teardown / reporting -------------------------------------------
    def drain(self):
        """Release prefix-cache page references through the evictor;
        with no requests in flight the page table must then balance —
        asserted here so drain can't mask a refcount leak."""
        if self.tree is not None:
            self.tree.evict_all()
        if not (self._waiting or self._active or self._prefilling):
            assert self.table.balanced(), (
                f"page table unbalanced after drain: "
                f"{self.table.used_pages} pages still referenced")

    def close(self):
        """Close probe sessions (restores each step's original sink)."""
        if self.config.probe:
            for entry in self._steps.values():
                entry.close()

    def stats(self) -> Dict[str, Any]:
        hits = self.tree.hits if self.tree else 0
        misses = self.tree.misses if self.tree else 0
        return {
            "requests": len(self._finished),
            "phases": {p: dict(v) for p, v in self.phase_stats.items()},
            "retraces": self.retraces(),
            "pages_peak": self.table.peak_used,
            "prefix_hits": hits,
            "prefix_misses": misses,
            "prefix_hit_rate": hits / (hits + misses) if hits + misses
            else 0.0,
            "buckets": dict(self.bucket_hist),
            "steps_traced": len(self._steps),
            "evictions": self.evictions,
            "hol_blocked_steps": self.hol_blocked_steps,
            "tokens_out": self.tokens_out,
        }

    def phase_table(self) -> str:
        from repro.core.report import engine_phase_table
        return engine_phase_table(self.phase_stats)

    def chunk_table(self) -> str:
        from repro.core.report import engine_chunk_table
        return engine_chunk_table(self.chunk_stats)

    def request_table(self, requests: List[Request]) -> str:
        from repro.core.report import engine_request_table
        return engine_request_table(requests)
