"""Pre-traced engine step builders: bucketed prefill / scatter / decode.

Every step here is traced exactly once per (bucket, shape) at engine
warmup — continuous batching then serves any request mix with zero
retraces (asserted in tests/test_engine.py). Three step families:

- **prefill** (one per prompt page-count bucket): batch-1 forward over
  the page-aligned padded prompt. The KV rows written for real
  positions are bit-identical to an unpadded prefill (causal attention
  makes each row depend only on its prefix), and the returned logits
  are gathered at the *real* last token, not the padded one.
- **scatter** (one per page-count bucket): copies the prefill cache
  into the shared page pool at the request's page-table entries — the
  engine's cache-management phase, probed separately from model math.
- **decode** (one per batch-size bucket): batched single-token step
  over the paged pool. The attend math mirrors
  ``models.attention.attn_decode`` operation-for-operation (same einsum
  shapes, same global softmax, vector positions instead of a shared
  scalar), optionally routed through the ``paged_attention`` Pallas
  kernel — both paths bit-identical to the dense reference.
- **chunkpf** (one per (ctx pages, chunk pages) pair): continuation
  prefill of one page-aligned prompt chunk against KV context gathered
  from the pool. The flash blocks replay the *whole-prompt* row plan
  (``attention._row_plan`` over ctx+chunk) restricted to the chunk's
  rows, and every _flash_row op is row-independent, so chunked prefill
  is bit-identical to the equivalent whole-prompt prefill step.

Padded lanes of a decode bucket run token 0 at position 0 against the
null page; every dummy lane writes identical values to the same slot,
so the pool stays deterministic and no real page is touched.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import moe as moe_mod
from repro.models import transformer as tfm
from repro.models.attention import (_flash_row, _head_mask, _project_qkv,
                                    _repeat_kv, _row_plan)
from repro.models.layers import mlp_apply, rmsnorm


def engine_compatible(cfg) -> bool:
    """Token-in/token-out attention stacks only: the paged KV layout
    has no analogue for SSM/hybrid recurrent state or frontend embeds."""
    return cfg.family not in ("ssm", "hybrid") and cfg.frontend == "none"


def donation_argnums(phase: str) -> Tuple[int, ...]:
    """Positional args each step family may donate under
    ``jax.jit(..., donate_argnums=...)``.

    Only buffers the step returns an updated version of are donatable:
    the scatter step consumes+returns (pool_k, pool_v) at args (0, 1),
    decode at args (1, 2). Prefill returns no pool, and chunkpf *reads*
    the pool without returning it — donating either would invalidate
    live engine state."""
    if phase == "cache":
        return (0, 1)
    if phase == "decode":
        return (1, 2)
    return ()


def build_engine_prefill(model, n_pages: int, page_size: int) -> Callable:
    """Batch-1 prefill over ``n_pages * page_size`` padded tokens.

    fn(params, batch) with batch = {"tokens": (1, n_pages*page_size),
    "last_idx": (1,)} -> (logits (1, V) at last_idx, k, v) where k/v are
    (L, n_pages, page_size, kv_heads, head_dim) page-major cache blocks.
    """
    cfg = model.cfg
    seq = n_pages * page_size

    def prefill(params, batch):
        p = model._compute_cast(params)
        x = model._embed_in(p, batch)
        B, S, _ = x.shape
        assert S == seq, (S, seq)
        positions = model._positions(batch, S, B)
        x, cache = tfm.stack_prefill(p["stack"], x, positions, cfg, seq)
        with jax.named_scope("last_logits"):
            idx = batch["last_idx"][:, None, None].astype(jnp.int32)
            last = jnp.take_along_axis(
                x, idx.repeat(x.shape[-1], -1), axis=1)[:, 0]
            logits = jnp.einsum(
                "bd,dv->bv", last,
                model._unembed_weight(p).astype(last.dtype),
                preferred_element_type=jnp.float32)
            logits = model._mask_pad(logits)
        L = cache["k"].shape[0]
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        k = cache["k"].reshape(L, n_pages, page_size, kv, hd)
        v = cache["v"].reshape(L, n_pages, page_size, kv, hd)
        return logits, k, v

    return prefill


def build_page_scatter(n_pages: int) -> Callable:
    """Cache-management step: write ``n_pages`` prefilled page blocks
    into the pool at the request's page-table entries.

    fn(pool_k, pool_v, k, v, page_ids (n_pages,)) -> (pool_k, pool_v).
    Re-writing a prefix-shared page stores bit-identical values (same
    token prefix -> same KV rows), so sharing never perturbs readers.
    """

    def scatter(pool_k, pool_v, k, v, page_ids):
        with jax.named_scope("page_scatter"):
            pool_k = pool_k.at[:, page_ids].set(k.astype(pool_k.dtype))
            pool_v = pool_v.at[:, page_ids].set(v.astype(pool_v.dtype))
        return pool_k, pool_v

    return scatter


def build_chunk_prefill(model, ctx_pages: int, chunk_pages: int,
                        page_size: int) -> Callable:
    """Continuation prefill: one page-aligned prompt chunk against the
    request's already-written context pages in the pool.

    fn(params, pool_k, pool_v, batch) with batch = {"tokens":
    (1, chunk_pages*page_size), "ctx_pages": (ctx_pages,) int32,
    "last_idx": (1,)} -> (logits (1, V) at last_idx *within the chunk*,
    k, v) where k/v are (L, chunk_pages, page_size, kv, hd) page-major
    cache blocks for the chunk's own rows.

    Bit-identity with whole-prompt prefill is structural: the flash
    blocks replay ``_row_plan(ctx+chunk, attn_chunk, attn_chunk)`` — the
    exact plan the whole-prompt step uses at this padded length —
    restricted to the chunk's q rows, and every ``_flash_row`` reduction
    is row-independent, so each row's (m, l, acc) accumulation sequence
    is identical. Context K/V gathered from the pool equals the freshly
    computed K/V bit-for-bit because the flash einsums cast inputs to
    bfloat16 and the pool's ``kv_cache_dtype`` round-trip commutes with
    that cast (exact for the repo's bf16/f32 cache dtypes).
    """
    cfg = model.cfg
    ctx_len = ctx_pages * page_size
    Sq = chunk_pages * page_size
    S = ctx_len + Sq                     # whole-prompt padded length
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(hd)
    qb, rows = _row_plan(S, cfg.attn_chunk, cfg.attn_chunk)
    # whole-prompt flash blocks clipped to the chunk's rows: _flash_row
    # is row-independent, so computing the sub-range of a block with the
    # block's own (ctx, kv_chunk) reproduces the whole-prompt bits.
    subs = []
    for (off, ctx, chunk) in rows:
        i0, i1 = max(off, ctx_len), min(off + qb, S)
        if i0 < i1:
            subs.append((i0, i1, ctx, chunk))

    def chunkpf(params, pool_k, pool_v, batch):
        p = model._compute_cast(params)
        x = model._embed_in(p, batch)
        assert x.shape[1] == Sq, (x.shape, Sq)
        cd = x.dtype
        positions = jnp.broadcast_to(
            jnp.arange(ctx_len, S, dtype=jnp.int32)[None], (1, Sq))
        ctx_ids = batch["ctx_pages"]

        def body(carry, inp):
            h, = carry
            lp, li = inp
            with jax.named_scope("layer"):
                kp = jax.lax.dynamic_index_in_dim(pool_k, li, 0,
                                                  keepdims=False)
                vp = jax.lax.dynamic_index_in_dim(pool_v, li, 0,
                                                  keepdims=False)
                with jax.named_scope("attn"):
                    qn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
                    q, k_new, v_new = _project_qkv(lp["attn"], qn, cfg,
                                                   positions)
                    with jax.named_scope("ctx_gather"):
                        kc = kp[ctx_ids].reshape(1, ctx_len, kv, hd)
                        vc = vp[ctx_ids].reshape(1, ctx_len, kv, hd)
                        k_full = jnp.concatenate(
                            [kc.astype(cd), k_new], axis=1)
                        v_full = jnp.concatenate(
                            [vc.astype(cd), v_new], axis=1)
                    kr, vr = _repeat_kv(k_full, v_full, cfg)
                    with jax.named_scope("flash"):
                        outs = []
                        for (i0, i1, ctx, chunk) in subs:
                            q_blk = jax.lax.slice_in_dim(
                                q, i0 - ctx_len, i1 - ctx_len, axis=1)
                            k_ctx = jax.lax.slice_in_dim(kr, 0, ctx, axis=1)
                            v_ctx = jax.lax.slice_in_dim(vr, 0, ctx, axis=1)
                            o, _, _ = _flash_row(q_blk, k_ctx, v_ctx, i0,
                                                 chunk, scale)
                            outs.append(o.astype(cd))
                        o = (jnp.concatenate(outs, axis=1)
                             if len(outs) > 1 else outs[0])
                    with jax.named_scope("out_proj"):
                        hm = _head_mask(cfg, o.dtype)
                        if hm is not None:
                            o = o * hm[None, None, :, None]
                        a = jnp.einsum("bsnh,nhd->bsd", o, lp["attn"]["wo"])
                h = h + a
                if cfg.moe is not None:
                    with jax.named_scope("moe"):
                        m, _ = moe_mod.moe_apply(
                            lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                            cfg)
                else:
                    with jax.named_scope("mlp"):
                        m = mlp_apply(lp["mlp"],
                                      rmsnorm(h, lp["ln2"], cfg.norm_eps))
                h = h + m
            return (h,), (k_new, v_new)

        stack = p["stack"]
        with jax.named_scope("layers"):
            (x,), (ks, vs) = jax.lax.scan(
                body, (x,),
                (stack["layers"],
                 jnp.arange(cfg.num_layers, dtype=jnp.int32)))
        with jax.named_scope("final_norm"):
            x = rmsnorm(x, stack["ln_f"], cfg.norm_eps)
        with jax.named_scope("last_logits"):
            idx = batch["last_idx"][:, None, None].astype(jnp.int32)
            last = jnp.take_along_axis(
                x, idx.repeat(x.shape[-1], -1), axis=1)[:, 0]
            logits = jnp.einsum(
                "bd,dv->bv", last,
                model._unembed_weight(p).astype(last.dtype),
                preferred_element_type=jnp.float32)
            logits = model._mask_pad(logits)
        L = cfg.num_layers
        k = ks[:, 0].reshape(L, chunk_pages, page_size, kv, hd)
        v = vs[:, 0].reshape(L, chunk_pages, page_size, kv, hd)
        return logits, k, v

    return chunkpf


def _paged_attn_xla(lp, x, kp, vp, pages, pos, cfg, s_max: int,
                    page_size: int):
    """Dense-gather paged attend: ``attn_decode`` with vector positions
    and a page-table cache — operation-for-operation the same math."""
    positions = pos[:, None]
    q, k_new, v_new = _project_qkv(lp, x, cfg, positions)
    B = x.shape[0]
    H, Hp = cfg.num_heads, q.shape[2]
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if Hp != H:
        q = q[:, :, :H]
    qg = q.reshape(B, 1, kv, cfg.q_per_kv, hd)
    with jax.named_scope("cache_update"):
        pidx = jnp.take_along_axis(pages, (pos // page_size)[:, None],
                                   axis=1)[:, 0]
        slot = pos % page_size
        kp = kp.at[pidx, slot].set(k_new[:, 0].astype(kp.dtype))
        vp = vp.at[pidx, slot].set(v_new[:, 0].astype(vp.dtype))
    with jax.named_scope("attend"):
        scale = 1.0 / math.sqrt(hd)
        kd = kp[pages].reshape(B, s_max, kv, hd)
        vd = vp[pages].reshape(B, s_max, kv, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.bfloat16),
                       kd.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.arange(s_max)[None, :] <= pos[:, None]
        s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
        m = s.max(axis=-1, keepdims=True)
        pr = jnp.exp(s - m)
        l = pr.sum(axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskh->bkgqh", (pr / l).astype(jnp.bfloat16),
                       vd.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
        o = o[:, :, :, 0]                               # (B, kv, g, hd)
    return o, kp, vp


def _paged_attn_kernel(lp, x, kp, vp, pages, pos, cfg, s_max: int,
                       page_size: int, pages_per_step: int,
                       interpret: bool):
    """Pallas paged-attention attend (bit-identical to the XLA path)."""
    from repro.kernels.paged_attention import paged_attention
    positions = pos[:, None]
    q, k_new, v_new = _project_qkv(lp, x, cfg, positions)
    B = x.shape[0]
    H, Hp = cfg.num_heads, q.shape[2]
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if Hp != H:
        q = q[:, :, :H]
    qg = q.reshape(B, 1, kv, cfg.q_per_kv, hd)
    with jax.named_scope("cache_update"):
        pidx = jnp.take_along_axis(pages, (pos // page_size)[:, None],
                                   axis=1)[:, 0]
        slot = pos % page_size
        kp = kp.at[pidx, slot].set(k_new[:, 0].astype(kp.dtype))
        vp = vp.at[pidx, slot].set(v_new[:, 0].astype(vp.dtype))
    o = paged_attention(qg[:, 0], kp, vp, pages, pos,
                        pages_per_step=pages_per_step, interpret=interpret)
    return o, kp, vp


def build_paged_decode(model, batch_size: int, n_pages: int,
                       page_size: int, *, use_kernel: bool = True,
                       pages_per_step: int = 1,
                       interpret: bool | None = None) -> Callable:
    """Batched single-token decode over the paged pool.

    fn(params, pool_k, pool_v, batch) with batch = {"tokens": (B, 1),
    "pos": (B,), "pages": (B, n_pages)} ->
    (logits (B, V), pool_k, pool_v, next_tokens (B,)).
    """
    cfg = model.cfg
    s_max = n_pages * page_size
    if interpret is None:
        from repro.kernels.ops import _interpret_default
        interpret = _interpret_default()

    def attend(lp, x, kp, vp, pages, pos):
        if use_kernel:
            return _paged_attn_kernel(lp, x, kp, vp, pages, pos, cfg,
                                      s_max, page_size, pages_per_step,
                                      interpret)
        return _paged_attn_xla(lp, x, kp, vp, pages, pos, cfg, s_max,
                               page_size)

    def decode(params, pool_k, pool_v, batch):
        cd = jnp.dtype(cfg.compute_dtype)
        p = model._compute_cast(params)
        with jax.named_scope("embed"):
            x = jnp.take(p["embed"], batch["tokens"], axis=0).astype(cd)
        pos = batch["pos"]
        pages = batch["pages"]

        def body(carry, inp):
            h, pk, pv = carry
            lp, li = inp
            with jax.named_scope("layer"):
                kp = jax.lax.dynamic_index_in_dim(pk, li, 0,
                                                  keepdims=False)
                vp = jax.lax.dynamic_index_in_dim(pv, li, 0,
                                                  keepdims=False)
                with jax.named_scope("attn"):
                    o, kp, vp = attend(
                        lp["attn"], rmsnorm(h, lp["ln1"], cfg.norm_eps),
                        kp, vp, pages, pos)
                    with jax.named_scope("out_proj"):
                        B = h.shape[0]
                        H = cfg.num_heads
                        hd = cfg.resolved_head_dim
                        Hp = lp["attn"]["wo"].shape[0]
                        ow = o[:, None].reshape(B, 1, H, hd).astype(h.dtype)
                        if Hp != H:
                            ow = jnp.pad(ow, [(0, 0), (0, 0),
                                              (0, Hp - H), (0, 0)])
                        a = jnp.einsum("bsnh,nhd->bsd", ow, lp["attn"]["wo"])
                h = h + a
                if cfg.moe is not None:
                    with jax.named_scope("moe"):
                        mo, _ = moe_mod.moe_apply(
                            lp["moe"], rmsnorm(h, lp["ln2"], cfg.norm_eps),
                            cfg)
                else:
                    with jax.named_scope("mlp"):
                        mo = mlp_apply(lp["mlp"],
                                       rmsnorm(h, lp["ln2"], cfg.norm_eps))
                h = h + mo
                pk = jax.lax.dynamic_update_index_in_dim(pk, kp, li, 0)
                pv = jax.lax.dynamic_update_index_in_dim(pv, vp, li, 0)
            return (h, pk, pv), None

        stack = p["stack"]
        with jax.named_scope("layers"):
            (x, pool_k, pool_v), _ = jax.lax.scan(
                body, (x, pool_k, pool_v),
                (stack["layers"],
                 jnp.arange(cfg.num_layers, dtype=jnp.int32)))
        with jax.named_scope("final_norm"):
            x = rmsnorm(x, stack["ln_f"], cfg.norm_eps)
        with jax.named_scope("last_logits"):
            logits = jnp.einsum("bd,dv->bv", x[:, -1],
                                model._unembed_weight(p).astype(cd),
                                preferred_element_type=jnp.float32)
            logits = model._mask_pad(logits)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, pool_k, pool_v, next_tok

    return decode
