"""Intra-kernel grid-step probing — the probe layer *below* the jaxpr
interpreter.

The paper's probes cover "the full function hierarchy, including
submodules and loops", but a ``pallas_call`` is a single opaque equation
to the jaxpr instrumenter: flash-attention's kv-block pipeline loop and
the SSD sub-chunk scan were priced as one flat cost-model number. This
module extends exact counters into the kernel grid:

- **Extraction** (:func:`extract_kernel_tree`, called by
  ``hierarchy.extract`` behind ``ProbeConfig(kernel_probes=...)``):
  each matched ``pallas_call`` contributes a subtree
  ``<scope>/kernel/<name>#i/grid`` — the grid node is a loop whose trip
  count is the grid-step product — plus named inner scopes from
  ``jax.named_scope`` markers inside the kernel body (the flash
  ``kv_block`` loop, the ssd ``sub_chunk`` loop).

- **Instrumentation** (:func:`instrument_pallas`, the state merge hook
  invoked by ``instrument.Instrumenter``): the datapath ``pallas_call``
  is bound completely untouched (bit-identity is structural, not
  asserted-after-the-fact); alongside it a ``lax.scan`` over the grid
  steps replays the kernel body *cycles-only* and merges per-step
  enter/exit events into the ordinary ``ProbeState``. The scan carry is
  the "SMEM counter block" of a hardware deployment — a few scalar
  counters accumulated across sequential grid steps and folded into the
  global state at kernel exit. Because the rows land in the same state,
  ``decode_record``, ``Report``, ``ProbeSession`` and
  ``MeshProbeSession`` all see intra-kernel rows with zero API change.

- **Replay** (:func:`oracle_pallas`, used by ``oracle.KernelOracle``):
  the same walk with plain Python integers — integer equality of the
  two is the Table-II exactness check, one level deeper.

The cycles-only walk evaluates the kernel body jaxpr per grid step with
a *scalar environment*: ``program_id`` resolves to the step's grid
coordinates, pure scalar arithmetic on grid indices is evaluated for
real, and anything touching a memory ref is opaque (costed statically).
``pl.when`` regions therefore price the branch the hardware would
actually take when the predicate is grid-derived (the causal-skip
signal the DSE calibrator feeds on) and fall back to the widest branch
when it is data-dependent. Only ``cycle_source="model"`` is supported —
per-step wallclock timestamps inside one XLA op do not exist.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core

from repro.core import costmodel as cm
from repro.core.counters import U32, c64_add_u32

_as_jaxpr = cm._as_jaxpr

# sentinel for "not computable from grid indices alone"
_OPAQUE = object()

KERNEL_SEG = "kernel"          # path segment grouping kernels per scope
GRID_SEG = "grid"              # the per-kernel grid loop node


# ----------------------------------------------------------- eqn probing

kernel_name = cm.pallas_kernel_name


def static_grid(eqn) -> Optional[Tuple[int, ...]]:
    """The call's grid as ints, or None when any dim is dynamic."""
    gm = eqn.params.get("grid_mapping")
    grid = tuple(getattr(gm, "grid", ()) or ())
    out = []
    for g in grid:
        try:
            out.append(int(g))
        except (TypeError, ValueError):
            return None
    return tuple(out) if out else None


def matches(kernel_probes: Sequence[str], name: str) -> bool:
    return any(p == "*" or p == name for p in kernel_probes)


# the cost model's per-step transfer term — one definition for both the
# flat pricing and this walker, so calibration's DMA subtraction holds
dma_cycles = cm.pallas_dma_cycles


def unravel(it, grid: Tuple[int, ...]) -> List[Any]:
    """Grid coordinates of sequential step ``it`` (last axis fastest —
    the pallas sequential-grid iteration order). Works on traced values
    and plain ints alike."""
    idxs: List[Any] = []
    rem = it
    for g in reversed(grid):
        idxs.append(rem % g)
        rem = rem // g
    return list(reversed(idxs))


# ------------------------------------------------ extraction-time walk

def extract_kernel_tree(eqn, node, ensure, put_site, counters,
                        source_of) -> Optional[str]:
    """Build the kernel subtree for one matched ``pallas_call``.

    Registers ``EqnInfo`` rows for every body equation (paths under the
    grid node, per-execution cycles) so the instrumenter and oracle
    replay the same annotations the outer interpreter uses. Rows go into
    the hierarchy's per-site table via ``put_site(eqn, info, site)``
    keyed by the grid node path — kernel body jaxprs are shared between
    identical ``pallas_call`` sites by jax's tracing cache, so each site
    must resolve its own subtree. Returns the kernel node's path, or
    None when the grid is dynamic (the caller then falls back to flat
    costing).
    """
    from repro.core.hierarchy import EqnInfo, normalize_stack

    grid = static_grid(eqn)
    if grid is None:
        return None
    kname = kernel_name(eqn)
    kroot = ensure(node, KERNEL_SEG)
    idx = counters.get(kroot.path + "#k", 0)
    counters[kroot.path + "#k"] = idx + 1
    knode = ensure(kroot, f"{kname}#{idx}", "kernel")
    knode.source = knode.source or source_of(eqn)
    gnode = ensure(knode, GRID_SEG, "loop")
    steps = int(np.prod(grid)) if grid else 1
    gnode.trip_count = steps
    gnode.grid = grid
    gnode.source = gnode.source or source_of(eqn)
    # the per-step DMA is priced at the grid node itself
    gnode.own_cycles += dma_cycles(eqn)
    gnode.n_eqns += 1

    def reg(e, info):
        put_site(e, info, gnode.path)

    def walk(jaxpr, prefix):
        for e in jaxpr.eqns:
            segs = normalize_stack(str(e.source_info.name_stack))
            n = prefix
            for s in segs:
                n = ensure(n, s)
                if not n.source:
                    n.source = source_of(e)
            name = e.primitive.name
            if name == "cond":
                # pl.when / lax.cond: priced as one leaf whose runtime
                # cycles select the taken branch when the predicate is
                # grid-derived; the static column keeps the widest
                # branch (what the walker also charges for data-
                # dependent predicates).
                c = max(cm.static_jaxpr_cycles(_as_jaxpr(b))
                        for b in e.params["branches"])
                n.n_eqns += 1
                n.own_cycles += c
                reg(e, EqnInfo(path=n.path, cycles=c))
            elif name in ("scan", "while"):
                c = cm.static_eqn_cycles(e)
                n.n_eqns += 1
                n.own_cycles += c
                reg(e, EqnInfo(path=n.path, cycles=c))
            elif any(True for _ in cm._sub_jaxprs(e)):
                # pjit wrappers (floor_divide, ...) — descend in place
                reg(e, EqnInfo(path=n.path))
                walk(_as_jaxpr(next(iter(cm._sub_jaxprs(e)))), n)
            else:
                c = cm.eqn_cost(e).cycles
                n.n_eqns += 1
                n.own_cycles += c
                reg(e, EqnInfo(path=n.path, cycles=c))

    walk(_as_jaxpr(eqn.params["jaxpr"]), gnode)
    return knode.path


# ------------------------------------------------------ cycles-only walk
#
# One walk, two modes. ``ops`` supplies the mode-specific pieces:
#   zero()            -> additive identity for pending cycles
#   add(a, b)         -> accumulate (int or traced)
#   select(i, opts)   -> opts[i] for a scalar index (traced or int)
#   advance(v)        -> fold pending cycles into the clock
#   transition(a, b)  -> probed-scope delta events between paths

class _WalkOps:
    def zero(self):
        return 0

    def add(self, a, b):
        return a + b


class DeviceOps(_WalkOps):
    """Traced-mode ops mutating a boxed ProbeState."""

    def __init__(self, instr, box):
        self.instr = instr
        self.box = box

    def add(self, a, b):
        if isinstance(a, int) and isinstance(b, int):
            return a + b
        return jnp.asarray(a, U32) + jnp.asarray(b, U32)

    def select(self, i, opts: Sequence[int]):
        idx = jnp.clip(jnp.asarray(i, jnp.int32), 0, len(opts) - 1)
        return jnp.asarray(np.asarray(opts, np.uint32))[idx]

    def advance(self, v):
        if isinstance(v, int):
            if v:
                self.box[0] = self.instr.src.advance(self.box[0], v)
            return
        st = dict(self.box[0])
        if "cyc_lo" in st:                  # packed: scalar add-with-carry
            v32 = jnp.asarray(v, U32)
            nlo = st["cyc_lo"] + v32
            st["cyc_hi"] = st["cyc_hi"] + (nlo < v32).astype(U32)
            st["cyc_lo"] = nlo
        else:
            st["cycle"] = c64_add_u32(st["cycle"], v)
        self.box[0] = st

    def transition(self, a, b):
        self.box[0] = self.instr._transition(self.box[0], a, b)


class OracleOps(_WalkOps):
    """Concrete-mode ops mutating OracleCounters."""

    def __init__(self, orc, st):
        self.orc = orc
        self.st = st

    def add(self, a, b):
        return int(a) + int(b)

    def select(self, i, opts: Sequence[int]):
        return opts[int(np.clip(int(np.asarray(i)), 0, len(opts) - 1))]

    def advance(self, v):
        self.st.cycle += int(v)

    def transition(self, a, b):
        self.orc._transition(self.st, a, b)


def _scalar_eval(eqn, invals):
    """Concretely evaluate a pure scalar equation; _OPAQUE on failure."""
    try:
        outs = eqn.primitive.bind(*invals, **eqn.params)
    except Exception:
        return None
    return outs if isinstance(outs, (list, tuple)) else [outs]


def walk_step(hierarchy, body_jaxpr, grid: Tuple[int, ...], it,
              ops: _WalkOps, entry_path: str) -> None:
    """Replay the cycles of ONE grid step of a kernel body.

    Scalar values derived from the step's grid coordinates are computed
    for real (so ``pl.when`` predicates select the taken branch);
    everything else is opaque and statically priced via the ``EqnInfo``
    rows registered at extraction. Scope transitions fire exactly like
    the outer interpreter's — enters/exits at path deltas with the
    pending segment cost flushed first.
    """
    idxs = unravel(it, grid)

    def run(jaxpr, entry: str, env: Dict[Any, Any]):
        cur = entry
        pending = ops.zero()

        def flush():
            nonlocal pending
            ops.advance(pending)
            pending = ops.zero()

        def read(v):
            if isinstance(v, core.Literal):
                return v.val
            return env.get(v, _OPAQUE)

        for e in jaxpr.eqns:
            # all body rows were registered under the grid node path —
            # one site table per pallas_call site (shared-body safe)
            info = hierarchy.info_at(e, entry_path)
            path = info.path if info else cur
            if path != cur:
                flush()
                ops.transition(cur, path)
                cur = path
            name = e.primitive.name
            invals = [read(v) for v in e.invars]
            avail = all(v is not _OPAQUE for v in invals)
            cost = info.cycles if info else None
            if name == "program_id":
                pending = ops.add(pending, cost if cost is not None
                                  else cm.eqn_cost(e).cycles)
                env[e.outvars[0]] = idxs[int(e.params["axis"])]
            elif name == "num_programs":
                pending = ops.add(pending, cost if cost is not None
                                  else cm.eqn_cost(e).cycles)
                env[e.outvars[0]] = grid[int(e.params["axis"])]
            elif name == "cond":
                branch_cycles = [cm.static_jaxpr_cycles(_as_jaxpr(b))
                                 for b in e.params["branches"]]
                # only the branch index needs resolving — the remaining
                # operands are the (opaque) refs the branches touch
                if invals and invals[0] is not _OPAQUE:
                    pending = ops.add(pending,
                                      ops.select(invals[0], branch_cycles))
                else:
                    pending = ops.add(pending, max(branch_cycles))
                for v in e.outvars:
                    env[v] = _OPAQUE
            elif name in ("scan", "while"):
                pending = ops.add(pending, cost if cost is not None
                                  else cm.static_eqn_cycles(e))
                for v in e.outvars:
                    env[v] = _OPAQUE
            elif (sub := next(iter(cm._sub_jaxprs(e)), None)) is not None:
                if avail:
                    sj = _as_jaxpr(sub)
                    consts = sub.consts if hasattr(sub, "consts") else []
                    sub_env = dict(zip(sj.constvars, consts))
                    sub_env.update(zip(sj.invars, invals))
                    flush()
                    run(sj, cur, sub_env)
                    for vo, vi in zip(e.outvars, sj.outvars):
                        env[vo] = vi.val if isinstance(vi, core.Literal) \
                            else sub_env.get(vi, _OPAQUE)
                else:
                    pending = ops.add(pending, cm.static_eqn_cycles(e))
                    for v in e.outvars:
                        env[v] = _OPAQUE
            else:
                pending = ops.add(pending, cost if cost is not None
                                  else cm.eqn_cost(e).cycles)
                outs = None
                if avail and all(getattr(v.aval, "shape", None) == ()
                                 for v in e.outvars):
                    outs = _scalar_eval(e, invals)
                if outs is not None:
                    for v, o in zip(e.outvars, outs):
                        env[v] = o
                else:
                    # memory-ref invars never resolve, so anything
                    # derived from tile data stays opaque by construction
                    for v in e.outvars:
                        env[v] = _OPAQUE
        flush()
        ops.transition(cur, entry)

    env0: Dict[Any, Any] = {v: _OPAQUE for v in body_jaxpr.invars}
    run(body_jaxpr, entry_path, env0)


# --------------------------------------------------- instrumenter hook

def probed_kernel_path(instr, eqn, info) -> Optional[str]:
    """The kernel node path when this pallas_call was descended at
    extraction (the signal that the walk — not flat costing — owns its
    cycles), else None."""
    if info is None or not info.sub_path:
        return None
    node = instr.h.node(info.sub_path)
    if node is None or node.kind != "kernel":
        return None
    return info.sub_path


def instrument_pallas(instr, eqn, invals, state, info, cur_path: str):
    """State merge hook for a descended ``pallas_call``.

    Binds the original equation untouched (datapath bit-identity), then
    scans a cycles-only replica over the grid steps: per step the grid
    probe enters, the DMA + executed-path body cycles advance the model
    clock (with inner-scope events), and the grid probe exits. The scan
    carry — the ProbeState — is the counter block merged back into the
    caller's state at kernel exit.
    """
    if instr.src.kind != "model":
        raise ValueError("kernel_probes require cycle_source='model' — "
                         "grid steps inside one XLA op have no host "
                         "timestamps")
    outs = eqn.primitive.bind(*invals, **eqn.params)
    if not isinstance(outs, (list, tuple)):
        outs = [outs]
    kpath = info.sub_path
    gpath = f"{kpath}/{GRID_SEG}"
    body = _as_jaxpr(eqn.params["jaxpr"])
    grid = static_grid(eqn)
    steps = int(np.prod(grid)) if grid else 1
    dma = dma_cycles(eqn)

    state = instr._transition(state, cur_path, kpath)

    def step_fn(st, it):
        box = [st]
        ops = DeviceOps(instr, box)
        ops.transition(kpath, gpath)
        ops.advance(dma)
        walk_step(instr.h, body, grid, it, ops, gpath)
        ops.transition(gpath, kpath)
        return box[0], None

    state, _ = jax.lax.scan(step_fn, state,
                            jnp.arange(steps, dtype=jnp.int32))
    state = instr._transition(state, kpath, cur_path)
    return state, list(outs)


# -------------------------------------------------------- oracle hook

def oracle_pallas(orc, eqn, invals, st, info, cur_path: str):
    """Python-integer replay of a descended ``pallas_call`` — the
    KernelOracle side of the Table-II equality, one grid step at a
    time."""
    outs = eqn.primitive.bind(*invals, **eqn.params)
    if not isinstance(outs, (list, tuple)):
        outs = [outs]
    kpath = info.sub_path
    gpath = f"{kpath}/{GRID_SEG}"
    body = _as_jaxpr(eqn.params["jaxpr"])
    grid = static_grid(eqn)
    steps = int(np.prod(grid)) if grid else 1
    dma = dma_cycles(eqn)

    orc._transition(st, cur_path, kpath)
    ops = OracleOps(orc, st)
    for it in range(steps):
        ops.transition(kpath, gpath)
        ops.advance(dma)
        walk_step(orc.h, body, grid, it, ops, gpath)
        ops.transition(gpath, kpath)
    orc._transition(st, kpath, cur_path)
    return list(outs)
