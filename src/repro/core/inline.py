"""Inlining policies (paper §IV-F) mapped to scope selection.

Vitis HLS inlines small functions, destroying probe targets; RealProbe
counters this with three policies. The jaxpr analogue of "inlined" is a
scope too small to be worth a probe (XLA will fuse it away):

- ``default``:  scopes with fewer than ``SMALL_SCOPE_EQNS`` equations in
  their subtree are attributed to their parent (not probeable).
- ``off_all``:  every scope is probeable (most detailed view).
- ``off_top``:  full detail inside the pragma targets' subtrees, default
  collapsing elsewhere.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.hierarchy import Hierarchy, ScopeNode

SMALL_SCOPE_EQNS = 3


def _subtree_eqns(node: ScopeNode) -> int:
    return sum(n.n_eqns for n in node.walk())


def selectable_paths(h: Hierarchy, policy: str,
                     targets: Tuple[str, ...]) -> List[str]:
    """Scope paths eligible for probes under an inlining policy."""
    if policy not in ("default", "off_all", "off_top"):
        raise ValueError(f"unknown inline policy {policy!r}")
    tset = [t.strip("/") for t in targets]

    def in_target(path: str) -> bool:
        return any(path == t or path.startswith(t + "/") or t == ""
                   for t in tset)

    out: List[str] = []
    for node in h.root.walk():
        if not node.path:
            continue
        if node.opaque:
            out.append(node.path)   # boundary visible, inside is not
            continue
        if policy == "off_all":
            out.append(node.path)
            continue
        keep_detail = policy == "off_top" and in_target(node.path)
        if keep_detail or node.kind in ("loop", "while", "cond"):
            out.append(node.path)
            continue
        if _subtree_eqns(node) >= SMALL_SCOPE_EQNS:
            out.append(node.path)
    return out
