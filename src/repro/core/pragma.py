"""The user-facing probe API — the ``#pragma HLS RealProbe`` analogue.

One call, zero model edits::

    pf = probe(train_step, ProbeConfig(targets=("loss/layers",)))
    (loss, new_state), record = pf(params, batch)      # jitted inside
    print(pf.report(record))

The first call traces the function ONCE, extracts the hierarchy, selects
probes, and builds + jit-compiles the instrumented evaluator. Changing
probe targets afterwards (``pf.retarget(...)``) reuses the cached trace
and hierarchy — the incremental-synthesis analogue, measured in
``bench_incremental``. The *unprobed* function's own jit executable is
never touched.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.core import inline as inline_mod
from repro.core.buffer import HostSink, state_bytes
from repro.core.hierarchy import Hierarchy, extract
from repro.core.instrument import Instrumenter, ProbeAssignment, init_state
from repro.core.oracle import Oracle, OracleCounters
from repro.core.report import Report, build_report


@dataclass(frozen=True)
class ProbeConfig:
    targets: Tuple[str, ...] = ("",)      # subtree roots ("" = everything)
    depth_limit: Optional[int] = None     # max hierarchy depth below target
    max_probes: int = 50                  # paper's conservative default
    buffer_depth: int = 4                 # iteration records kept on-chip
    offload: float = 0.0                  # fraction of probes that DRAM-spill
                                          # when their ring fills (paper's
                                          # 0/25/50/75% dump ratios)
    cycle_source: str = "model"           # model | wallclock
    inline: str = "default"               # default | off_all | off_top
    kernel_probes: Tuple[str, ...] = ()   # pallas kernel body names to
                                          # probe inside ("*" = all);
                                          # empty = kernels stay flat
                                          # leaves (seed behavior)
    layout: str = "packed"                # probe-state layout: "packed"
                                          # (SoA planes, batched event
                                          # scatters) or "legacy" (dict
                                          # of small arrays, per-event
                                          # updates — the equivalence
                                          # reference)

    def replace(self, **kw) -> "ProbeConfig":
        return dataclasses.replace(self, **kw)


# Cross-instance trace memo: probing the SAME function object at the
# same shapes (DSE re-measure loops, overhead sweeps, repeated
# ``probe(fn, cfg)`` construction) reuses one traced jaxpr + out-tree,
# which in turn hits ``hierarchy.extract``'s memo — re-extraction costs
# nothing. Keyed weakly on the function so transient closures don't pin
# their constants forever.
_TRACE_MEMO: "weakref.WeakKeyDictionary" = None  # type: ignore[assignment]
_TRACE_MEMO_MAX = 8          # per-fn LRU cap (traced jaxprs are large)


def _trace_memo_get(fn, key):
    global _TRACE_MEMO
    if _TRACE_MEMO is None:
        import weakref
        _TRACE_MEMO = weakref.WeakKeyDictionary()
    try:
        ent = _TRACE_MEMO.get(fn)
        if ent is None or key not in ent:
            return None
        ent.move_to_end(key)
        return ent[key]
    except TypeError:                      # unhashable / non-weakrefable fn
        return None


def _trace_memo_put(fn, key, value):
    from collections import OrderedDict
    try:
        ent = _TRACE_MEMO.setdefault(fn, OrderedDict())
        ent[key] = value
        while len(ent) > _TRACE_MEMO_MAX:
            ent.popitem(last=False)
    except TypeError:
        pass


def _select_probes(h: Hierarchy, cfg: ProbeConfig) -> Tuple[str, ...]:
    eligible = set(inline_mod.selectable_paths(h, cfg.inline, cfg.targets))
    tset = [t.strip("/") for t in cfg.targets]

    def in_target(path: str) -> bool:
        return any(t == "" or path == t or path.startswith(t + "/")
                   for t in tset)

    chosen = []
    for node in h.root.walk():          # preorder: shallow scopes first
        p = node.path
        if not p or p not in eligible or not in_target(p):
            continue
        if cfg.depth_limit is not None:
            rel_depth = p.count("/") + 1
            for t in tset:
                if t and (p == t or p.startswith(t + "/")):
                    rel_depth = p[len(t):].count("/")
                    break
            if rel_depth > cfg.depth_limit:
                continue
        chosen.append(p)
        if len(chosen) >= cfg.max_probes:
            break
    return tuple(chosen)


class ProbedFunction:
    """Instrumented wrapper around a traced user function."""

    def __init__(self, fn: Callable, config: ProbeConfig = ProbeConfig()):
        self.fn = fn
        self.config = config
        self.sink = HostSink()
        self._hierarchy: Optional[Hierarchy] = None
        self._trace_key = None
        self._closed = None
        self._kernel_key = None
        self._assignment: Optional[ProbeAssignment] = None
        self._instrumenter: Optional[Instrumenter] = None
        self._jitted = None
        self._jitted_stateful = None
        self.timings: Dict[str, float] = {}

    # -- stage 2: module extraction (once) ------------------------------
    def trace(self, *args, **kwargs) -> Hierarchy:
        key = (jax.tree_util.tree_structure((args, kwargs)), tuple(
            (a.shape, str(a.dtype)) if hasattr(a, "shape")
            else ("static", repr(a))
            for a in jax.tree_util.tree_leaves((args, kwargs))))
        kkey = tuple(self.config.kernel_probes)
        if self._closed is None or key != self._trace_key:
            t0 = time.perf_counter()
            cached = _trace_memo_get(self.fn, key)
            if cached is not None:
                self._closed, self._out_tree = cached
            else:
                self._closed = jax.make_jaxpr(self.fn)(*args, **kwargs)
                self._out_tree = jax.tree_util.tree_structure(
                    jax.eval_shape(self.fn, *args, **kwargs))
                _trace_memo_put(self.fn, key,
                                (self._closed, self._out_tree))
            self._trace_key = key
            self._hierarchy = None
            self.timings["trace_s"] = time.perf_counter() - t0
        if self._hierarchy is None or kkey != self._kernel_key:
            # kernel descent is part of extraction, not tracing — a
            # retarget that flips kernel_probes reuses the cached trace
            t1 = time.perf_counter()
            self._hierarchy = extract(self._closed, kernel_probes=kkey)
            self._kernel_key = kkey
            self._jitted = None
            self.timings["extract_s"] = time.perf_counter() - t1
        return self._hierarchy

    @property
    def hierarchy(self) -> Hierarchy:
        if self._hierarchy is None:
            raise RuntimeError("call .trace(*args) or the function first")
        return self._hierarchy

    # -- stage 3: RealProbe IP generation --------------------------------
    def _build(self, *args, **kwargs):
        if self.config.kernel_probes and self.config.cycle_source != "model":
            raise ValueError("kernel_probes require cycle_source='model': "
                             "grid steps execute inside one XLA op, so "
                             "there is no host timestamp per step")
        h = self.trace(*args, **kwargs)
        t0 = time.perf_counter()
        paths = _select_probes(h, self.config)
        import math as _math
        n_spill = int(_math.ceil(float(self.config.offload) * len(paths)))
        spill = tuple(i < n_spill for i in range(len(paths)))
        self._assignment = ProbeAssignment(paths=paths,
                                           depth=self.config.buffer_depth,
                                           spill=spill)
        interp = Instrumenter(h, self._assignment,
                              cycle_source=self.config.cycle_source,
                              sink=self.sink, layout=self.config.layout)
        self._instrumenter = interp

        def instrumented_stateful(state, *a, **kw):
            flat = jax.tree_util.tree_leaves((a, kw))
            outs, state = interp.run(h.closed_jaxpr, flat, state)
            return jax.tree_util.tree_unflatten(self._out_tree, outs), state

        def instrumented(*a, **kw):
            # one-shot = stateful from a fresh zeroed state
            state = init_state(self._assignment.n, self.config.buffer_depth,
                               layout=self.config.layout)
            return instrumented_stateful(state, *a, **kw)

        self._jitted = jax.jit(instrumented)
        self._jitted_stateful = jax.jit(instrumented_stateful)
        self.timings["instrument_s"] = time.perf_counter() - t0

    # -- public ----------------------------------------------------------
    def __call__(self, *args, **kwargs):
        if self._jitted is None:
            self._build(*args, **kwargs)
        return self._jitted(*args, **kwargs)

    def ensure_built(self, *args, **kwargs) -> "ProbedFunction":
        """Trace + instrument + jit without executing (for sessions)."""
        if self._jitted is None:
            self._build(*args, **kwargs)
        return self

    def init_state(self):
        """Fresh zeroed device counter state for the stateful entry."""
        return init_state(self.assignment.n, self.config.buffer_depth,
                          layout=self.config.layout)

    def stateful_call(self, state, *args, **kwargs):
        """Run one step with explicit counter state threading.

        Unlike ``__call__`` (which zeroes counters per invocation), the
        caller owns the state, so cycle/call totals accumulate across
        steps — the streaming ``ProbeSession`` substrate. Returns
        ``(outputs, new_state)``; the jitted executable is shared with
        ``__call__``'s build, so no retrace happens per step.
        """
        if self._jitted is None:
            self._build(*args, **kwargs)
        return self._jitted_stateful(state, *args, **kwargs)

    def retarget(self, config: ProbeConfig) -> "ProbedFunction":
        """Incremental re-instrumentation: reuses the cached trace +
        hierarchy; only probe selection and the instrumented evaluator
        are rebuilt (paper §IV-C.2)."""
        self.config = config
        self._jitted = None
        return self

    @property
    def assignment(self) -> ProbeAssignment:
        if self._assignment is None:
            raise RuntimeError("not built yet")
        return self._assignment

    def probe_paths(self) -> Tuple[str, ...]:
        return self.assignment.paths

    def resource_bytes(self) -> int:
        return state_bytes(self.assignment.n, self.config.buffer_depth,
                           layout=self.config.layout)

    # -- verification / reporting ------------------------------------------
    def oracle(self, *args, **kwargs) -> OracleCounters:
        if self._assignment is None:
            self._build(*args, **kwargs)
        flat = jax.tree_util.tree_leaves((args, kwargs))
        return Oracle(self.hierarchy, self._assignment).run(
            self.hierarchy.closed_jaxpr, flat)

    def report(self, record: Dict[str, Any]) -> Report:
        return build_report(self.hierarchy, self.assignment, record,
                            self.sink, cycle_source=self.config.cycle_source)


def probe(fn: Callable, config: ProbeConfig = ProbeConfig()) -> ProbedFunction:
    """Single-directive activation (the pragma)."""
    return ProbedFunction(fn, config)
