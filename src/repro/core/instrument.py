"""Non-intrusive instrumentation: a jaxpr interpreter that threads a
ProbeState through the user's program.

This is the RealProbe IP. The user function is traced once (by
``pragma.probe``); this module re-evaluates the jaxpr equation-by-
equation, and at **scope boundary transitions only** (the paper's
edge-triggered sampling) emits counter updates:

    enter(p):  starts[p] (first activation), totals[p] -= now, ring write
    exit(p):   ends[p] = now, totals[p] += now, ring write,
               calls[p] += 1, optional DRAM spill

(the packed layout's enter-subtract/exit-add telescopes to the legacy
``now - last`` accumulation exactly; see the layout notes below)

Between events the global cycle counter advances by the *statically
summed* cost-model cycles of the executed segment — one fused add per
segment instead of one per equation (the analogue of the paper's
hierarchical read-mux optimization, quantified in bench_overhead).

Decoupling guarantees:
- instrumentation ops never read or write model tensors (only the state),
  so enabling probes cannot change model outputs (asserted in tests);
- scans whose bodies contain no probes / no dynamic control flow are left
  completely untouched (black-box bind + static cycle fold), keeping the
  instrumented HLO footprint O(probes), not O(model).

Control flow: scan bodies with probes get the state threaded through the
carry (per-iteration records, first-``depth`` iterations kept — the
paper's first-4-iterations truncation); while loops always thread state
(trip counts are runtime-only — the exact thing C-synth/Co-sim get
wrong); cond branches thread state so the *taken* branch's cycles are
counted.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core
from jax._src.core import eval_jaxpr as _eval_jaxpr

from repro.core import costmodel as cm
from repro.core import kernelprobe
from repro.core.buffer import HostSink
from repro.core.counters import (c64, c64_add, c64_add_int, c64_sub,
                                 c64_to_int, c64_zeros, U32)
from repro.core.hierarchy import Hierarchy

_as_jaxpr = cm._as_jaxpr


# --------------------------------------------------------- probe state
#
# Packed structure-of-arrays layout (the default): the per-probe c64
# counters live as contiguous planes of ONE (3, n, 2) uint32 buffer, so
# a scope transition updates all of them with a couple of fused scatters
# and the state threads through scan/while/cond carries as 4 leaves
# instead of 7. The legacy dict-of-small-arrays layout is kept
# (``layout="legacy"``) as the equivalence-test reference and the
# bench_instrument before/after subject.

# plane indices into the packed counter block ("cnt"). There is no LAST
# plane: instead of remembering each probe's enter timestamp, the packed
# layout SUBTRACTS t from TOTALS at enter and ADDS t at exit — modular
# c64 arithmetic telescopes to the same sum, every interval is closed by
# the time a record is decoded, and one whole counter plane (plus its
# per-event bookkeeping) disappears from the threaded carry. The order
# makes each event kind touch ADJACENT planes (enter: STARTS+TOTALS,
# exit: TOTALS+ENDS), so a single-event transition is one contiguous
# dynamic-update-slice.
STARTS, TOTALS, ENDS = 0, 1, 2

# Bump whenever the on-device state layout changes: persisted caches
# (EvalCache) fold this into their keys so records produced under one
# layout can never serve a run instrumented under another.
STATE_LAYOUT_VERSION = 2

LAYOUTS = ("packed", "legacy")


def init_state(n_probes: int, depth: int,
               layout: str = "packed") -> Dict[str, jnp.ndarray]:
    if layout == "legacy":
        return {
            "cycle": c64(0),
            "starts": c64_zeros((n_probes,)),
            "ends": c64_zeros((n_probes,)),
            "totals": c64_zeros((n_probes,)),
            "last": c64_zeros((n_probes,)),
            "calls": jnp.zeros((n_probes,), U32),
            "ring": jnp.zeros((n_probes, depth, 2, 2), U32),
        }
    assert layout == "packed", layout
    # the global clock lives as two scalar words: reading "now" costs
    # zero equations and the segment advance is a 3-op add-with-carry
    return {
        "cyc_hi": jnp.zeros((), U32),
        "cyc_lo": jnp.zeros((), U32),
        "cnt": c64_zeros((3, n_probes)),          # (3, n, 2) SoA planes
        "calls": jnp.zeros((n_probes,), U32),
        "ring": jnp.zeros((n_probes, depth, 2, 2), U32),
    }


def state_layout(state: Dict[str, Any]) -> str:
    """Which layout a (device or host) ProbeState dict uses."""
    return "packed" if "cnt" in state else "legacy"


def state_totals(state: Dict[str, Any]) -> np.ndarray:
    """Per-probe total cycles (int64) straight from a raw state, either
    layout — the cheap read sessions poll at window boundaries."""
    if "cnt" in state:
        arr = np.asarray(state["cnt"])[TOTALS]
    else:
        arr = np.asarray(state["totals"])
    return np.atleast_1d(c64_to_int(arr))


def state_clock(state: Dict[str, Any]) -> int:
    """Current model-clock value (int) straight from a raw state, either
    layout — the cheap read the serving engine polls between phase steps
    to attribute per-request cycle deltas."""
    if "cyc_hi" in state:
        return int((np.asarray(state["cyc_hi"]).astype(np.uint64)
                    << np.uint64(32))
                   | np.asarray(state["cyc_lo"]).astype(np.uint64))
    return int(c64_to_int(np.asarray(state["cycle"])))


def decode_record(record: Dict[str, Any]) -> Dict[str, Any]:
    """Host-side view of a ProbeState / device record (either layout).

    Splits the (hi, lo) uint32 counter pairs into plain integers:
    ``cycle`` (int), ``starts``/``ends``/``totals`` (int64 arrays),
    ``calls`` (int64 array) and ``ring`` (int64, shape (n, depth, 2) of
    (start, end) pairs). The single place that knows the state layout —
    report building and streaming aggregation both go through it, and
    the decoded dict is identical for the packed and legacy layouts
    (asserted in tests/test_layout.py).
    """
    if "cnt" in record:
        cnt = np.asarray(record["cnt"])
        starts, ends = cnt[STARTS], cnt[ENDS]
        totals = cnt[TOTALS]
        cycle = int((np.asarray(record["cyc_hi"]).astype(np.uint64)
                     << np.uint64(32))
                    | np.asarray(record["cyc_lo"]).astype(np.uint64))
    else:
        starts = np.asarray(record["starts"])
        ends = np.asarray(record["ends"])
        totals = np.asarray(record["totals"])
        cycle = int(c64_to_int(np.asarray(record["cycle"])))
    ring = np.asarray(record["ring"])
    return {
        "cycle": cycle,
        "starts": np.atleast_1d(c64_to_int(starts)),
        "ends": np.atleast_1d(c64_to_int(ends)),
        "totals": np.atleast_1d(c64_to_int(totals)),
        "calls": np.asarray(record["calls"]).astype(np.int64),
        "ring": np.stack([np.atleast_2d(c64_to_int(ring[:, :, 0])),
                          np.atleast_2d(c64_to_int(ring[:, :, 1]))],
                         axis=-1),
    }


@dataclass
class ProbeAssignment:
    paths: Tuple[str, ...]                 # probe id -> scope path
    depth: int                             # ring depth per probe
    spill: Tuple[bool, ...]                # probe id -> DRAM offload enabled

    @property
    def n(self) -> int:
        return len(self.paths)

    def id_of(self, path: str) -> Optional[int]:
        try:
            return self.paths.index(path)
        except ValueError:
            return None


class CycleSource:
    """Where 'now' comes from. ``model``: the deterministic cost-model
    clock (exact, CPU-validatable). ``wallclock``: host-time reads via
    ordered io_callback (captures real runtime dynamics)."""

    def __init__(self, kind: str):
        assert kind in ("model", "wallclock")
        self.kind = kind

    def advance(self, state, static_cycles: int):
        if static_cycles and self.kind == "model":
            state = dict(state)
            if "cyc_lo" in state:                  # packed scalar words
                lo_add = np.uint32(static_cycles & 0xFFFFFFFF)
                hi_add = (static_cycles >> 32) & 0xFFFFFFFF
                nlo = state["cyc_lo"] + lo_add
                # result < addend  <=>  the 32-bit add wrapped
                nhi = state["cyc_hi"] + (nlo < lo_add).astype(U32)
                if hi_add:
                    nhi = nhi + np.uint32(hi_add)
                state["cyc_lo"], state["cyc_hi"] = nlo, nhi
            else:
                state["cycle"] = c64_add_int(state["cycle"], static_cycles)
        return state

    @staticmethod
    def _host_now():
        t = time.perf_counter_ns()
        return np.array([(t >> 32) & 0xFFFFFFFF, t & 0xFFFFFFFF], np.uint32)

    def now(self, state):
        """Legacy-layout read: (state, (2,)-pair)."""
        if self.kind == "model":
            return state, state["cycle"]
        pair = jax.experimental.io_callback(
            self._host_now, jax.ShapeDtypeStruct((2,), jnp.uint32),
            ordered=True)
        state = dict(state)
        state["cycle"] = pair
        return state, pair

    def now_scalars(self, state):
        """Packed-layout read: (state, hi word, lo word). In model mode
        this emits ZERO equations — the clock already lives as the two
        scalar state leaves."""
        if self.kind == "model":
            return state, state["cyc_hi"], state["cyc_lo"]
        pair = jax.experimental.io_callback(
            self._host_now, jax.ShapeDtypeStruct((2,), jnp.uint32),
            ordered=True)
        state = dict(state)
        state["cyc_hi"], state["cyc_lo"] = pair[0], pair[1]
        return state, state["cyc_hi"], state["cyc_lo"]


# ------------------------------------------------------ event emitters

_IB = None          # lazily-built lax.GatherScatterMode.PROMISE_IN_BOUNDS
_DNUMS = {}


def _dnums():
    """Gather/scatter dimension numbers for the packed buffers (direct
    ``lax.gather``/``lax.scatter`` — the jnp indexing sugar spends more
    equations normalizing indices than the update itself costs). Ring
    access gathers whole (depth, 2)-rows per (probe, side) pair so the
    step index never enters a scatter index (one-hot select instead);
    the ring depth only appears in call-site slice sizes, never here."""
    global _IB
    if _DNUMS:
        return _DNUMS
    from jax import lax
    _IB = lax.GatherScatterMode.PROMISE_IN_BOUNDS
    _DNUMS.update(
        cnt_g=lax.GatherDimensionNumbers(
            offset_dims=(1,), collapsed_slice_dims=(0, 1),
            start_index_map=(0, 1)),
        cnt_s=lax.ScatterDimensionNumbers(
            update_window_dims=(1,), inserted_window_dims=(0, 1),
            scatter_dims_to_operand_dims=(0, 1)),
        ring_g=lax.GatherDimensionNumbers(
            offset_dims=(1, 2), collapsed_slice_dims=(0, 2),
            start_index_map=(0, 2)),
        ring_s=lax.ScatterDimensionNumbers(
            update_window_dims=(1, 2), inserted_window_dims=(0, 2),
            scatter_dims_to_operand_dims=(0, 2)),
        vec_g=lax.GatherDimensionNumbers(
            offset_dims=(), collapsed_slice_dims=(0,),
            start_index_map=(0,)),
    )
    return _DNUMS


def _pair2(hi, lo, shape):
    """Broadcast two scalar u32 words into a (*shape, 2) c64 array."""
    from jax import lax
    return lax.concatenate(
        [lax.broadcast_in_dim(hi, tuple(shape) + (1,), ()),
         lax.broadcast_in_dim(lo, tuple(shape) + (1,), ())],
        dimension=len(shape))


def emit_events(state, th, tl, exit_pids: Tuple[int, ...],
                enter_pids: Tuple[int, ...], depth: int,
                spill: Tuple[bool, ...],
                sink: Optional[HostSink] = None):
    """One scope transition's exits + enters as a single batched update
    on the packed layout.

    All events of a transition share one timestamp (hi word ``th``, lo
    word ``tl``; the model clock does not advance between them, so this
    is bit-identical to the per-event legacy path). The whole delta
    lands as one fused gather + scatter on the counter planes (a
    contiguous dynamic-update-slice when the transition is a single
    event), a one-hot masked row update on the ring, and one constant-
    vector add on the call counts. Exits ADD the timestamp to TOTALS
    while enters add its two's complement (i.e. subtract), so the
    telescoped sum equals the legacy last-based accumulation exactly
    once every interval is closed.
    """
    k, m = len(exit_pids), len(enter_pids)
    n_ev = k + m
    if not n_ev:
        return state
    from jax import lax
    dn = _dnums()
    state = dict(state)
    cnt, calls, ring = state["cnt"], state["calls"], state["ring"]
    n = calls.shape[0]
    pids = np.asarray(exit_pids + enter_pids, np.int32)
    spill_mask = np.asarray([spill[p] for p in pids], bool)
    all_spill = bool(spill_mask.all())
    no_spill = not spill_mask.any()

    if n_ev == 1:
        ev_calls = lax.squeeze(
            lax.slice(calls, (int(pids[0]),), (int(pids[0]) + 1,)), (0,))
    else:
        ev_calls = lax.gather(calls, pids[:, None], dn["vec_g"], (1,),
                              unique_indices=True, mode=_IB)
    if all_spill:                                   # static specialization
        slot = lax.rem(ev_calls, np.uint32(depth))
        write = None                                # ring always written
    elif no_spill:
        slot = jnp.minimum(ev_calls, np.uint32(depth - 1))
        write = ev_calls < np.uint32(depth)
    else:
        slot = jnp.where(spill_mask, lax.rem(ev_calls, np.uint32(depth)),
                         jnp.minimum(ev_calls, np.uint32(depth - 1)))
        write = jnp.logical_or(spill_mask, ev_calls < np.uint32(depth))

    # --- counter planes -------------------------------------------------
    # TOTALS: exits ADD t with carry, enters SUBTRACT t with borrow
    tp11 = _pair2(th, tl, (1, 1))                   # shared (1, 1, 2) t
    if n_ev == 1:
        pid = int(pids[0])
        old = lax.slice(cnt, (TOTALS, pid, 0), (TOTALS + 1, pid + 1, 2))
        oh = lax.slice(old, (0, 0, 0), (1, 1, 1))
        ol = lax.slice(old, (0, 0, 1), (1, 1, 2))
        if k:
            ntl = ol + tl
            nth = oh + th + (ntl < tl).astype(U32)
            tot = lax.concatenate([nth, ntl], 2)
            upd = lax.concatenate([tot, tp11], 0)   # TOTALS, ENDS planes
            cnt = lax.dynamic_update_slice(
                cnt, upd, (np.int32(TOTALS), np.int32(pid), np.int32(0)))
        else:
            ntl = ol - tl
            nth = oh - th - (ol < tl).astype(U32)
            tot = lax.concatenate([nth, ntl], 2)
            first = ev_calls == np.uint32(0)
            st_old = lax.slice(cnt, (STARTS, pid, 0),
                               (STARTS + 1, pid + 1, 2))
            st_new = lax.select_n(first, st_old, tp11)
            upd = lax.concatenate([st_new, tot], 0)  # STARTS, TOTALS planes
            cnt = lax.dynamic_update_slice(
                cnt, upd, (np.int32(STARTS), np.int32(pid), np.int32(0)))
    else:
        # one gather: TOTALS rows for every event + STARTS rows for
        # enters; one scatter: TOTALS + ENDS + STARTS results
        g_idx = np.concatenate(
            [np.stack([np.full(n_ev, TOTALS, np.int32), pids], 1),
             np.stack([np.full(m, STARTS, np.int32), pids[k:]], 1)]
        ).astype(np.int32)
        old = lax.gather(cnt, g_idx, dn["cnt_g"], (1, 1, 2), mode=_IB)
        oh = lax.squeeze(lax.slice(old, (0, 0), (n_ev + m, 1)), (1,))
        ol = lax.squeeze(lax.slice(old, (0, 1), (n_ev + m, 2)), (1,))
        uh, ul = [], []
        if k:                                       # TOTALS += t (exits)
            xl, xh = lax.slice(ol, (0,), (k,)), lax.slice(oh, (0,), (k,))
            etl = xl + tl
            uh.append(xh + th + (etl < tl).astype(U32))
            ul.append(etl)
        if m:                                       # TOTALS -= t (enters)
            el = lax.slice(ol, (k,), (n_ev,))
            eh = lax.slice(oh, (k,), (n_ev,))
            uh.append(eh - th - (el < tl).astype(U32))
            ul.append(el - tl)
        if k:                                       # ENDS = t
            uh.append(lax.broadcast(th, (k,)))
            ul.append(lax.broadcast(tl, (k,)))
        if m:                                       # STARTS = first ? t : old
            first = lax.slice(ev_calls, (k,), (n_ev,)) == np.uint32(0)
            uh.append(lax.select_n(first, lax.slice(oh, (n_ev,), (n_ev + m,)),
                                   lax.broadcast(th, (m,))))
            ul.append(lax.select_n(first, lax.slice(ol, (n_ev,), (n_ev + m,)),
                                   lax.broadcast(tl, (m,))))
        s_idx = np.concatenate(
            [np.stack([np.full(n_ev, TOTALS, np.int32), pids], 1),
             np.stack([np.full(k, ENDS, np.int32), pids[:k]], 1),
             np.stack([np.full(m, STARTS, np.int32), pids[k:]], 1)]
        ).astype(np.int32)
        vh = lax.concatenate(uh, 0) if len(uh) > 1 else uh[0]
        vl = lax.concatenate(ul, 0) if len(ul) > 1 else ul[0]
        vals = lax.concatenate([vh[:, None], vl[:, None]], 1)
        cnt = lax.scatter(cnt, s_idx, vals, dn["cnt_s"],
                          unique_indices=True, mode=_IB)

    # --- ring -----------------------------------------------------------
    if n_ev == 1:
        # single event: one dynamic slice/update at the traced slot
        # (unsigned indices skip lax's negative-index normalization)
        start = (np.uint32(pids[0]), slot, np.uint32(1 if k else 0),
                 np.uint32(0))
        upd = lax.reshape(tp11, (1, 1, 1, 2))
        if write is not None:
            cur = lax.dynamic_slice(ring, start, (1, 1, 1, 2))
            upd = lax.select_n(write, cur, upd)
        ring = lax.dynamic_update_slice(ring, upd, start)
    else:
        # gather whole (depth, 2)-rows per (probe, side), update the
        # slot via a one-hot select, scatter back — the dynamic slot
        # index never becomes a scatter index
        sides = np.concatenate([np.ones(k), np.zeros(m)])[:, None]
        r_idx = np.concatenate([pids[:, None], sides], 1).astype(np.int32)
        rows = lax.gather(ring, r_idx, dn["ring_g"], (1, depth, 1, 2),
                          mode=_IB)                 # (n_ev, depth, 2)
        hot = lax.broadcast_in_dim(slot, (n_ev, depth), (0,)) == \
            np.arange(depth, dtype=np.uint32)
        if write is not None:
            hot = jnp.logical_and(
                hot, lax.broadcast_in_dim(write, (n_ev, depth), (0,)))
        new_rows = lax.select_n(
            lax.broadcast_in_dim(hot, (n_ev, depth, 2), (0, 1)),
            rows, _pair2(th, tl, (n_ev, depth)))
        ring = lax.scatter(ring, r_idx, new_rows, dn["ring_s"],
                           unique_indices=True, mode=_IB)

    # --- call counts: one constant-vector add --------------------------
    if k:
        inc = np.zeros(n, np.uint32)
        inc[pids[:k]] = 1
        calls = calls + inc
    state["cnt"], state["calls"], state["ring"] = cnt, calls, ring
    for pid in exit_pids:
        if spill[pid] and sink is not None:
            new_calls = calls[pid]
            should = lax.rem(new_calls, np.uint32(depth)) == 0
            jax.experimental.io_callback(
                functools.partial(sink.dump, pid), None,
                should, new_calls - np.uint32(depth), ring[pid],
                ordered=True)
    return state


# Legacy per-event emitters (dict-of-small-arrays layout). Retained as
# the bit-exact reference for the layout-equivalence tests and as the
# before-side of bench_instrument; the packed path above is the default.

def emit_enter(state, pid: int, depth: int, spill: bool, src: CycleSource):
    state, t = src.now(state)
    state = dict(state)
    calls = state["calls"][pid]
    first = (calls == 0)
    state["starts"] = state["starts"].at[pid].set(
        jnp.where(first, t, state["starts"][pid]))
    state["last"] = state["last"].at[pid].set(t)
    slot = (calls % depth) if spill else jnp.minimum(calls, depth - 1)
    write = True if spill else (calls < depth)
    cur = state["ring"][pid, slot, 0]
    state["ring"] = state["ring"].at[pid, slot, 0].set(
        jnp.where(write, t, cur))
    return state


def emit_exit(state, pid: int, depth: int, spill: bool, src: CycleSource,
              sink: Optional[HostSink]):
    state, t = src.now(state)
    state = dict(state)
    calls = state["calls"][pid]
    state["ends"] = state["ends"].at[pid].set(t)
    state["totals"] = state["totals"].at[pid].set(
        c64_add(state["totals"][pid], c64_sub(t, state["last"][pid])))
    slot = (calls % depth) if spill else jnp.minimum(calls, depth - 1)
    write = True if spill else (calls < depth)
    cur = state["ring"][pid, slot, 1]
    state["ring"] = state["ring"].at[pid, slot, 1].set(
        jnp.where(write, t, cur))
    new_calls = calls + 1
    state["calls"] = state["calls"].at[pid].set(new_calls)
    if spill and sink is not None:
        should = (new_calls % depth) == 0
        jax.experimental.io_callback(
            functools.partial(sink.dump, pid), None,
            should, new_calls - depth, state["ring"][pid],
            ordered=True)
    return state


# --------------------------------------------------------- interpreter

class Instrumenter:
    def __init__(self, hierarchy: Hierarchy, assignment: ProbeAssignment,
                 cycle_source: str = "model",
                 sink: Optional[HostSink] = None,
                 layout: str = "packed"):
        if layout not in ("packed", "legacy"):
            raise ValueError(f"unknown probe-state layout {layout!r}")
        self.h = hierarchy
        self.asg = assignment
        self.src = CycleSource(cycle_source)
        self.sink = sink
        self.layout = layout
        # probed-ancestor chains per scope path, precomputed
        self._chain_cache: Dict[str, Tuple[int, ...]] = {}
        self._needs_thread_cache: Dict[int, bool] = {}
        # memoized instrumented sub-evaluators: identical sub-jaxprs
        # (e.g. N calls to one jitted transformer layer) are walked once
        # and re-bound per call site — see _call_sub
        self._sub_cache: Dict[Tuple[int, str], Tuple[Any, Any]] = {}
        self.sub_walks = 0          # distinct instrumented sub-traces
        self.sub_rebinds = 0        # cache hits (re-bound, not re-walked)

    # -- static helpers ------------------------------------------------
    def _chain(self, path: str) -> Tuple[int, ...]:
        """Probe ids active (outermost first) when executing at ``path``."""
        if path in self._chain_cache:
            return self._chain_cache[path]
        ids: List[int] = []
        segs = path.split("/") if path else []
        cur = ""
        for s in segs:
            cur = f"{cur}/{s}" if cur else s
            pid = self.asg.id_of(cur)
            if pid is not None:
                ids.append(pid)
        out = tuple(ids)
        self._chain_cache[path] = out
        return out

    def _transition(self, state, old_path: str, new_path: str):
        """Emit exits/enters for the probed-scope delta old -> new."""
        a, b = self._chain(old_path), self._chain(new_path)
        i = 0
        while i < len(a) and i < len(b) and a[i] == b[i]:
            i += 1
        if self.layout == "legacy":
            for pid in reversed(a[i:]):
                state = emit_exit(state, pid, self.asg.depth,
                                  self.asg.spill[pid], self.src, self.sink)
            for pid in b[i:]:
                state = emit_enter(state, pid, self.asg.depth,
                                   self.asg.spill[pid], self.src)
            return state
        exits, enters = tuple(reversed(a[i:])), tuple(b[i:])
        if not exits and not enters:
            return state
        state, th, tl = self.src.now_scalars(state)
        return emit_events(state, th, tl, exits, enters, self.asg.depth,
                           self.asg.spill, self.sink)

    def _enter1(self, state, pid: int):
        """Single probe enter (loop-body boundaries), either layout."""
        if self.layout == "legacy":
            return emit_enter(state, pid, self.asg.depth,
                              self.asg.spill[pid], self.src)
        state, th, tl = self.src.now_scalars(state)
        return emit_events(state, th, tl, (), (pid,), self.asg.depth,
                           self.asg.spill, self.sink)

    def _exit1(self, state, pid: int):
        """Single probe exit (loop-body boundaries), either layout."""
        if self.layout == "legacy":
            return emit_exit(state, pid, self.asg.depth,
                             self.asg.spill[pid], self.src, self.sink)
        state, th, tl = self.src.now_scalars(state)
        return emit_events(state, th, tl, (pid,), (), self.asg.depth,
                           self.asg.spill, self.sink)

    def _jaxpr_has_probes(self, jaxpr) -> bool:
        for eqn in jaxpr.eqns:
            # conservative across call sites: a body shared by several
            # sites is threaded everywhere if probed anywhere
            for info in self.h.infos_of(eqn):
                if self._chain(info.path):
                    return True
                if info.sub_path and (
                        self._chain(info.sub_path) or
                        self.asg.id_of(info.sub_path) is not None or
                        any(p.startswith(info.sub_path + "/")
                            for p in self.asg.paths)):
                    return True
            for sub in cm._sub_jaxprs(eqn):
                if self._jaxpr_has_probes(_as_jaxpr(sub)):
                    return True
        return False

    def _needs_threading(self, jaxpr) -> bool:
        key = id(jaxpr)
        if key not in self._needs_thread_cache:
            self._needs_thread_cache[key] = (
                self._jaxpr_has_probes(jaxpr) or
                cm.jaxpr_has_dynamic_cycles(jaxpr) or
                self.src.kind == "wallclock")
        return self._needs_thread_cache[key]

    # -- memoized sub-jaxpr instrumentation ----------------------------
    def _call_sub(self, sub, invals, state, entry_path: str):
        """Instrumented evaluation of a call primitive's sub-jaxpr,
        memoized on (sub-jaxpr identity, entry path).

        The first occurrence wraps the instrumented walk in ``jax.jit``
        and traces it; every later call site with the same sub-jaxpr
        (e.g. the N calls of one jitted transformer layer) re-binds the
        cached evaluator instead of re-walking the body — the software
        analogue of the paper's incremental synthesis, measured in
        bench_instrument.
        """
        key = (id(sub), entry_path)
        hit = self._sub_cache.get(key)
        if hit is None or hit[0] is not sub:
            jaxpr = _as_jaxpr(sub)
            consts = sub.consts if hasattr(sub, "consts") else []

            def run_sub(st, *flat):
                outs, st = self._eval(jaxpr, consts, list(flat), st,
                                      entry_path=entry_path)
                return tuple(outs), st

            hit = (sub, jax.jit(run_sub))
            self._sub_cache[key] = hit
            self.sub_walks += 1
        else:
            self.sub_rebinds += 1
        outs, state = hit[1](state, *invals)
        return list(outs), state

    # -- evaluation ----------------------------------------------------
    def run(self, closed_jaxpr, args, state):
        outs, state = self._eval(closed_jaxpr.jaxpr, closed_jaxpr.consts,
                                 args, state, entry_path="")
        return outs, state

    def _eval(self, jaxpr, consts, args, state, entry_path: str):
        env: Dict[Any, Any] = {}

        def read(v):
            if isinstance(v, core.Literal):
                return v.val
            return env[v]

        def write(v, val):
            env[v] = val

        list(map(write, jaxpr.constvars, consts))
        list(map(write, jaxpr.invars, args))

        cur_path = entry_path
        pending = 0          # statically accumulated cycles since last event

        def flush(state):
            nonlocal pending
            if pending:
                state = self.src.advance(state, pending)
                pending = 0
            return state

        for eqn in jaxpr.eqns:
            info = self.h.info_at(eqn, entry_path)
            path = info.path if info else cur_path
            if path != cur_path:
                state = flush(state)
                state = self._transition(state, cur_path, path)
                cur_path = path
            name = eqn.primitive.name
            invals = [read(v) for v in eqn.invars]
            if name == "scan":
                state = flush(state)    # in-loop timestamps must be current
                state, outs, pend = self._scan(eqn, invals, state, info)
                pending += pend
            elif name == "while":
                state = flush(state)
                state, outs = self._while(eqn, invals, state, info)
            elif name == "cond":
                state = flush(state)
                state, outs = self._cond(eqn, invals, state, info)
            elif (name == "pallas_call" and
                  kernelprobe.probed_kernel_path(self, eqn, info)):
                # descended kernel: grid-step counters merge into the
                # state on kernel exit (core.kernelprobe)
                state = flush(state)
                state, outs = kernelprobe.instrument_pallas(
                    self, eqn, invals, state, info, cur_path)
            elif name in ("pjit", "jit", "closed_call", "core_call",
                          "custom_jvp_call", "custom_vjp_call",
                          "custom_vjp_call_jaxpr", "remat", "remat2",
                          "checkpoint"):
                sub = next(iter(cm._sub_jaxprs(eqn)), None)
                if sub is None:
                    outs = eqn.primitive.bind(*invals, **eqn.params)
                    pending += cm.eqn_cost(eqn).cycles
                elif (name in ("pjit", "jit", "remat", "remat2",
                               "checkpoint") and
                      not self._needs_threading(_as_jaxpr(sub))):
                    # no probes, no dynamic cycles: bind the call as an
                    # untouched black box and fold its statically summed
                    # cycles into the pending segment (same rule as
                    # unprobed scans) — instrumented op count stays
                    # O(probes), not O(model). Only params-driven
                    # primitives qualify: closed_call/core_call and the
                    # custom_jvp/vjp variants cannot be rebound from
                    # their params, so they take the descend path below
                    outs = eqn.primitive.bind(*invals, **eqn.params)
                    pending += cm.static_eqn_cycles(eqn)
                else:
                    state = flush(state)
                    outs, state = self._call_sub(sub, invals, state,
                                                 cur_path)
            else:
                outs = eqn.primitive.bind(*invals, **eqn.params)
                if not isinstance(outs, (list, tuple)):
                    outs = [outs]
                pending += (info.cycles if info else
                            cm.eqn_cost(eqn).cycles)
            list(map(write, eqn.outvars, list(outs)))

        state = flush(state)
        state = self._transition(state, cur_path, entry_path)
        return [read(v) for v in jaxpr.outvars], state

    # -- control flow ---------------------------------------------------
    def _scan(self, eqn, invals, state, info):
        p = eqn.params
        body = p["jaxpr"]                       # ClosedJaxpr
        nc, ncar = p["num_consts"], p["num_carry"]
        length = int(p["length"])
        loop_path = info.sub_path
        loop_pid = self.asg.id_of(loop_path) if loop_path else None
        threaded = (self._needs_threading(body.jaxpr) or
                    loop_pid is not None)
        if not threaded:
            outs = eqn.primitive.bind(*invals, **eqn.params)
            pend = length * cm.static_jaxpr_cycles(body.jaxpr)
            return state, list(outs), pend

        consts = invals[:nc]
        carry0 = invals[nc:nc + ncar]
        xs = invals[nc + ncar:]

        def body_fn(carry_state, x):
            carry, st = carry_state
            if loop_pid is not None:
                st = self._enter1(st, loop_pid)
            outs, st = self._eval(body.jaxpr, body.consts,
                                  list(consts) + list(carry) + list(x),
                                  st, entry_path=loop_path or "")
            if loop_pid is not None:
                st = self._exit1(st, loop_pid)
            return (tuple(outs[:ncar]), st), tuple(outs[ncar:])

        (carry_f, state), ys = jax.lax.scan(
            body_fn, (tuple(carry0), state), tuple(xs),
            length=length, reverse=p["reverse"])
        return state, list(carry_f) + list(ys), 0

    def _while(self, eqn, invals, state, info):
        p = eqn.params
        cnc, bnc = p["cond_nconsts"], p["body_nconsts"]
        cond_j, body_j = p["cond_jaxpr"], p["body_jaxpr"]
        cconsts = invals[:cnc]
        bconsts = invals[cnc:cnc + bnc]
        carry0 = invals[cnc + bnc:]
        cond_cycles = cm.static_jaxpr_cycles(cond_j.jaxpr)
        loop_path = info.sub_path
        body_path = f"{loop_path}/body" if loop_path else ""
        loop_pid = self.asg.id_of(loop_path) if loop_path else None

        def cond_fn(carry_state):
            carry, _ = carry_state
            outs = _eval_jaxpr(cond_j.jaxpr, cond_j.consts,
                                   *(list(cconsts) + list(carry)))
            return outs[0]

        def body_fn(carry_state):
            carry, st = carry_state
            st = self.src.advance(st, cond_cycles)
            if loop_pid is not None:
                st = self._enter1(st, loop_pid)
            outs, st = self._eval(body_j.jaxpr, body_j.consts,
                                  list(bconsts) + list(carry),
                                  st, entry_path=body_path)
            if loop_pid is not None:
                st = self._exit1(st, loop_pid)
            return (tuple(outs), st)

        carry_f, state = jax.lax.while_loop(cond_fn, body_fn,
                                            (tuple(carry0), state))
        state = self.src.advance(state, cond_cycles)   # final failed check
        return state, list(carry_f)

    def _cond(self, eqn, invals, state, info):
        branches = eqn.params["branches"]
        index, *ops = invals
        cond_path = info.sub_path

        def mk(bi, br):
            def f(ops_state):
                ops_, st = ops_state
                outs, st = self._eval(
                    br.jaxpr, br.consts, list(ops_), st,
                    entry_path=f"{cond_path}/branch{bi}" if cond_path else "")
                return tuple(outs), st
            return f

        outs, state = jax.lax.switch(index,
                                     [mk(bi, br) for bi, br in
                                      enumerate(branches)],
                                     (tuple(ops), state))
        return state, list(outs)
