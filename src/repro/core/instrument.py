"""Non-intrusive instrumentation: a jaxpr interpreter that threads a
ProbeState through the user's program.

This is the RealProbe IP. The user function is traced once (by
``pragma.probe``); this module re-evaluates the jaxpr equation-by-
equation, and at **scope boundary transitions only** (the paper's
edge-triggered sampling) emits counter updates:

    enter(p):  starts[p] (first activation), last[p] = now, ring write
    exit(p):   ends[p] = now, totals[p] += now - last[p], ring write,
               calls[p] += 1, optional DRAM spill

Between events the global cycle counter advances by the *statically
summed* cost-model cycles of the executed segment — one fused add per
segment instead of one per equation (the analogue of the paper's
hierarchical read-mux optimization, quantified in bench_overhead).

Decoupling guarantees:
- instrumentation ops never read or write model tensors (only the state),
  so enabling probes cannot change model outputs (asserted in tests);
- scans whose bodies contain no probes / no dynamic control flow are left
  completely untouched (black-box bind + static cycle fold), keeping the
  instrumented HLO footprint O(probes), not O(model).

Control flow: scan bodies with probes get the state threaded through the
carry (per-iteration records, first-``depth`` iterations kept — the
paper's first-4-iterations truncation); while loops always thread state
(trip counts are runtime-only — the exact thing C-synth/Co-sim get
wrong); cond branches thread state so the *taken* branch's cycles are
counted.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.extend import core
from jax._src.core import eval_jaxpr as _eval_jaxpr

from repro.core import costmodel as cm
from repro.core import kernelprobe
from repro.core.buffer import HostSink
from repro.core.counters import (c64, c64_add, c64_add_int, c64_sub,
                                 c64_to_int, c64_zeros, U32)
from repro.core.hierarchy import Hierarchy

_as_jaxpr = cm._as_jaxpr


# --------------------------------------------------------- probe state

def init_state(n_probes: int, depth: int) -> Dict[str, jnp.ndarray]:
    return {
        "cycle": c64(0),
        "starts": c64_zeros((n_probes,)),
        "ends": c64_zeros((n_probes,)),
        "totals": c64_zeros((n_probes,)),
        "last": c64_zeros((n_probes,)),
        "calls": jnp.zeros((n_probes,), U32),
        "ring": jnp.zeros((n_probes, depth, 2, 2), U32),
    }


def decode_record(record: Dict[str, Any]) -> Dict[str, Any]:
    """Host-side view of a ProbeState / device record.

    Splits the (hi, lo) uint32 counter pairs into plain integers:
    ``cycle`` (int), ``starts``/``ends``/``totals`` (int64 arrays),
    ``calls`` (int64 array) and ``ring`` (int64, shape (n, depth, 2) of
    (start, end) pairs). The single place that knows the state layout —
    report building and streaming aggregation both go through it.
    """
    ring = np.asarray(record["ring"])
    return {
        "cycle": int(c64_to_int(np.asarray(record["cycle"]))),
        "starts": np.atleast_1d(c64_to_int(np.asarray(record["starts"]))),
        "ends": np.atleast_1d(c64_to_int(np.asarray(record["ends"]))),
        "totals": np.atleast_1d(c64_to_int(np.asarray(record["totals"]))),
        "calls": np.asarray(record["calls"]).astype(np.int64),
        "ring": np.stack([np.atleast_2d(c64_to_int(ring[:, :, 0])),
                          np.atleast_2d(c64_to_int(ring[:, :, 1]))],
                         axis=-1),
    }


@dataclass
class ProbeAssignment:
    paths: Tuple[str, ...]                 # probe id -> scope path
    depth: int                             # ring depth per probe
    spill: Tuple[bool, ...]                # probe id -> DRAM offload enabled

    @property
    def n(self) -> int:
        return len(self.paths)

    def id_of(self, path: str) -> Optional[int]:
        try:
            return self.paths.index(path)
        except ValueError:
            return None


class CycleSource:
    """Where 'now' comes from. ``model``: the deterministic cost-model
    clock (exact, CPU-validatable). ``wallclock``: host-time reads via
    ordered io_callback (captures real runtime dynamics)."""

    def __init__(self, kind: str):
        assert kind in ("model", "wallclock")
        self.kind = kind

    def advance(self, state, static_cycles: int):
        if static_cycles and self.kind == "model":
            state = dict(state)
            state["cycle"] = c64_add_int(state["cycle"], static_cycles)
        return state

    @staticmethod
    def _host_now():
        t = time.perf_counter_ns()
        return np.array([(t >> 32) & 0xFFFFFFFF, t & 0xFFFFFFFF], np.uint32)

    def now(self, state):
        if self.kind == "model":
            return state, state["cycle"]
        pair = jax.experimental.io_callback(
            self._host_now, jax.ShapeDtypeStruct((2,), jnp.uint32),
            ordered=True)
        state = dict(state)
        state["cycle"] = pair
        return state, pair


# ------------------------------------------------------ event emitters

def emit_enter(state, pid: int, depth: int, spill: bool, src: CycleSource):
    state, t = src.now(state)
    state = dict(state)
    calls = state["calls"][pid]
    first = (calls == 0)
    state["starts"] = state["starts"].at[pid].set(
        jnp.where(first, t, state["starts"][pid]))
    state["last"] = state["last"].at[pid].set(t)
    slot = (calls % depth) if spill else jnp.minimum(calls, depth - 1)
    write = True if spill else (calls < depth)
    cur = state["ring"][pid, slot, 0]
    state["ring"] = state["ring"].at[pid, slot, 0].set(
        jnp.where(write, t, cur))
    return state


def emit_exit(state, pid: int, depth: int, spill: bool, src: CycleSource,
              sink: Optional[HostSink]):
    state, t = src.now(state)
    state = dict(state)
    calls = state["calls"][pid]
    state["ends"] = state["ends"].at[pid].set(t)
    state["totals"] = state["totals"].at[pid].set(
        c64_add(state["totals"][pid], c64_sub(t, state["last"][pid])))
    slot = (calls % depth) if spill else jnp.minimum(calls, depth - 1)
    write = True if spill else (calls < depth)
    cur = state["ring"][pid, slot, 1]
    state["ring"] = state["ring"].at[pid, slot, 1].set(
        jnp.where(write, t, cur))
    new_calls = calls + 1
    state["calls"] = state["calls"].at[pid].set(new_calls)
    if spill and sink is not None:
        should = (new_calls % depth) == 0
        jax.experimental.io_callback(
            functools.partial(sink.dump, pid), None,
            should, new_calls - depth, state["ring"][pid],
            ordered=True)
    return state


# --------------------------------------------------------- interpreter

class Instrumenter:
    def __init__(self, hierarchy: Hierarchy, assignment: ProbeAssignment,
                 cycle_source: str = "model",
                 sink: Optional[HostSink] = None):
        self.h = hierarchy
        self.asg = assignment
        self.src = CycleSource(cycle_source)
        self.sink = sink
        # probed-ancestor chains per scope path, precomputed
        self._chain_cache: Dict[str, Tuple[int, ...]] = {}
        self._needs_thread_cache: Dict[int, bool] = {}

    # -- static helpers ------------------------------------------------
    def _chain(self, path: str) -> Tuple[int, ...]:
        """Probe ids active (outermost first) when executing at ``path``."""
        if path in self._chain_cache:
            return self._chain_cache[path]
        ids: List[int] = []
        segs = path.split("/") if path else []
        cur = ""
        for s in segs:
            cur = f"{cur}/{s}" if cur else s
            pid = self.asg.id_of(cur)
            if pid is not None:
                ids.append(pid)
        out = tuple(ids)
        self._chain_cache[path] = out
        return out

    def _transition(self, state, old_path: str, new_path: str):
        """Emit exits/enters for the probed-scope delta old -> new."""
        a, b = self._chain(old_path), self._chain(new_path)
        i = 0
        while i < len(a) and i < len(b) and a[i] == b[i]:
            i += 1
        for pid in reversed(a[i:]):
            state = emit_exit(state, pid, self.asg.depth,
                              self.asg.spill[pid], self.src, self.sink)
        for pid in b[i:]:
            state = emit_enter(state, pid, self.asg.depth,
                               self.asg.spill[pid], self.src)
        return state

    def _jaxpr_has_probes(self, jaxpr) -> bool:
        for eqn in jaxpr.eqns:
            info = self.h.eqn_info.get(id(eqn))
            if info is None:
                continue
            if self._chain(info.path):
                return True
            if info.sub_path and (self._chain(info.sub_path) or
                                  self.asg.id_of(info.sub_path) is not None or
                                  any(p.startswith(info.sub_path + "/")
                                      for p in self.asg.paths)):
                return True
            for sub in cm._sub_jaxprs(eqn):
                if self._jaxpr_has_probes(_as_jaxpr(sub)):
                    return True
        return False

    def _needs_threading(self, jaxpr) -> bool:
        key = id(jaxpr)
        if key not in self._needs_thread_cache:
            self._needs_thread_cache[key] = (
                self._jaxpr_has_probes(jaxpr) or
                cm.jaxpr_has_dynamic_cycles(jaxpr) or
                self.src.kind == "wallclock")
        return self._needs_thread_cache[key]

    # -- evaluation ----------------------------------------------------
    def run(self, closed_jaxpr, args, state):
        outs, state = self._eval(closed_jaxpr.jaxpr, closed_jaxpr.consts,
                                 args, state, entry_path="")
        return outs, state

    def _eval(self, jaxpr, consts, args, state, entry_path: str):
        env: Dict[Any, Any] = {}

        def read(v):
            if isinstance(v, core.Literal):
                return v.val
            return env[v]

        def write(v, val):
            env[v] = val

        list(map(write, jaxpr.constvars, consts))
        list(map(write, jaxpr.invars, args))

        cur_path = entry_path
        pending = 0          # statically accumulated cycles since last event

        def flush(state):
            nonlocal pending
            if pending:
                state = self.src.advance(state, pending)
                pending = 0
            return state

        for eqn in jaxpr.eqns:
            info = self.h.eqn_info.get(id(eqn))
            path = info.path if info else cur_path
            if path != cur_path:
                state = flush(state)
                state = self._transition(state, cur_path, path)
                cur_path = path
            name = eqn.primitive.name
            invals = [read(v) for v in eqn.invars]
            if name == "scan":
                state = flush(state)    # in-loop timestamps must be current
                state, outs, pend = self._scan(eqn, invals, state, info)
                pending += pend
            elif name == "while":
                state = flush(state)
                state, outs = self._while(eqn, invals, state, info)
            elif name == "cond":
                state = flush(state)
                state, outs = self._cond(eqn, invals, state, info)
            elif (name == "pallas_call" and
                  kernelprobe.probed_kernel_path(self, eqn, info)):
                # descended kernel: grid-step counters merge into the
                # state on kernel exit (core.kernelprobe)
                state = flush(state)
                state, outs = kernelprobe.instrument_pallas(
                    self, eqn, invals, state, info, cur_path)
            elif name in ("pjit", "jit", "closed_call", "core_call",
                          "custom_jvp_call", "custom_vjp_call",
                          "custom_vjp_call_jaxpr", "remat", "remat2",
                          "checkpoint"):
                sub = next(iter(cm._sub_jaxprs(eqn)), None)
                if sub is None:
                    outs = eqn.primitive.bind(*invals, **eqn.params)
                    pending += cm.eqn_cost(eqn).cycles
                else:
                    cj = sub if hasattr(sub, "consts") else None
                    state = flush(state)
                    outs, state = self._eval(
                        _as_jaxpr(sub), cj.consts if cj else [],
                        invals, state, entry_path=cur_path)
            else:
                outs = eqn.primitive.bind(*invals, **eqn.params)
                if not isinstance(outs, (list, tuple)):
                    outs = [outs]
                pending += (info.cycles if info else
                            cm.eqn_cost(eqn).cycles)
            list(map(write, eqn.outvars, list(outs)))

        state = flush(state)
        state = self._transition(state, cur_path, entry_path)
        return [read(v) for v in jaxpr.outvars], state

    # -- control flow ---------------------------------------------------
    def _scan(self, eqn, invals, state, info):
        p = eqn.params
        body = p["jaxpr"]                       # ClosedJaxpr
        nc, ncar = p["num_consts"], p["num_carry"]
        length = int(p["length"])
        loop_path = info.sub_path
        loop_pid = self.asg.id_of(loop_path) if loop_path else None
        threaded = (self._needs_threading(body.jaxpr) or
                    loop_pid is not None)
        if not threaded:
            outs = eqn.primitive.bind(*invals, **eqn.params)
            pend = length * cm.static_jaxpr_cycles(body.jaxpr)
            return state, list(outs), pend

        consts = invals[:nc]
        carry0 = invals[nc:nc + ncar]
        xs = invals[nc + ncar:]

        def body_fn(carry_state, x):
            carry, st = carry_state
            if loop_pid is not None:
                st = emit_enter(st, loop_pid, self.asg.depth,
                                self.asg.spill[loop_pid], self.src)
            outs, st = self._eval(body.jaxpr, body.consts,
                                  list(consts) + list(carry) + list(x),
                                  st, entry_path=loop_path or "")
            if loop_pid is not None:
                st = emit_exit(st, loop_pid, self.asg.depth,
                               self.asg.spill[loop_pid], self.src, self.sink)
            return (tuple(outs[:ncar]), st), tuple(outs[ncar:])

        (carry_f, state), ys = jax.lax.scan(
            body_fn, (tuple(carry0), state), tuple(xs),
            length=length, reverse=p["reverse"])
        return state, list(carry_f) + list(ys), 0

    def _while(self, eqn, invals, state, info):
        p = eqn.params
        cnc, bnc = p["cond_nconsts"], p["body_nconsts"]
        cond_j, body_j = p["cond_jaxpr"], p["body_jaxpr"]
        cconsts = invals[:cnc]
        bconsts = invals[cnc:cnc + bnc]
        carry0 = invals[cnc + bnc:]
        cond_cycles = cm.static_jaxpr_cycles(cond_j.jaxpr)
        loop_path = info.sub_path
        body_path = f"{loop_path}/body" if loop_path else ""
        loop_pid = self.asg.id_of(loop_path) if loop_path else None

        def cond_fn(carry_state):
            carry, _ = carry_state
            outs = _eval_jaxpr(cond_j.jaxpr, cond_j.consts,
                                   *(list(cconsts) + list(carry)))
            return outs[0]

        def body_fn(carry_state):
            carry, st = carry_state
            st = self.src.advance(st, cond_cycles)
            if loop_pid is not None:
                st = emit_enter(st, loop_pid, self.asg.depth,
                                self.asg.spill[loop_pid], self.src)
            outs, st = self._eval(body_j.jaxpr, body_j.consts,
                                  list(bconsts) + list(carry),
                                  st, entry_path=body_path)
            if loop_pid is not None:
                st = emit_exit(st, loop_pid, self.asg.depth,
                               self.asg.spill[loop_pid], self.src, self.sink)
            return (tuple(outs), st)

        carry_f, state = jax.lax.while_loop(cond_fn, body_fn,
                                            (tuple(carry0), state))
        state = self.src.advance(state, cond_cycles)   # final failed check
        return state, list(carry_f)

    def _cond(self, eqn, invals, state, info):
        branches = eqn.params["branches"]
        index, *ops = invals
        cond_path = info.sub_path

        def mk(bi, br):
            def f(ops_state):
                ops_, st = ops_state
                outs, st = self._eval(
                    br.jaxpr, br.consts, list(ops_), st,
                    entry_path=f"{cond_path}/branch{bi}" if cond_path else "")
                return tuple(outs), st
            return f

        outs, state = jax.lax.switch(index,
                                     [mk(bi, br) for bi, br in
                                      enumerate(branches)],
                                     (tuple(ops), state))
        return state, list(outs)
