"""Trace-once cycle simulator: capture a kernel's schedule ONCE, price
any candidate config in microseconds with no tracing and no device.

LightningSim and the Rapid Cycle-Accurate Simulator (PAPERS.md) both
split *trace capture* from *cycle evaluation* so new configurations
re-price without re-running the design. This module is that split for
the model-clock profiler: one :func:`capture` per (kernel, shape,
config) walks the traced jaxpr exactly once and stores everything the
cost model needs as a plain-data, JSON-serializable :class:`KernelTrace`
artifact; :func:`price` then replays the captured schedule arithmetic —
honoring the process-global ``set_kernel_calibration`` state and the
``collective_axis_sizes`` context *at pricing time* — without touching
jax at all.

Two pricing modes, matching the two live measurement paths:

``mode="sim"``
    The grid-replay clock: per ``pallas_call`` site, grid steps x block
    DMA plus the scalar-env-walked body cycles (``pl.when`` causal skips
    seen per tile). Integer-identical to the live kernel-probed replay
    (``ProbeConfig(kernel_probes=("*",))`` decode span) on every
    statically-gridded kernel — asserted across the golden kernels in
    ``tests/test_tracesim.py``.

``mode="flat"``
    The flat model clock: pallas sites priced by
    ``costmodel.flat_pallas_cycles`` (calibration-scaled body + DMA per
    step). Integer-identical to ``DSEEngine._measure``'s ProbeSession
    span/steps — which is exactly the quantity device measurement
    produces, so the sweep farm filters thousands of candidates on the
    same clock the finalists are measured on.

The walked body total is memoized over the grid axes the body actually
reads via ``program_id``: only their cartesian product is walked and the
result is multiplied by the unused axes' sizes, so capture stays cheap
even for large grids whose bodies only branch on one coordinate.

``TraceStore`` persists artifacts next to the :class:`EvalCache`
(``<cache>/traces/``), one JSON per (kernel, shape, space fingerprint)
— a kernel edit changes the fingerprint and naturally invalidates the
stale file — with the same :class:`~repro.core.incremental.FileLock`
read-merge-write discipline, so multi-process sweep workers can share
one store with zero lost entries.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import costmodel as cm
from repro.core import kernelprobe as kp
from repro.core.incremental import FileLock, fingerprint_closed

TRACE_VERSION = 1


# ----------------------------------------------------------- artifacts

@dataclass(frozen=True)
class KernelSite:
    """One ``pallas_call`` site in the captured schedule.

    ``count`` is the static execution multiplicity (outer scan trip
    counts multiplied through); ``walked`` is the scalar-env grid-walk
    body total over all grid steps (None when the grid is dynamic or
    the capture ran with ``walk=False``).
    """
    kernel: str
    grid: Optional[Tuple[int, ...]]
    steps: int                    # grid-step product (1 for dynamic grids)
    count: int
    dma: int                      # per-step HBM<->VMEM block DMA cycles
    body_static: int              # flat per-step body cycles, uncalibrated
    walked: Optional[int] = None

    def cycles(self, mode: str) -> int:
        if mode == "sim" and self.walked is not None:
            return self.count * (self.steps * self.dma + self.walked)
        return self.count * cm.flat_pallas_cycles(
            self.kernel, self.body_static, self.dma, self.steps)


@dataclass(frozen=True)
class CollectiveSite:
    """One collective eqn, decomposed so the ring wire model can
    re-price it for a different mesh (``collective_axis_sizes``) without
    the original eqn."""
    prim: str
    axes: Tuple[str, ...]
    count: int
    flops: int
    in_bytes: int
    out_bytes: int

    def cycles(self) -> int:
        return self.count * cm.collective_cycles(
            self.prim, self.axes, flops=self.flops,
            in_bytes=self.in_bytes, out_bytes=self.out_bytes)


@dataclass
class TraceEntry:
    """The captured schedule of ONE (config, shape) candidate: a flat
    base-cycle term for everything the cost model prices statically,
    plus decomposed pallas and collective sites that re-price against
    the calibration / mesh context current at :func:`price` time."""
    config: Dict[str, Any]
    fingerprint: str              # lowered-IR hash (EvalCache key scheme)
    base_cycles: int
    sites: List[KernelSite] = field(default_factory=list)
    collectives: List[CollectiveSite] = field(default_factory=list)
    exact: bool = True            # sim price == live replay guaranteed?
    walked: bool = True           # sites carry grid-walk totals?
    vmem_bytes: int = 0
    hbm_bytes: int = 0
    flops: int = 0
    grid_steps: int = 0


@dataclass
class KernelTrace:
    """All captured entries for one (kernel, shape), keyed by canonical
    config JSON. ``space_fingerprint`` is the default config's lowered-
    IR hash: any edit to the kernel source changes it, so a persisted
    trace can never silently price a stale schedule."""
    kernel_id: str
    shape: str
    space_fingerprint: str = ""
    entries: Dict[str, TraceEntry] = field(default_factory=dict)
    version: int = TRACE_VERSION


def config_key(config: Dict[str, Any]) -> str:
    return json.dumps(config, sort_keys=True, separators=(",", ":"))


def shape_signature(args: Sequence[Any]) -> str:
    """Canonical (shape, dtype) signature of example inputs."""
    import jax
    leaves = [[list(getattr(a, "shape", ())), str(getattr(a, "dtype", "?"))]
              for a in jax.tree_util.tree_leaves(args)]
    return json.dumps(leaves, separators=(",", ":"))


# ------------------------------------------------------------- capture

class _NullHierarchy:
    """No-op site table: ``walk_step`` then prices every eqn with the
    cost-model fallback — the same values extraction would register."""

    def info_at(self, eqn, entry_path):
        return None


class _SumOps(kp._WalkOps):
    """Concrete walk accumulator: plain-integer clock, no probe state."""

    def __init__(self):
        self.total = 0

    def select(self, i, opts: Sequence[int]):
        return opts[int(np.clip(int(np.asarray(i)), 0, len(opts) - 1))]

    def advance(self, v) -> None:
        self.total += int(v)

    def transition(self, a, b) -> None:
        pass


def _program_id_axes(jaxpr, acc: Optional[set] = None) -> set:
    """Grid axes the body actually reads (recursively) via
    ``program_id`` — the only step-dependent walk inputs."""
    acc = set() if acc is None else acc
    for e in jaxpr.eqns:
        if e.primitive.name == "program_id":
            acc.add(int(e.params["axis"]))
        for s in cm._sub_jaxprs(e):
            _program_id_axes(cm._as_jaxpr(s), acc)
    return acc


def _walked_total(eqn) -> Optional[int]:
    """Scalar-env walk total over ALL grid steps of one pallas site,
    enumerating only the axes the body reads (times the unused axes'
    sizes)."""
    grid = kp.static_grid(eqn)
    if grid is None:
        return None
    body = cm._as_jaxpr(eqn.params["jaxpr"])
    used = sorted(a for a in _program_id_axes(body) if a < len(grid))
    # sequential-step strides, last axis fastest (pallas iteration order)
    strides = [1] * len(grid)
    for i in range(len(grid) - 2, -1, -1):
        strides[i] = strides[i + 1] * grid[i + 1]
    unused_mult = 1
    for a in range(len(grid)):
        if a not in used:
            unused_mult *= grid[a]
    h = _NullHierarchy()
    total = 0
    for combo in itertools.product(*(range(grid[a]) for a in used)):
        it = sum(idx * strides[a] for a, idx in zip(used, combo))
        ops = _SumOps()
        kp.walk_step(h, body, grid, it, ops, "")
        total += ops.total
    return total * unused_mult


def _capture_jaxpr(jaxpr, mult: int, entry: TraceEntry, walk: bool) -> None:
    for e in jaxpr.eqns:
        name = e.primitive.name
        if name == "pallas_call":
            try:
                body = cm._as_jaxpr(e.params["jaxpr"])
                grid = kp.static_grid(e)
                site = KernelSite(
                    kernel=cm.pallas_kernel_name(e), grid=grid,
                    steps=cm._pallas_grid_steps(e), count=mult,
                    dma=cm.pallas_dma_cycles(e),
                    body_static=cm.static_jaxpr_cycles(body),
                    walked=(_walked_total(e)
                            if walk and grid is not None else None))
            except (KeyError, AttributeError, TypeError):
                # unknown pallas param layout: flat generic fallback,
                # exactly like eqn_cost
                entry.base_cycles += mult * cm.eqn_cost(e).cycles
                entry.exact = False
                continue
            entry.sites.append(site)
            if grid is None or (walk and site.walked is None):
                entry.exact = False
            continue
        if name == "scan":
            _capture_jaxpr(cm._as_jaxpr(e.params["jaxpr"]),
                           mult * int(e.params["length"]), entry, walk)
            continue
        if name in ("while", "cond"):
            # data-dependent control flow: statically priced, like the
            # static estimate — runtime counters alone know the truth
            entry.base_cycles += mult * cm.static_eqn_cycles(e)
            entry.exact = False
            continue
        if name in cm._COLLECTIVES:
            in_b = sum(cm._aval_bytes(v.aval) for v in e.invars
                       if hasattr(v, "aval"))
            out_b = sum(cm._aval_bytes(v.aval) for v in e.outvars)
            entry.collectives.append(CollectiveSite(
                prim=name, axes=cm.collective_eqn_axes(e), count=mult,
                flops=cm._aval_size(e.outvars[0].aval) if e.outvars else 0,
                in_bytes=in_b, out_bytes=out_b))
            continue
        sub = next(iter(cm._sub_jaxprs(e)), None)
        if name in cm._SUBJAXPR_PRIMS and sub is not None:
            _capture_jaxpr(cm._as_jaxpr(sub), mult, entry, walk)
            continue
        entry.base_cycles += mult * cm.eqn_cost(e).cycles


def capture_closed(closed, *, config: Optional[Dict[str, Any]] = None,
                   walk: bool = True) -> TraceEntry:
    """Capture a trace entry from an already-traced closed jaxpr."""
    entry = TraceEntry(config=dict(config or {}),
                       fingerprint=fingerprint_closed(closed),
                       base_cycles=0, walked=walk)
    _capture_jaxpr(closed.jaxpr, 1, entry, walk)
    res = cm.jaxpr_kernel_resources(closed.jaxpr)
    entry.vmem_bytes = res.vmem_bytes
    entry.hbm_bytes = res.hbm_bytes
    entry.flops = res.flops
    entry.grid_steps = res.grid_steps
    return entry


def capture_entry(space, config: Dict[str, Any], *,
                  walk: bool = True) -> TraceEntry:
    """Trace ONE candidate of a ``SearchSpace`` and capture its
    schedule (the only step that runs jax; everything downstream is
    plain arithmetic)."""
    import jax
    closed = jax.make_jaxpr(space.bind(config))(*space.args)
    return capture_closed(closed, config=config, walk=walk)


def capture(space, configs: Optional[Sequence[Dict[str, Any]]] = None, *,
            walk: bool = True,
            space_fingerprint: str = "") -> KernelTrace:
    """Capture a :class:`KernelTrace` over ``configs`` (default: every
    valid candidate of the space)."""
    trace = KernelTrace(kernel_id=space.kernel_id,
                        shape=shape_signature(space.args),
                        space_fingerprint=space_fingerprint)
    for cfg in (space.candidates() if configs is None else configs):
        trace.entries[config_key(cfg)] = capture_entry(space, cfg, walk=walk)
    return trace


def space_fingerprint(space) -> str:
    """Lowered-IR hash of the space's DEFAULT config — the staleness
    key for persisted traces (any kernel-source edit changes it)."""
    import jax
    closed = jax.make_jaxpr(space.bind(space.default))(*space.args)
    return fingerprint_closed(closed)


# ------------------------------------------------------------- pricing

def price(trace: Union[KernelTrace, TraceEntry],
          config: Optional[Dict[str, Any]] = None, *,
          mode: str = "sim") -> int:
    """Cycles of one captured candidate — pure arithmetic, re-evaluated
    against the CURRENT ``kernel_calibration`` state (flat site term)
    and ``collective_axis_sizes`` context. See the module docstring for
    the two modes."""
    if mode not in ("sim", "flat"):
        raise ValueError(f"price mode must be 'sim' or 'flat', got {mode!r}")
    if isinstance(trace, KernelTrace):
        if config is None:
            raise ValueError("price(trace, config): config required when "
                             "pricing a KernelTrace")
        key = config_key(config)
        entry = trace.entries.get(key)
        if entry is None:
            raise KeyError(
                f"config {key} not captured in trace of "
                f"{trace.kernel_id} ({len(trace.entries)} entries)")
    else:
        entry = trace
    total = entry.base_cycles
    for s in entry.sites:
        total += s.cycles(mode)
    for c in entry.collectives:
        total += c.cycles()
    return int(total)


def entry_resources(entry: TraceEntry) -> cm.KernelResources:
    """The candidate's static footprint for ``DeviceBudget`` pruning,
    rebuilt from the artifact (``static_cycles`` is the pallas-site
    flat term under the current calibration, mirroring
    ``jaxpr_kernel_resources``)."""
    static = sum(s.count * cm.flat_pallas_cycles(
        s.kernel, s.body_static, s.dma, s.steps) for s in entry.sites)
    return cm.KernelResources(
        vmem_bytes=entry.vmem_bytes, hbm_bytes=entry.hbm_bytes,
        flops=entry.flops, grid_steps=entry.grid_steps,
        static_cycles=static)


# ------------------------------------------------------- serialization

def entry_to_dict(e: TraceEntry) -> Dict[str, Any]:
    return {
        "config": e.config, "fingerprint": e.fingerprint,
        "base_cycles": e.base_cycles, "exact": e.exact, "walked": e.walked,
        "vmem_bytes": e.vmem_bytes, "hbm_bytes": e.hbm_bytes,
        "flops": e.flops, "grid_steps": e.grid_steps,
        "sites": [{"kernel": s.kernel,
                   "grid": list(s.grid) if s.grid is not None else None,
                   "steps": s.steps, "count": s.count, "dma": s.dma,
                   "body_static": s.body_static, "walked": s.walked}
                  for s in e.sites],
        "collectives": [{"prim": c.prim, "axes": list(c.axes),
                         "count": c.count, "flops": c.flops,
                         "in_bytes": c.in_bytes, "out_bytes": c.out_bytes}
                        for c in e.collectives],
    }


def entry_from_dict(d: Dict[str, Any]) -> TraceEntry:
    return TraceEntry(
        config=dict(d["config"]), fingerprint=d["fingerprint"],
        base_cycles=int(d["base_cycles"]), exact=bool(d["exact"]),
        walked=bool(d["walked"]), vmem_bytes=int(d["vmem_bytes"]),
        hbm_bytes=int(d["hbm_bytes"]), flops=int(d["flops"]),
        grid_steps=int(d["grid_steps"]),
        sites=[KernelSite(
            kernel=s["kernel"],
            grid=tuple(s["grid"]) if s["grid"] is not None else None,
            steps=int(s["steps"]), count=int(s["count"]), dma=int(s["dma"]),
            body_static=int(s["body_static"]),
            walked=int(s["walked"]) if s["walked"] is not None else None)
            for s in d["sites"]],
        collectives=[CollectiveSite(
            prim=c["prim"], axes=tuple(c["axes"]), count=int(c["count"]),
            flops=int(c["flops"]), in_bytes=int(c["in_bytes"]),
            out_bytes=int(c["out_bytes"])) for c in d["collectives"]])


def to_dict(trace: KernelTrace) -> Dict[str, Any]:
    return {"kernel": trace.kernel_id, "shape": trace.shape,
            "space_fingerprint": trace.space_fingerprint,
            "version": trace.version,
            "entries": {k: entry_to_dict(e)
                        for k, e in sorted(trace.entries.items())}}


def from_dict(d: Dict[str, Any]) -> KernelTrace:
    return KernelTrace(
        kernel_id=d["kernel"], shape=d["shape"],
        space_fingerprint=d.get("space_fingerprint", ""),
        version=int(d.get("version", TRACE_VERSION)),
        entries={k: entry_from_dict(v) for k, v in d["entries"].items()})


def to_json(trace: KernelTrace) -> str:
    """Canonical JSON: sorted keys, fixed separators — byte-identical
    across round-trips, so artifacts diff and hash cleanly."""
    return json.dumps(to_dict(trace), sort_keys=True,
                      separators=(",", ":"))


def from_json(s: str) -> KernelTrace:
    return from_dict(json.loads(s))


# ------------------------------------------------------------ on-disk

class TraceStore:
    """Shared on-disk store of trace artifacts, colocated with the
    ``EvalCache`` root. One JSON file per (kernel, shape, space
    fingerprint); concurrent ``merge`` calls are read-merge-write under
    a :class:`FileLock`, entry-wise, so parallel capture workers never
    drop each other's entries."""

    def __init__(self, root: str):
        self.root = os.path.join(os.path.expanduser(root), "traces")

    def path_for(self, kernel_id: str, shape: str,
                 space_fingerprint: str = "") -> str:
        blob = f"{kernel_id}|{shape}|{space_fingerprint}|v{TRACE_VERSION}"
        h = hashlib.sha256(blob.encode()).hexdigest()[:16]
        return os.path.join(self.root, f"{kernel_id}__{h}.json")

    def load(self, kernel_id: str, shape: str,
             space_fingerprint: str = "") -> Optional[KernelTrace]:
        path = self.path_for(kernel_id, shape, space_fingerprint)
        try:
            with open(path) as f:
                return from_dict(json.load(f))
        except (OSError, ValueError, KeyError):
            return None

    def merge(self, trace: KernelTrace) -> KernelTrace:
        """Merge ``trace``'s entries into the stored artifact (new
        entries win per config key); returns the merged trace."""
        path = self.path_for(trace.kernel_id, trace.shape,
                             trace.space_fingerprint)
        os.makedirs(self.root, exist_ok=True)
        with FileLock(path + ".lock"):
            try:
                with open(path) as f:
                    merged = from_dict(json.load(f))
            except (OSError, ValueError, KeyError):
                merged = KernelTrace(
                    kernel_id=trace.kernel_id, shape=trace.shape,
                    space_fingerprint=trace.space_fingerprint)
            merged.entries.update(trace.entries)
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                f.write(to_json(merged))
            os.replace(tmp, path)
        return merged
