"""Mesh-aware probing: per-device cycle records for sharded programs.

``probe()`` observes ONE device. Production workloads run SPMD over a
``Mesh`` — and a hierarchy profile is only trustworthy when *every*
parallel instance is observed (a straggler device is invisible in a
single-device record, and communication time is invisible in a
compute-only cost model). This module extends the RealProbe pipeline to
``shard_map``-style sharded programs:

- ``mesh_probe(fn, mesh, in_specs, out_specs)`` instruments the
  *per-shard* body once (one trace, zero retraces afterwards) and runs
  the instrumented evaluator under ``shard_map``, with the whole
  ``ProbeState`` carried as a **device-sharded buffer**: every state
  leaf grows a leading device axis sharded over all mesh axes, so row
  ``d`` holds the counters of the device at mesh coordinate
  ``unravel_index(d, mesh_shape)``. Counters never touch model values,
  so outputs stay bit-identical with probing on or off — the same
  non-intrusiveness guarantee as the single-device path, now per shard.
- cycle counts use the deterministic model clock with the **collective
  term** enabled (``costmodel.collective_axis_sizes``): a ``psum`` over
  a G-device axis costs its ring-model wire bytes, so per-device cycles
  respond to the mesh shape.
- ``CycleRecord`` decodes the sharded state into per-device arrays with
  cross-device reductions (``max`` / ``mean`` / ``per-device``) and the
  straggler signal ``skew = max - min``.
- ``MeshProbedFunction.collectives()`` joins the probe hierarchy
  against the ring wire-byte model (``launch.collectives``), so reports
  split compute vs. communication per module.
- ``ShardOracle`` replays one shard with plain Python integer counters
  (collectives stubbed shape-faithfully, ``axis_index`` resolved from
  the replayed device's mesh coordinate); device rows must equal it
  EXACTLY — the paper's 100%-accuracy check, per device.
- ``MeshProbeSession`` keeps the sharded counters running across a
  serving/training loop (constant memory, no retrace), feeding
  per-window per-device cycle deltas into a device-major
  ``StreamAggregator``.

Shard spills (DRAM offload) are disabled under a mesh — host callbacks
from inside ``shard_map`` are not portable — so per-call history is
limited to each probe's ring depth; the counters themselves stay exact.
Only ``cycle_source="model"`` is supported (wallclock needs the same
callbacks).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import costmodel as cm
from repro.core import report as report_mod
from repro.core.hierarchy import Hierarchy, extract
from repro.core.instrument import (Instrumenter, ProbeAssignment,
                                   decode_record, init_state)
from repro.core.oracle import Oracle, OracleCounters
from repro.core.pragma import ProbeConfig, _select_probes
from repro.core.streaming import StreamAggregator
from repro.distributed import compat
from repro.launch.collectives import (PRIMITIVE_KINDS, CollectiveSite,
                                      jaxpr_collectives)


def _is_spec_leaf(x) -> bool:
    return x is None or isinstance(x, P)


def _flat_specs(spec_tree, arg_tree, what: str) -> List[Optional[P]]:
    """Broadcast a (possibly prefix) spec pytree over ``arg_tree``,
    returning one spec per argument leaf — the shard_map convention."""
    treedef = jax.tree_util.tree_structure(spec_tree, is_leaf=_is_spec_leaf)
    leaves = jax.tree_util.tree_leaves(spec_tree, is_leaf=_is_spec_leaf)
    try:
        subtrees = treedef.flatten_up_to(arg_tree)
    except ValueError as e:
        raise ValueError(f"{what} is not a prefix of the argument "
                         f"structure: {e}") from None
    out: List[Optional[P]] = []
    for spec, sub in zip(leaves, subtrees):
        out.extend([spec] * len(jax.tree_util.tree_leaves(sub)))
    return out


def _spec_axes(spec: Optional[P], ndim: int) -> Tuple[Tuple[str, ...], ...]:
    """Per-dimension mesh axes of a PartitionSpec, padded to ``ndim``."""
    entries = tuple(spec) if spec is not None else ()
    out = []
    for i in range(ndim):
        e = entries[i] if i < len(entries) else None
        if e is None:
            out.append(())
        elif isinstance(e, str):
            out.append((e,))
        else:
            out.append(tuple(e))
    return tuple(out)


def _shard_shape(shape: Tuple[int, ...], spec: Optional[P],
                 sizes: Dict[str, int]) -> Tuple[int, ...]:
    out = []
    for dim, axes in zip(shape, _spec_axes(spec, len(shape))):
        k = 1
        for a in axes:
            k *= int(sizes.get(a, 1))
        if k > 1 and dim % k != 0:
            raise ValueError(f"dimension {dim} not divisible by mesh axes "
                             f"{axes} (size {k}) — spec {spec} on {shape}")
        out.append(dim // k)
    return tuple(out)


def _shard_slice(x, spec: Optional[P], sizes: Dict[str, int],
                 coords: Dict[str, int]):
    """The shard of global array ``x`` owned by the device at ``coords``."""
    x = np.asarray(x)
    idx: List[slice] = []
    for dim, axes in zip(x.shape, _spec_axes(spec, x.ndim)):
        k = 1
        block = 0
        for a in axes:
            k *= int(sizes.get(a, 1))
            block = block * int(sizes.get(a, 1)) + int(coords.get(a, 0))
        bs = dim // max(k, 1)
        idx.append(slice(block * bs, (block + 1) * bs))
    return x[tuple(idx)]


# ------------------------------------------------------- decoded record

@dataclass
class CycleRecord:
    """Per-device decoded counter state of one mesh-probed program.

    Row ``d`` of every array belongs to the device at mesh coordinate
    ``np.unravel_index(d, mesh_shape)`` (mesh axes in order) — the
    device-sharded counter buffer, brought to the host.
    """
    mesh_axes: Tuple[str, ...]
    mesh_shape: Tuple[int, ...]
    paths: Tuple[str, ...]
    cycle: np.ndarray             # (D,)      global span per device
    starts: np.ndarray            # (D, n)
    ends: np.ndarray              # (D, n)
    totals: np.ndarray            # (D, n)
    calls: np.ndarray             # (D, n)
    ring: np.ndarray              # (D, n, depth, 2)

    REDUCTIONS = ("per-device", "max", "mean")

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.mesh_shape))

    def coords(self, device: int) -> Tuple[int, ...]:
        return tuple(int(c) for c in
                     np.unravel_index(device, self.mesh_shape))

    def device(self, device: int) -> Dict[str, Any]:
        """Single-device view, shaped like ``decode_record``'s output."""
        return {"cycle": int(self.cycle[device]),
                "starts": self.starts[device], "ends": self.ends[device],
                "totals": self.totals[device], "calls": self.calls[device],
                "ring": self.ring[device]}

    def reduce(self, mode: str = "max") -> np.ndarray:
        """Cross-device reduction of per-probe total cycles."""
        if mode == "per-device":
            return self.totals
        if mode == "max":
            return self.totals.max(axis=0)
        if mode == "mean":
            return self.totals.mean(axis=0)
        raise ValueError(f"unknown reduction {mode!r}; "
                         f"expected one of {self.REDUCTIONS}")

    def skew(self) -> np.ndarray:
        """Per-probe max−min total cycles across devices — the
        straggler signal (0 everywhere = perfectly balanced)."""
        return self.totals.max(axis=0) - self.totals.min(axis=0)

    def straggler(self) -> Tuple[int, str]:
        """(device, probe path) of the worst cell by total cycles.
        ``(0, "")`` when no probes were selected."""
        if self.totals.size == 0:
            return 0, ""
        d, p = np.unravel_index(int(self.totals.argmax()),
                                self.totals.shape)
        return int(d), self.paths[int(p)]

    def row(self, path: str, device: Optional[int] = None):
        pid = self.paths.index(path)
        col = self.totals[:, pid]
        return col if device is None else int(col[device])


def decode_mesh_record(state: Dict[str, Any], mesh_axes: Sequence[str],
                       mesh_shape: Sequence[int],
                       paths: Sequence[str]) -> CycleRecord:
    """Decode a device-sharded ProbeState (leading device axis) into a
    host-side :class:`CycleRecord`. Goes through ``decode_record`` row
    by row — the single place that knows the counter layout."""
    state = jax.device_get(state)
    n_dev = int(np.prod(tuple(mesh_shape)))
    per_dev = [decode_record({k: np.asarray(v)[d] for k, v in state.items()})
               for d in range(n_dev)]
    return CycleRecord(
        mesh_axes=tuple(mesh_axes), mesh_shape=tuple(mesh_shape),
        paths=tuple(paths),
        cycle=np.array([r["cycle"] for r in per_dev], np.int64),
        starts=np.stack([r["starts"] for r in per_dev]),
        ends=np.stack([r["ends"] for r in per_dev]),
        totals=np.stack([r["totals"] for r in per_dev]),
        calls=np.stack([r["calls"] for r in per_dev]),
        ring=np.stack([r["ring"] for r in per_dev]))


# ------------------------------------------------------- shard oracle

class ShardOracle(Oracle):
    """Replay ONE device's shard with Python integer counters.

    Collectives cannot execute outside their mesh, so they are stubbed
    shape-faithfully: shape-preserving ones (psum/pmax/pmin/ppermute)
    pass their operands through, the rest return zeros of the output
    aval, and ``axis_index`` resolves to the replayed device's mesh
    coordinate. Cycle advances always use the hierarchy's precomputed
    per-eqn costs, so the replayed counters are exact as long as control
    flow does not branch on collective *values*.
    """

    _PASSTHROUGH = {"psum", "pmax", "pmin", "ppermute", "pbroadcast"}

    def __init__(self, hierarchy: Hierarchy, assignment: ProbeAssignment,
                 coords: Dict[str, int]):
        super().__init__(hierarchy, assignment)
        self.coords = dict(coords)

    def _bind(self, eqn, invals):
        name = eqn.primitive.name
        if name == "axis_index":
            axis = eqn.params.get("axis_name")
            return [np.int32(self.coords.get(str(axis), 0))]
        if name in self._PASSTHROUGH:
            return list(invals)
        if name in PRIMITIVE_KINDS:
            return [np.zeros(v.aval.shape, v.aval.dtype)
                    for v in eqn.outvars]
        return super()._bind(eqn, invals)


# ------------------------------------------------- mesh-probed function

class MeshProbedFunction:
    """Instrumented wrapper around a per-shard (shard_map-style) body.

    Mirrors ``ProbedFunction``'s surface — ``__call__`` returns
    ``(outputs, sharded_state)``, ``stateful_call`` threads the caller's
    state, ``report``/``oracle`` verify — but every counter exists once
    per device. Positional arguments only (the shard_map convention).
    """

    def __init__(self, fn: Callable, mesh, in_specs, out_specs,
                 config: ProbeConfig = ProbeConfig(), *,
                 check_specs: bool = False):
        if config.cycle_source != "model":
            raise ValueError("mesh_probe supports cycle_source='model' only "
                             "(wallclock needs host callbacks, which cannot "
                             "cross shard_map)")
        if config.offload:
            config = config.replace(offload=0.0)   # no host spill in-mesh
        # shard_map's replication check. Off by default: probe workloads
        # legitimately return device-varying values (skew demos, per-
        # device loop counts) under replicated out_specs. Turn it on to
        # have misdeclared out_specs diagnosed at trace time instead of
        # silently yielding one device's value.
        self.check_specs = bool(check_specs)
        self.fn = fn
        self.mesh = mesh
        self.config = config
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.mesh_axes: Tuple[str, ...] = tuple(mesh.axis_names)
        self.axis_sizes: Dict[str, int] = {a: int(s) for a, s in
                                           dict(mesh.shape).items()}
        self.mesh_shape: Tuple[int, ...] = tuple(self.axis_sizes[a]
                                                 for a in self.mesh_axes)
        self.n_devices = int(np.prod(self.mesh_shape))
        self._hierarchy: Optional[Hierarchy] = None
        self._trace_key = None
        self._assignment: Optional[ProbeAssignment] = None
        self._closed = None
        self._out_tree = None
        self._flat_in_specs: Optional[List[Optional[P]]] = None
        self._flat_out_specs: Optional[List[Optional[P]]] = None
        self._jitted = None
        self._jitted_stateful = None
        self.timings: Dict[str, float] = {}

    # -- stage 2: per-shard trace + extraction --------------------------
    def trace(self, *args) -> Hierarchy:
        flat, in_tree = jax.tree_util.tree_flatten(args)
        key = (in_tree, tuple((a.shape, str(a.dtype)) for a in flat
                              if hasattr(a, "shape")))
        if self._hierarchy is not None and key == self._trace_key:
            return self._hierarchy
        t0 = time.perf_counter()
        self._flat_in_specs = _flat_specs(self.in_specs, args, "in_specs")
        shard_avals = [jax.ShapeDtypeStruct(
            _shard_shape(tuple(np.shape(a)), s, self.axis_sizes),
            jnp.result_type(a))
            for a, s in zip(flat, self._flat_in_specs)]
        store: Dict[str, Any] = {}

        def flat_fn(*flat_args):
            out = self.fn(*jax.tree_util.tree_unflatten(in_tree, flat_args))
            flat_out, out_tree = jax.tree_util.tree_flatten(out)
            store["out_tree"] = out_tree
            return flat_out

        with compat.extend_axis_env(self.axis_sizes), \
                cm.collective_axis_sizes(self.axis_sizes):
            self._closed = jax.make_jaxpr(flat_fn)(*shard_avals)
            t1 = time.perf_counter()
            self._hierarchy = extract(
                self._closed,
                kernel_probes=tuple(self.config.kernel_probes))
        self._out_tree = store["out_tree"]
        out_template = jax.tree_util.tree_unflatten(
            self._out_tree, [v.aval for v in self._closed.jaxpr.outvars])
        self._flat_out_specs = _flat_specs(self.out_specs, out_template,
                                           "out_specs")
        self._in_tree = in_tree
        self._trace_key = key
        self._jitted = None
        self.timings["trace_s"] = t1 - t0
        self.timings["extract_s"] = time.perf_counter() - t1
        return self._hierarchy

    @property
    def hierarchy(self) -> Hierarchy:
        if self._hierarchy is None:
            raise RuntimeError("call .trace(*args) or the function first")
        return self._hierarchy

    # -- stage 3: IP generation under shard_map -------------------------
    def _build(self, *args):
        h = self.trace(*args)
        t0 = time.perf_counter()
        paths = _select_probes(h, self.config)
        self._assignment = ProbeAssignment(
            paths=paths, depth=self.config.buffer_depth,
            spill=(False,) * len(paths))
        interp = Instrumenter(h, self._assignment, cycle_source="model",
                              sink=None, layout=self.config.layout)
        state_specs = jax.tree_util.tree_map(
            lambda _: P(self.mesh_axes),
            init_state(self._assignment.n, self.config.buffer_depth,
                       layout=self.config.layout))
        axis_sizes = self.axis_sizes
        closed, out_tree = self._closed, self._out_tree

        def shard_body(state, *flat_args):
            st = {k: v[0] for k, v in state.items()}    # drop device dim
            with cm.collective_axis_sizes(axis_sizes):
                outs, st = interp.run(closed, list(flat_args), st)
            return tuple(outs), {k: v[None] for k, v in st.items()}

        sm = compat.shard_map(
            shard_body, mesh=self.mesh,
            in_specs=(state_specs,) + tuple(self._flat_in_specs),
            out_specs=(tuple(self._flat_out_specs), state_specs),
            check_vma=self.check_specs)

        def stateful(state, *flat_args):
            outs, state = sm(state, *flat_args)
            return jax.tree_util.tree_unflatten(out_tree, list(outs)), state

        def oneshot(*flat_args):
            return stateful(self._zero_state(), *flat_args)

        self._jitted_stateful = jax.jit(stateful)
        self._jitted = jax.jit(oneshot)
        self.timings["instrument_s"] = time.perf_counter() - t0

    def _zero_state(self):
        # placed with the session-steady sharding (leading device axis
        # over the whole mesh) so the first stateful call compiles the
        # same specialization every later step reuses — zero retraces
        from jax.sharding import NamedSharding
        sh = NamedSharding(self.mesh, P(self.mesh_axes))
        base = init_state(self._assignment.n, self.config.buffer_depth,
                          layout=self.config.layout)
        return {k: jax.device_put(
                    jnp.zeros((self.n_devices,) + v.shape, v.dtype), sh)
                for k, v in base.items()}

    # -- public ----------------------------------------------------------
    def ensure_built(self, *args) -> "MeshProbedFunction":
        if self._jitted is None:
            self._build(*args)
        return self

    def __call__(self, *args):
        self.ensure_built(*args)
        return self._jitted(*jax.tree_util.tree_leaves(args))

    def init_state(self):
        """Fresh zeroed device-sharded counter state (one row/device)."""
        if self._assignment is None:
            raise RuntimeError("not built yet")
        return self._zero_state()

    def stateful_call(self, state, *args):
        """One step with caller-owned sharded counter state (the
        ``MeshProbeSession`` substrate; no retrace per step)."""
        self.ensure_built(*args)
        return self._jitted_stateful(state, *jax.tree_util.tree_leaves(args))

    def unprobed(self) -> Callable:
        """The reference executable: same shard_map, no instrumentation
        (for bit-identity checks and overhead measurement)."""
        def flat_fn(*flat_args):
            out = self.fn(*jax.tree_util.tree_unflatten(self._in_tree,
                                                        flat_args))
            return tuple(jax.tree_util.tree_leaves(out))
        sm = compat.shard_map(
            flat_fn, mesh=self.mesh, in_specs=tuple(self._flat_in_specs),
            out_specs=tuple(self._flat_out_specs),
            check_vma=self.check_specs)

        def run(*args):
            outs = sm(*jax.tree_util.tree_leaves(args))
            return jax.tree_util.tree_unflatten(self._out_tree, list(outs))
        return jax.jit(run)

    @property
    def assignment(self) -> ProbeAssignment:
        if self._assignment is None:
            raise RuntimeError("not built yet")
        return self._assignment

    def probe_paths(self) -> Tuple[str, ...]:
        return self.assignment.paths

    # -- verification / reporting ---------------------------------------
    def decode(self, state) -> CycleRecord:
        return decode_mesh_record(state, self.mesh_axes, self.mesh_shape,
                                  self.assignment.paths)

    def oracle(self, *args, device: int = 0) -> OracleCounters:
        """Independent per-shard replay for one device (the ILA check):
        slices each global argument to that device's shard and replays
        the per-shard jaxpr with its mesh coordinate bound."""
        self.ensure_built(*args)
        coords = dict(zip(self.mesh_axes,
                          np.unravel_index(device, self.mesh_shape)))
        flat = jax.tree_util.tree_leaves(args)
        shard_args = [_shard_slice(a, s, self.axis_sizes, coords)
                      for a, s in zip(flat, self._flat_in_specs)]
        with cm.collective_axis_sizes(self.axis_sizes):
            return ShardOracle(self.hierarchy, self._assignment,
                               coords).run(self._closed, shard_args)

    def collectives(self) -> List[CollectiveSite]:
        """Collective sites of the per-shard program, joined to scope
        paths (the hierarchy ↔ wire-byte model join)."""
        h = self.hierarchy
        eqn_paths = {eid: info.path for eid, info in h.eqn_info.items()}
        with cm.collective_axis_sizes(self.axis_sizes):
            return jaxpr_collectives(self._closed.jaxpr, self.axis_sizes,
                                     eqn_paths)

    def report(self, state) -> "MeshReport":
        rec = state if isinstance(state, CycleRecord) else self.decode(state)
        return MeshReport(record=rec, hierarchy=self.hierarchy,
                          comm=self.collectives())


def mesh_probe(fn: Callable, mesh, in_specs, out_specs,
               config: ProbeConfig = ProbeConfig(), *,
               check_specs: bool = False) -> MeshProbedFunction:
    """Single-directive activation for sharded programs (the pragma,
    per device): ``fn`` is the per-shard body you would hand to
    ``shard_map(fn, mesh, in_specs, out_specs)``. ``check_specs=True``
    turns shard_map's replication check on (both the probed and the
    ``unprobed()`` executable), diagnosing misdeclared ``out_specs`` at
    trace time."""
    return MeshProbedFunction(fn, mesh, in_specs, out_specs, config,
                              check_specs=check_specs)


# ------------------------------------------------------------- report

@dataclass
class MeshReport:
    """Per-device result view: device table, mesh heat map, reductions,
    and the compute-vs-communication split per module."""
    record: CycleRecord
    hierarchy: Hierarchy
    comm: List[CollectiveSite] = field(default_factory=list)

    def device_table(self) -> str:
        return report_mod.mesh_device_table(self.record)

    def heat(self, path: Optional[str] = None) -> str:
        return report_mod.mesh_heat(self.record, path)

    def comm_table(self) -> str:
        return report_mod.mesh_comm_table(self.record, self.hierarchy,
                                          self.comm)

    def reduce(self, mode: str = "max") -> np.ndarray:
        return self.record.reduce(mode)

    def skew(self) -> np.ndarray:
        return self.record.skew()


# ------------------------------------------------------------- session

@dataclass
class MeshSnapshot:
    """Point-in-time view of a live mesh session (constant-size)."""
    steps: int
    wall_s: float
    record: CycleRecord
    stats: StreamAggregator       # device-major rows: (device, probe)
    state_nbytes: int

    @property
    def span(self) -> int:
        """Worst-device cumulative cycle span since session start."""
        return int(self.record.cycle.max(initial=0))

    def table(self, reduce: str = "max") -> str:
        return report_mod.mesh_session_table(self, reduce=reduce)

    def device_table(self) -> str:
        return report_mod.mesh_device_table(self.record)

    def heat(self, path: Optional[str] = None) -> str:
        return report_mod.mesh_heat(self.record, path)

    def skew(self) -> np.ndarray:
        return self.record.skew()


class MeshProbeSession:
    """Continuous mesh-wide profiling over a sharded step function.

    The per-device counter state is threaded across steps on-device
    (``stateful_call`` — no retrace, totals accumulate per device); at
    window boundaries one host read folds the per-window per-device
    cycle deltas into a device-major :class:`StreamAggregator`, whose
    ``reduce``/``skew`` expose the cross-device modes. Memory is
    constant in step count.
    """

    def __init__(self, fn, mesh=None, in_specs=None, out_specs=None,
                 config: Optional[ProbeConfig] = None, *,
                 window_steps: int = 16, ema_alpha: float = 0.1,
                 bus=None, source: str = "mesh"):
        if isinstance(fn, MeshProbedFunction):
            self.mpf = fn
        else:
            if mesh is None:
                raise ValueError("MeshProbeSession(fn, mesh, in_specs, "
                                 "out_specs) needs a mesh for a plain fn")
            self.mpf = mesh_probe(fn, mesh, in_specs, out_specs,
                                  config or ProbeConfig())
        self.window_steps = int(window_steps)
        self.ema_alpha = float(ema_alpha)
        self.bus = bus
        self.source = source
        self._stream = None
        self.stats: Optional[StreamAggregator] = None
        self._state = None
        self._steps = 0
        self._closed = False
        self._t0 = 0.0
        self._prev_totals: Optional[np.ndarray] = None
        self._win_start = 0

    def __enter__(self) -> "MeshProbeSession":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    @property
    def steps(self) -> int:
        return self._steps

    @property
    def paths(self) -> Tuple[str, ...]:
        return self.mpf.assignment.paths

    @property
    def n_devices(self) -> int:
        return self.mpf.n_devices

    def step(self, *args):
        if self._closed:
            raise RuntimeError("session is closed")
        if self._state is None:
            self.mpf.ensure_built(*args)
            self._state = self.mpf.init_state()
            n = self.mpf.assignment.n
            # per-window per-device deltas publish through the bus
            # abstraction (device-major stream); `stats` stays the
            # stream's aggregator, as before the telemetry refactor
            from repro.telemetry.bus import ProbeStream
            paths = self.mpf.assignment.paths
            if self.bus is not None:
                self._stream = self.bus.stream(
                    self.source, paths, n_devices=self.mpf.n_devices,
                    ema_alpha=self.ema_alpha)
            else:
                self._stream = ProbeStream(
                    self.source, paths, n_devices=self.mpf.n_devices,
                    ema_alpha=self.ema_alpha)
            self.stats = self._stream.agg
            self._prev_totals = np.zeros(self.mpf.n_devices * n, np.int64)
            self._t0 = time.perf_counter()
        out, self._state = self.mpf.stateful_call(self._state, *args)
        self._steps += 1
        if self._steps - self._win_start >= self.window_steps:
            self._roll_window()
        return out

    def _read_totals(self) -> np.ndarray:
        from repro.core.counters import c64_to_int
        from repro.core.instrument import TOTALS
        st = jax.device_get(self._state)
        if "cnt" in st:                            # packed: (D, 3, n, 2)
            t = c64_to_int(np.asarray(st["cnt"])[:, TOTALS])
        else:
            t = c64_to_int(np.asarray(st["totals"]))
        return np.atleast_2d(t).reshape(-1)       # device-major (D*n,)

    def _roll_window(self):
        totals = self._read_totals()
        delta = totals - self._prev_totals
        for row in np.nonzero(delta)[0]:
            self._stream.add(int(row), np.array([delta[row]]))
        self._stream.roll(self._win_start, self._steps,
                          exact_totals=delta)
        self._prev_totals = totals
        self._win_start = self._steps

    def snapshot(self) -> MeshSnapshot:
        if self._state is None:
            raise RuntimeError("no steps executed yet")
        if self._steps > self._win_start:
            self._roll_window()                    # fold the partial window
        rec = self.mpf.decode(self._state)
        return MeshSnapshot(steps=self._steps,
                            wall_s=time.perf_counter() - self._t0,
                            record=rec, stats=self.stats.copy(),
                            state_nbytes=self.state_nbytes())

    def state_nbytes(self) -> int:
        host = self.stats.nbytes if self.stats is not None else 0
        if self._prev_totals is not None:
            host += self._prev_totals.nbytes
        from repro.core.buffer import state_bytes
        dev = (self.mpf.n_devices *
               state_bytes(self.mpf.assignment.n,
                           self.mpf.config.buffer_depth,
                           layout=self.mpf.config.layout)
               if self._state is not None else 0)
        return host + dev

    def close(self) -> Optional[MeshSnapshot]:
        if self._closed:
            return None
        snap = self.snapshot() if self._state is not None else None
        self._closed = True
        return snap
