"""64-bit performance counters built from uint32 pairs.

The paper's profiler IP builds a 32- or 64-bit global cycle counter out
of FPGA registers; we do the literal analogue — (hi, lo) uint32 pairs
with add-with-carry — so counter width never depends on the host's
``jax_enable_x64`` flag and the 2^64-cycle guarantee holds everywhere.

A counter value is an array whose trailing dimension is 2: ``[..., 0]`` =
hi word, ``[..., 1]`` = lo word.
"""
from __future__ import annotations

from typing import Tuple, Union

import jax.numpy as jnp
import numpy as np

U32 = jnp.uint32
MASK32 = (1 << 32) - 1


def c64(value: int = 0):
    """Scalar counter constant."""
    return jnp.array([(value >> 32) & MASK32, value & MASK32], U32)


def c64_zeros(shape) -> jnp.ndarray:
    return jnp.zeros(tuple(shape) + (2,), U32)


def c64_add(a, b):
    """a + b for counters with matching shape (..., 2)."""
    lo = a[..., 1] + b[..., 1]
    carry = (lo < a[..., 1]).astype(U32)
    hi = a[..., 0] + b[..., 0] + carry
    return jnp.stack([hi, lo], axis=-1)


def c64_add_int(a, value: int):
    """a + static python int (may exceed 2^32)."""
    return c64_add(a, jnp.broadcast_to(c64(value), a.shape))


def c64_add_u32(a, lo):
    """a + a traced uint32 scalar (kernel grid-step cycle merges —
    per-step costs always fit one word; the carry still propagates)."""
    lo32 = jnp.asarray(lo, U32)
    return c64_add(a, jnp.stack([jnp.zeros_like(lo32), lo32], axis=-1))


def c64_sub(a, b):
    """a - b (modular, like hardware counters)."""
    lo = a[..., 1] - b[..., 1]
    borrow = (a[..., 1] < b[..., 1]).astype(U32)
    hi = a[..., 0] - b[..., 0] - borrow
    return jnp.stack([hi, lo], axis=-1)


def c64_to_int(a) -> Union[int, np.ndarray]:
    """Host-side conversion to python int / int64 ndarray."""
    arr = np.asarray(a)
    out = (arr[..., 0].astype(np.uint64) << np.uint64(32)) | \
        arr[..., 1].astype(np.uint64)
    if out.ndim == 0:
        return int(out)
    return out.astype(np.int64)


def int_to_pair(value: int) -> Tuple[int, int]:
    return (value >> 32) & MASK32, value & MASK32
