"""Scope-hierarchy extraction from a traced jaxpr (C-to-RTL analogue).

The paper's modified Clang/LLVM flow maps RTL modules/loops back to C
functions; here ``jax.named_scope`` name-stacks play the role of module
boundaries and ``lax.scan``/``while`` equations the role of loops. The
extraction walks the closed jaxpr ONCE (the paper's "extraction is
performed only once") and produces:

- a ``ScopeNode`` tree (the RTL hierarchy tree of Fig 5),
- per-equation annotations (``EqnInfo``) that the instrumenter and the
  oracle replay so all three agree on paths,
- static cycle estimates per node (the "C-synth report" column),
- source locations (file:line) per scope — the mapping-table payload.

Transform wrappers in name stacks ('jvp(f)', 'transpose(jvp(f))') are
normalized: forward scopes keep their names, backward scopes get a
``~bwd`` suffix — so a probed training step shows forward and backward
costs of the same module as sibling nodes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core import costmodel as cm

_WRAP_RE = re.compile(r"^(\w+)\((.*)\)$")


def normalize_segment(seg: str) -> Tuple[Optional[str], bool]:
    """'transpose(jvp(layers))' -> ('layers', bwd=True); 'jvp()' -> (None, _)."""
    bwd = False
    while True:
        m = _WRAP_RE.match(seg)
        if not m:
            break
        wrapper, inner = m.group(1), m.group(2)
        if wrapper == "transpose":
            bwd = True
        seg = inner
    seg = seg.strip()
    return (seg if seg else None), bwd


def normalize_stack(stack_str: str) -> Tuple[str, ...]:
    """Full name-stack string -> tuple of scope segments."""
    if not stack_str:
        return ()
    segs: List[str] = []
    bwd_any = False
    for raw in stack_str.split("/"):
        name, bwd = normalize_segment(raw)
        bwd_any = bwd_any or bwd
        if name:
            segs.append(name + ("~bwd" if bwd else ""))
        elif bwd and not segs:
            bwd_any = True
    return tuple(segs)


@dataclass
class ScopeNode:
    name: str
    path: str
    kind: str = "scope"               # scope | loop | while | cond | root
                                      # | kernel (pallas_call subtree)
    trip_count: Optional[int] = None  # loops with static length
    dynamic: bool = False             # subtree contains while/cond
    opaque: bool = False              # shard_map etc: not probeable inside
    n_eqns: int = 0                   # eqns directly at this node
    own_cycles: int = 0               # direct-eqn cycles per single visit
    static_cycles: int = 0            # subtree cycles per single visit
    source: str = ""                  # file:line of first eqn (C-to-RTL map)
    grid: Optional[Tuple[int, ...]] = None   # kernel grid loops only
    children: "Dict[str, ScopeNode]" = field(default_factory=dict)

    def walk(self):
        yield self
        for c in self.children.values():
            yield from c.walk()

    def find(self, path: str) -> Optional["ScopeNode"]:
        if path in ("", "/"):
            return self
        node = self
        for seg in path.strip("/").split("/"):
            node = node.children.get(seg)
            if node is None:
                return None
        return node


@dataclass
class EqnInfo:
    path: str                          # scope path the eqn lives at
    sub_path: Optional[str] = None     # control-flow node path (loops etc.)
    cycles: int = 0                    # flat cycles (leaf eqns)


@dataclass
class Hierarchy:
    root: ScopeNode
    eqn_info: Dict[int, EqnInfo]
    closed_jaxpr: Any
    # Site-qualified annotations: jax's tracing caches share one traced
    # sub-jaxpr OBJECT across call sites with identical avals (two calls
    # of the same custom_vjp/scan body, say), so eqns inside carry one
    # EqnInfo per walk entry path — keyed (id(eqn) -> entry -> info).
    # ``eqn_info`` keeps the first site's row as the fallback.
    site_info: Dict[int, Dict[str, EqnInfo]] = field(default_factory=dict)

    def info_at(self, eqn, entry: str) -> Optional[EqnInfo]:
        """EqnInfo for ``eqn`` as seen from the jaxpr walked under
        ``entry`` (the interpreter's entry path for that jaxpr)."""
        sites = self.site_info.get(id(eqn))
        if sites is not None:
            hit = sites.get(entry)
            if hit is not None:
                return hit
        return self.eqn_info.get(id(eqn))

    def infos_of(self, eqn) -> List[EqnInfo]:
        """Every site's info for one eqn (for probe-presence predicates
        that must be conservative across all call sites)."""
        out: List[EqnInfo] = []
        base = self.eqn_info.get(id(eqn))
        if base is not None:
            out.append(base)
        out.extend(self.site_info.get(id(eqn), {}).values())
        return out

    def node(self, path: str) -> Optional[ScopeNode]:
        return self.root.find(path)

    def all_paths(self) -> List[str]:
        return [n.path for n in self.root.walk() if n.path]

    def mapping_table(self) -> List[Dict[str, Any]]:
        """The C-to-RTL mapping table: scope -> source, kind, static cost."""
        rows = []
        for n in self.root.walk():
            rows.append(dict(path=n.path or "/", kind=n.kind,
                             source=n.source, n_eqns=n.n_eqns,
                             static_cycles=n.static_cycles,
                             trip_count=n.trip_count,
                             dynamic=n.dynamic))
        return rows


def _source_of(eqn) -> str:
    try:
        from jax._src import source_info_util
        try:
            # 0.4.x signature: user_frame(SourceInfo)
            frame = source_info_util.user_frame(eqn.source_info)
        except AttributeError:
            # newer signature: user_frame(Traceback)
            frame = source_info_util.user_frame(eqn.source_info.traceback)
        if frame is None:
            return ""
        return f"{frame.file_name.rsplit('/', 1)[-1]}:{frame.start_line}"
    except Exception:
        return ""


def _ensure(parent: ScopeNode, name: str, kind: str = "scope") -> ScopeNode:
    if name not in parent.children:
        path = f"{parent.path}/{name}" if parent.path else name
        parent.children[name] = ScopeNode(name=name, path=path, kind=kind)
    return parent.children[name]


def _as_jaxpr(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


_DESCEND = {"pjit", "jit", "closed_call", "core_call", "custom_jvp_call",
            "custom_vjp_call", "custom_vjp_call_jaxpr", "remat", "remat2",
            "checkpoint"}
_LOOPS = {"scan": "loop", "while": "while"}


# Extraction memo: ``extract`` is pure in (closed jaxpr identity,
# kernel_probes), and the returned Hierarchy strongly references its
# closed jaxpr — so while an entry lives in this bounded LRU, the id
# cannot be recycled and the identity check below is sound. Retargets,
# DSE re-measure loops and overhead sweeps that re-extract the same
# trace hit this instead of re-walking (paper §IV-C.2's incremental
# reuse, measured in bench_instrument).
_EXTRACT_MEMO: "OrderedDict[Tuple[int, Tuple[str, ...]], Hierarchy]" = None
_EXTRACT_MEMO_MAX = 32
extract_hits = 0
extract_misses = 0


def extract(closed_jaxpr, kernel_probes: Tuple[str, ...] = ()) -> Hierarchy:
    """Extract the scope hierarchy (memoized on the closed jaxpr's
    identity). With ``kernel_probes`` (kernel body names, '*' = all),
    matched ``pallas_call`` equations are descended into
    ``<scope>/kernel/<name>#i/grid`` subtrees (see ``core.kernelprobe``)
    instead of being flat-costed leaves."""
    global _EXTRACT_MEMO, extract_hits, extract_misses
    if _EXTRACT_MEMO is None:
        from collections import OrderedDict
        _EXTRACT_MEMO = OrderedDict()
    # eqn costs depend on the ambient cost-model context: kernel
    # calibration scales and the mesh axis sizes for collectives — a
    # hierarchy extracted under one context must not serve another
    sizes = cm.current_axis_sizes()
    ctx = (cm.kernel_calibration_state(),
           tuple(sorted(sizes.items())) if sizes else None)
    key = (id(closed_jaxpr), tuple(kernel_probes), ctx)
    hit = _EXTRACT_MEMO.get(key)
    if hit is not None and hit.closed_jaxpr is closed_jaxpr:
        _EXTRACT_MEMO.move_to_end(key)
        extract_hits += 1
        return hit
    extract_misses += 1
    h = _extract_uncached(closed_jaxpr, tuple(kernel_probes))
    _EXTRACT_MEMO[key] = h
    while len(_EXTRACT_MEMO) > _EXTRACT_MEMO_MAX:
        _EXTRACT_MEMO.popitem(last=False)
    return h


def _extract_uncached(closed_jaxpr,
                      kernel_probes: Tuple[str, ...]) -> Hierarchy:
    from repro.core import kernelprobe

    root = ScopeNode(name="", path="", kind="root")
    eqn_info: Dict[int, EqnInfo] = {}
    site_info: Dict[int, Dict[str, EqnInfo]] = {}
    seen_jaxprs: Dict[int, str] = {}    # id(jaxpr) -> first walk entry

    def put_site(eqn, info: EqnInfo, site: str):
        site_info.setdefault(id(eqn), {})[site] = info

    def walk(jaxpr, prefix_node: ScopeNode, counters: Dict[str, int],
             entry: str):
        # A jaxpr object revisited under a different entry is a traced
        # body shared across call sites: its eqns' annotations go into
        # the per-site table so each site resolves its own paths.
        shared = seen_jaxprs.setdefault(id(jaxpr), entry) != entry

        def put(eqn, info: EqnInfo):
            if shared:
                put_site(eqn, info, entry)
            else:
                eqn_info[id(eqn)] = info

        for eqn in jaxpr.eqns:
            segs = normalize_stack(str(eqn.source_info.name_stack))
            node = prefix_node
            for s in segs:
                node = _ensure(node, s)
                if not node.source:
                    node.source = _source_of(eqn)
            name = eqn.primitive.name
            if name in _LOOPS:
                idx = counters.get(node.path + "#" + name, 0)
                counters[node.path + "#" + name] = idx + 1
                lname = f"{name}#{idx}"
                lnode = _ensure(node, lname, kind=_LOOPS[name])
                lnode.source = lnode.source or _source_of(eqn)
                put(eqn, EqnInfo(path=node.path, sub_path=lnode.path))
                if name == "scan":
                    lnode.trip_count = int(eqn.params["length"])
                    walk(_as_jaxpr(eqn.params["jaxpr"]), lnode, counters,
                         lnode.path)
                else:
                    lnode.dynamic = True
                    walk(_as_jaxpr(eqn.params["cond_jaxpr"]),
                         _ensure(lnode, "cond"), counters,
                         lnode.path + "/cond")
                    walk(_as_jaxpr(eqn.params["body_jaxpr"]),
                         _ensure(lnode, "body"), counters,
                         lnode.path + "/body")
            elif name == "cond":
                idx = counters.get(node.path + "#cond", 0)
                counters[node.path + "#cond"] = idx + 1
                cnode = _ensure(node, f"cond#{idx}", kind="cond")
                cnode.dynamic = True
                cnode.source = cnode.source or _source_of(eqn)
                put(eqn, EqnInfo(path=node.path, sub_path=cnode.path))
                for bi, br in enumerate(eqn.params["branches"]):
                    walk(_as_jaxpr(br), _ensure(cnode, f"branch{bi}"),
                         counters, f"{cnode.path}/branch{bi}")
            elif name in _DESCEND and any(True for _ in cm._sub_jaxprs(eqn)):
                put(eqn, EqnInfo(path=node.path, sub_path=None))
                for sub in cm._sub_jaxprs(eqn):
                    walk(_as_jaxpr(sub), node, counters, node.path)
                    break    # only the call jaxpr
            elif (name == "pallas_call" and kernel_probes and
                  kernelprobe.matches(kernel_probes,
                                      kernelprobe.kernel_name(eqn)) and
                  (kpath := kernelprobe.extract_kernel_tree(
                      eqn, node, _ensure, put_site, counters,
                      _source_of)) is not None):
                # grid-step probing: the kernel subtree owns the cycles
                put(eqn, EqnInfo(path=node.path, sub_path=kpath))
            elif name == "shard_map":
                # opaque region: costed as a black box, not probeable inside
                idx = counters.get(node.path + "#smap", 0)
                counters[node.path + "#smap"] = idx + 1
                snode = _ensure(node, f"shard_map#{idx}")
                snode.opaque = True
                snode.source = snode.source or _source_of(eqn)
                c = cm.static_eqn_cycles(eqn)
                snode.n_eqns += 1
                snode.own_cycles += c
                put(eqn, EqnInfo(path=snode.path, cycles=c))
            else:
                c = cm.eqn_cost(eqn).cycles
                node.n_eqns += 1
                node.own_cycles += c
                put(eqn, EqnInfo(path=node.path, cycles=c))

    walk(closed_jaxpr.jaxpr, root, {}, "")

    def finalize(node: ScopeNode) -> Tuple[int, bool]:
        total = node.own_cycles
        dyn = node.dynamic
        for c in node.children.values():
            sub, d = finalize(c)
            mult = c.trip_count if (c.kind == "loop" and c.trip_count) else 1
            total += sub * mult
            dyn = dyn or d or c.kind in ("while", "cond")
        node.static_cycles = total
        node.dynamic = dyn
        return total, dyn

    finalize(root)
    return Hierarchy(root=root, eqn_info=eqn_info,
                     closed_jaxpr=closed_jaxpr, site_info=site_info)
