"""Streaming probe telemetry: continuous in-production profiling.

One-shot ``probe(fn)`` answers "where did *this* invocation spend its
cycles"; a serving or training loop needs "where do cycles go across
*millions* of steps, right now" — the paper's always-available in-FPGA
counters, kept running. This module provides that as a session:

    from repro.core import ProbeSession, ProbeConfig

    with ProbeSession(decode_step, ProbeConfig(targets=("layers",))) as s:
        for batch in stream:
            out = s.step(params, cache, batch)       # identical outputs
            if s.steps % 512 == 0:
                print(s.snapshot().table())          # running aggregates

Design points (mirroring the paper's profiler IP constraints):

- **No retracing.** The wrapped function is traced/instrumented/jitted
  once; every ``step`` reuses the same executable with the counter
  state threaded explicitly (``ProbedFunction.stateful_call``), so
  cycle/call totals accumulate across steps on-device.
- **Constant memory.** Cross-step aggregation keeps only fixed-size
  per-probe arrays — call counts, total/min/max cycles, an EMA, and a
  64-bucket log₂ histogram for p50/p99 — never the per-call history.
  ``ProbeSession.state_nbytes()`` is independent of step count.
- **Asynchronous host offload.** Ring-buffer spills (``HostSink``
  protocol) are enqueued by the ``io_callback`` and folded into the
  aggregates by a background worker thread, keeping the device-to-host
  path off the step's critical path.
- **Non-intrusive.** The instrumented step never reads probe state into
  model math, so outputs stay bit-identical with the session on or off
  (asserted in ``tests/test_streaming.py``).
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.buffer import HostSink, row_durations, state_bytes
from repro.core.pragma import ProbeConfig, ProbedFunction, probe
from repro.core.instrument import decode_record
from repro.core import report as report_mod

HIST_BUCKETS = 64
_I64_MAX = np.iinfo(np.int64).max


# powers of two for exact vectorized bit_length (searchsorted over
# uint64 is integer-exact, unlike float log2 near power-of-two edges)
_POW2 = (np.uint64(1) << np.arange(HIST_BUCKETS - 1, dtype=np.uint64))


def _buckets_of(durations: np.ndarray) -> np.ndarray:
    """Log₂ bucket index per duration: bucket b holds [2^(b-1), 2^b).

    Vectorized bit_length: the number of powers of two <= |x| equals
    ``int(x).bit_length()`` exactly, clamped to the last bucket."""
    d = np.abs(np.asarray(durations, dtype=np.int64)).astype(np.uint64)
    return np.searchsorted(_POW2, d, side="right").astype(np.int64)


def _bucket_rep(b: int) -> int:
    """Representative cycle value for bucket ``b`` (its midpoint)."""
    if b <= 0:
        return 0
    return ((1 << (b - 1)) + (1 << b) - 1) // 2


class StreamAggregator:
    """Constant-memory per-probe duration statistics.

    Fixed-size arrays over ``n`` probes: call count, total, min, max,
    EMA of per-call cycles, and a log-bucketed histogram from which
    quantiles (p50/p99) are estimated. Thread-safe: the streaming
    sink's worker updates it while snapshots copy it.
    """

    def __init__(self, n_probes: int, ema_alpha: float = 0.1):
        self.n = n_probes
        self.alpha = float(ema_alpha)
        self.count = np.zeros(n_probes, np.int64)
        self.total = np.zeros(n_probes, np.int64)
        self.min = np.full(n_probes, _I64_MAX, np.int64)
        self.max = np.zeros(n_probes, np.int64)
        self.ema = np.zeros(n_probes, np.float64)
        self.hist = np.zeros((n_probes, HIST_BUCKETS), np.int64)
        self._lock = threading.Lock()

    def add(self, pid: int, durations: np.ndarray):
        """Fold per-call cycle durations (oldest first) into the stats.

        Whole-array numpy throughout (the hot decode path): the EMA uses
        the closed form of the recurrence ``e <- (1-a)e + ax`` — the
        same statistic as the sequential loop up to float rounding.
        """
        d = np.asarray(durations, dtype=np.int64).ravel()
        if d.size == 0:
            return
        with self._lock:
            first = self.count[pid] == 0
            self.count[pid] += d.size
            self.total[pid] += int(d.sum())
            self.min[pid] = min(int(self.min[pid]), int(d.min()))
            self.max[pid] = max(int(self.max[pid]), int(d.max()))
            a = self.alpha
            k = d.size
            # weights w[i] = (1-a)^(k-1-i): one dot product replaces the
            # per-sample Python recurrence
            w = np.power(1.0 - a, np.arange(k - 1, -1, -1, dtype=np.float64))
            x = d.astype(np.float64)
            if first:
                e = float(x[0]) if k == 1 else \
                    float(w[0] * x[0] + a * np.dot(w[1:], x[1:]))
            else:
                e = float((1.0 - a) ** k * self.ema[pid] + a * np.dot(w, x))
            self.ema[pid] = e
            np.add.at(self.hist[pid], _buckets_of(d), 1)

    def copy(self) -> "StreamAggregator":
        with self._lock:
            out = StreamAggregator(self.n, self.alpha)
            out.count = self.count.copy()
            out.total = self.total.copy()
            out.min = self.min.copy()
            out.max = self.max.copy()
            out.ema = self.ema.copy()
            out.hist = self.hist.copy()
        return out

    def quantile(self, pid: int, q: float) -> int:
        """Histogram-estimated q-quantile of per-call cycles (bucket
        midpoint, clamped to the exact observed [min, max])."""
        n = int(self.count[pid])
        if n == 0:
            return 0
        target = max(1, int(np.ceil(q * n)))
        cum = np.cumsum(self.hist[pid])
        b = int(np.searchsorted(cum, target))
        return int(np.clip(_bucket_rep(b), self.min[pid], self.max[pid]))

    # -- cross-device reductions (mesh probing) -------------------------
    # A device-major aggregator lays its rows out as (device, probe)
    # flattened — row d*n_probes+p is probe p on device d, mirroring the
    # device-sharded counter buffer. These views reduce across that
    # leading device axis.

    REDUCTIONS = ("per-device", "max", "mean")

    def reduce(self, mode: str = "max", n_devices: int = 1) -> np.ndarray:
        """Per-probe total cycles reduced across devices: ``max`` (the
        critical path), ``mean`` (the balanced view), or ``per-device``
        (the full (D, n) matrix)."""
        t = self.total.reshape(int(n_devices), -1)
        if mode == "per-device":
            return t
        if mode == "max":
            return t.max(axis=0)
        if mode == "mean":
            return t.mean(axis=0)
        raise ValueError(f"unknown reduction {mode!r}; "
                         f"expected one of {self.REDUCTIONS}")

    def skew(self, n_devices: int) -> np.ndarray:
        """Per-probe max−min of total cycles across devices — the
        straggler signal (0 = perfectly balanced)."""
        t = self.total.reshape(int(n_devices), -1)
        return t.max(axis=0) - t.min(axis=0)

    @property
    def nbytes(self) -> int:
        return (self.count.nbytes + self.total.nbytes + self.min.nbytes +
                self.max.nbytes + self.ema.nbytes + self.hist.nbytes)


class StreamingSink(HostSink):
    """Drop-in ``HostSink`` that aggregates spills instead of storing.

    ``dump`` (the ordered ``io_callback`` target) only enqueues the ring
    row; a daemon worker thread decodes it to per-call durations and
    folds them into a :class:`~repro.telemetry.bus.ProbeStream` — the
    pub/sub refactoring of the old private ``StreamAggregator`` (the
    aggregation code path is unchanged; ``stats`` still exposes the
    aggregator).  The raw history is never retained, so memory stays
    constant no matter how many rings spill.  ``records()`` therefore
    returns ``[]``; use a plain ``HostSink`` when full per-iteration
    history is wanted.

    With a :class:`~repro.telemetry.bus.TelemetryBus` attached, the
    stream is registered on the bus under ``source`` and the session's
    window rolls flow through the same FIFO queue as the ring rows
    (``queue_roll``), so bus windows close in spill order.
    """

    def __init__(self, ema_alpha: float = 0.1, *, bus=None,
                 source: str = "session"):
        super().__init__()
        self.ema_alpha = ema_alpha
        self.bus = bus
        self.source = source
        self._stream = None
        self.dropped = 0
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None

    @property
    def stats(self) -> Optional[StreamAggregator]:
        """The live aggregator (the bus stream's, post-refactor)."""
        return self._stream.agg if self._stream is not None else None

    def bind(self, n_probes: int, paths: Optional[Tuple[str, ...]] = None):
        """Size the aggregator (probe count is known only post-build)."""
        paths = tuple(paths) if paths is not None else \
            tuple(f"probe{i}" for i in range(n_probes))
        if self._stream is None or self._stream.paths != paths:
            from repro.telemetry.bus import ProbeStream
            if self.bus is not None:
                self._stream = self.bus.stream(self.source, paths,
                                               ema_alpha=self.ema_alpha)
            else:
                self._stream = ProbeStream(self.source, paths,
                                           ema_alpha=self.ema_alpha)
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _store(self, probe_id: int, base_count: int, row: np.ndarray):
        self._q.put(("row", probe_id, row))

    def queue_roll(self, start_step: int, end_step: int,
                   exact_totals: Optional[np.ndarray] = None):
        """Enqueue a window-roll marker; the drain worker closes the bus
        window after folding every ring row queued before it."""
        self._q.put(("roll", start_step, end_step, exact_totals))

    def _fold(self, per_pid: Dict[int, List[np.ndarray]]):
        for pid, durs in per_pid.items():
            try:
                if self._stream is None:
                    raise RuntimeError("sink not bound")
                self._stream.add(pid, np.concatenate(durs))
            except Exception:
                self.dropped += 1
        per_pid.clear()

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            # batch: grab everything already queued, decode each row to
            # durations (vectorized), then fold ONE concatenated array
            # per probe per window segment — queue FIFO keeps per-probe
            # sample order and window-roll ordering
            batch = [item]
            done = 1
            stop = False
            while True:
                try:
                    nxt = self._q.get_nowait()
                except queue.Empty:
                    break
                done += 1
                if nxt is None:
                    stop = True
                    break
                batch.append(nxt)
            per_pid: Dict[int, List[np.ndarray]] = {}
            for item in batch:
                if item[0] == "roll":
                    self._fold(per_pid)    # close the segment in order
                    try:
                        if self._stream is not None:
                            self._stream.roll(item[1], item[2],
                                              exact_totals=item[3])
                    except Exception:
                        self.dropped += 1
                    continue
                _, pid, row = item
                try:
                    per_pid.setdefault(pid, []).append(row_durations(row))
                except Exception:
                    # a poisoned row must not kill the drain thread —
                    # that would turn every later flush() into a hang
                    self.dropped += 1
            self._fold(per_pid)
            for _ in range(done):
                self._q.task_done()
            if stop:
                return

    def flush(self):
        """Block until every enqueued spill has been aggregated."""
        self._q.join()

    def close(self):
        if self._worker is not None and self._worker.is_alive():
            self._q.put(None)
            self._q.join()
            self._worker.join(timeout=5.0)
        self._worker = None


@dataclass
class WindowStat:
    """Per-probe cycles spent inside one time window of the session."""
    label: str
    start_step: int
    end_step: int
    totals: np.ndarray            # (n_probes,) int64


@dataclass
class StreamRow:
    """Running aggregate for one probe at snapshot time."""
    path: str
    calls: int                    # exact, from the device counter
    total_cycles: int             # exact, from the device counter
    observed: int                 # calls covered by duration stats
    mean: float
    ema: float
    min: int
    p50: int
    p99: int
    max: int


@dataclass
class StreamSnapshot:
    """Point-in-time view of a live session (itself constant-size)."""
    steps: int
    span: int                     # cumulative cycles since session start
    wall_s: float
    paths: Tuple[str, ...]
    rows: List[StreamRow]
    windows: List[WindowStat]
    state_nbytes: int

    def table(self) -> str:
        return report_mod.streaming_table(self)

    def bump_chart(self, top: int = 5, width: int = 18) -> str:
        return report_mod.streaming_bump_chart(self, top=top, width=width)

    def row(self, path: str) -> Optional[StreamRow]:
        for r in self.rows:
            if r.path == path:
                return r
        return None

    def bottleneck(self) -> Optional[StreamRow]:
        leaf = [r for r in self.rows
                if not any(o.path.startswith(r.path + "/")
                           for o in self.rows)]
        return max(leaf or self.rows, key=lambda r: r.total_cycles,
                   default=None)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "steps": self.steps, "span": self.span, "wall_s": self.wall_s,
            "rows": [r.__dict__ for r in self.rows],
            "windows": [{"label": w.label, "start_step": w.start_step,
                         "end_step": w.end_step,
                         "totals": w.totals.tolist()}
                        for w in self.windows],
            "state_nbytes": self.state_nbytes,
        }


class ProbeSession:
    """Continuous profiling session over a jitted step function.

    Lifecycle: construct (or ``with ProbeSession(fn) as s``), call
    ``s.step(*args)`` in place of the step function — outputs are
    unchanged — then ``s.snapshot()`` any time for running aggregates
    and ``s.close()`` when done (returns the final snapshot).

    ``fn`` may be a plain callable or an existing ``ProbedFunction``;
    either way the session installs its :class:`StreamingSink` before
    the one-time build, then every step reuses the same executable.

    By default every probe spills its ring (``offload=1.0``) so the
    duration statistics cover *all* calls; pass a custom ``ProbeConfig``
    to restrict targets or disable spilling (stats then cover only each
    probe's first ``buffer_depth`` calls, like one-shot truncation).
    """

    def __init__(self, fn: Union[Callable, ProbedFunction],
                 config: Optional[ProbeConfig] = None, *,
                 window_steps: int = 16, max_windows: int = 8,
                 ema_alpha: float = 0.1, poll_every: int = 1,
                 bus=None, source: str = "session"):
        if isinstance(fn, ProbedFunction):
            self.pf = fn
            if config is not None:
                self.pf.retarget(config)
        else:
            self.pf = probe(fn, config if config is not None
                            else ProbeConfig(offload=1.0))
        self.sink = StreamingSink(ema_alpha=ema_alpha, bus=bus,
                                  source=source)
        # install before build so the Instrumenter captures this sink;
        # close() restores the original and forces a rebuild
        self._orig_sink = self.pf.sink
        self.pf.sink = self.sink
        self.pf.retarget(self.pf.config)       # force (re)build on step 1
        self.window_steps = int(window_steps)
        self.max_windows = int(max_windows)
        self.poll_every = int(poll_every)
        self._state = None
        self._steps = 0
        self._closed = False
        self._t0 = 0.0
        self._prev_totals: Optional[np.ndarray] = None
        self._win_start = 0
        self._windows: deque = deque(maxlen=max_windows)

    # -- lifecycle -------------------------------------------------------
    def __enter__(self) -> "ProbeSession":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    @property
    def steps(self) -> int:
        return self._steps

    @property
    def paths(self) -> Tuple[str, ...]:
        return self.pf.assignment.paths

    def step(self, *args, **kwargs):
        """Run one profiled step; returns exactly ``fn(*args)``'s output."""
        if self._closed:
            raise RuntimeError("session is closed")
        if self._state is None:
            self._start(*args, **kwargs)
        out, self._state = self.pf.stateful_call(self._state, *args,
                                                 **kwargs)
        self._steps += 1
        if self._steps % self.poll_every == 0:
            self._maybe_roll_window()
        return out

    def _start(self, *args, **kwargs):
        self.pf.ensure_built(*args, **kwargs)
        n = self.pf.assignment.n
        self.sink.bind(n, paths=self.pf.assignment.paths)
        self._state = self.pf.init_state()
        self._prev_totals = np.zeros(n, np.int64)
        self._win_start = 0
        self._t0 = time.perf_counter()

    def _read_totals(self) -> np.ndarray:
        from repro.core.instrument import state_totals
        return state_totals(self._state)

    def clock(self) -> int:
        """Current device model-clock value (cycles since the session's
        first step; 0 before any step). Reading it between steps costs
        one scalar device_get — the serving engine's per-request phase
        attribution takes clock deltas around each step call."""
        if self._state is None:
            return 0
        from repro.core.instrument import state_clock
        return state_clock(jax.device_get(
            {k: self._state[k] for k in ("cyc_hi", "cyc_lo")
             if k in self._state} or self._state))

    def _maybe_roll_window(self):
        """Close the current time window once it is full. The window
        delta telescopes to (totals now - totals at window start), so
        the blocking device read happens once per window boundary —
        never on the step's critical path in between."""
        if self._steps - self._win_start < self.window_steps:
            return
        totals = self._read_totals()
        delta = totals - self._prev_totals
        self._windows.append(WindowStat(
            f"[{self._win_start}..{self._steps})", self._win_start,
            self._steps, delta))
        # the device_get above is a barrier: every ordered spill
        # callback of the window has already enqueued, so the roll
        # marker closes the bus window at exactly this boundary
        self.sink.queue_roll(self._win_start, self._steps,
                             exact_totals=delta)
        self._prev_totals = totals
        self._win_start = self._steps

    # -- results ---------------------------------------------------------
    def _merged_stats(self, rec: Dict[str, Any]) -> StreamAggregator:
        """Aggregates incl. calls still sitting in the device rings."""
        asg = self.pf.assignment
        merged = self.sink.stats.copy()
        for pid in range(asg.n):
            calls = int(rec["calls"][pid])
            rem = (calls % asg.depth) if asg.spill[pid] \
                else min(calls, asg.depth)
            if rem:
                spans = rec["ring"][pid, :rem]
                merged.add(pid, spans[:, 1] - spans[:, 0])
        return merged

    def snapshot(self) -> StreamSnapshot:
        """Flush pending offloads and build a constant-size snapshot.

        Order matters: the device_get first acts as a barrier — all
        dispatched steps (and their ordered spill callbacks) complete
        before the flush drains the queue, so the aggregates cover
        every call the counters have seen."""
        if self._state is None:
            raise RuntimeError("no steps executed yet")
        rec = decode_record(jax.device_get(self._state))
        self.sink.flush()
        asg = self.pf.assignment
        stats = self._merged_stats(rec)
        rows = []
        for pid, path in enumerate(asg.paths):
            cnt = int(stats.count[pid])
            rows.append(StreamRow(
                path=path,
                calls=int(rec["calls"][pid]),
                total_cycles=int(rec["totals"][pid]),
                observed=cnt,
                mean=float(stats.total[pid]) / cnt if cnt else 0.0,
                ema=float(stats.ema[pid]),
                min=int(stats.min[pid]) if cnt else 0,
                p50=stats.quantile(pid, 0.50),
                p99=stats.quantile(pid, 0.99),
                max=int(stats.max[pid])))
        windows = list(self._windows)
        if self._steps > self._win_start:
            partial = rec["totals"] - self._prev_totals
            if partial.any():
                windows.append(WindowStat(
                    f"[{self._win_start}..{self._steps})*",
                    self._win_start, self._steps, partial))
        return StreamSnapshot(
            steps=self._steps, span=rec["cycle"],
            wall_s=time.perf_counter() - self._t0,
            paths=asg.paths, rows=rows, windows=windows,
            state_nbytes=self.state_nbytes())

    def state_nbytes(self) -> int:
        """Total profiling-state footprint: device counters + host
        aggregates + bounded window history. Independent of ``steps``."""
        host = self.sink.stats.nbytes if self.sink.stats is not None else 0
        if self._prev_totals is not None:
            host += self._prev_totals.nbytes
        host += sum(w.totals.nbytes for w in self._windows)
        dev = state_bytes(self.pf.assignment.n,
                          self.pf.config.buffer_depth,
                          layout=self.pf.config.layout) \
            if self._state is not None else 0
        return host + dev

    def close(self) -> Optional[StreamSnapshot]:
        """End the session; returns the final snapshot (None if unused).

        Restores the wrapped function's original sink (forcing a
        rebuild on its next use) so later one-shot calls don't spill
        into the now-dead streaming worker."""
        if self._closed:
            return None
        snap = self.snapshot() if self._state is not None else None
        self.sink.close()
        self.pf.sink = self._orig_sink
        self.pf.retarget(self.pf.config)
        self._closed = True
        return snap
