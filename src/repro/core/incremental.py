"""Incremental re-instrumentation + evaluation caching (paper §IV-C.2).

Vivado's incremental synthesis preserves 99% of cells when RealProbe
retargets; the XLA analogue has three layers:

1. the traced jaxpr + hierarchy are extracted ONCE per function/shape
   (``ProbedFunction.trace``) and reused verbatim across retargets;
2. the *unprobed* model executable is compiled under its own jit cache
   key and is never invalidated by probe changes (decoupling);
3. DSE measurements persist in an on-disk :class:`EvalCache` keyed by
   (kernel id, candidate config, lowered-IR hash, device kind), so
   re-running the autotuner after an unrelated edit re-measures nothing
   — and an edit to the kernel itself changes the IR hash and naturally
   invalidates exactly the stale entries.

``measure_incremental`` quantifies the first two — full cold setup vs
retarget cost vs the untouched base executable — for bench_incremental.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax

from repro.core.pragma import ProbeConfig, probe

try:
    import fcntl
except ImportError:                       # non-POSIX: O_EXCL spin fallback
    fcntl = None


class FileLock:
    """Advisory inter-process lock guarding read-merge-write saves.

    ``flock`` on a sidecar ``.lock`` file where available (released
    automatically by the OS if the holder dies), an ``O_EXCL``
    create-spin elsewhere. Sweep-farm workers and concurrent tuner
    processes all mutate the same cache files; every mutation must
    happen under this lock or a whole-file rewrite from a stale
    snapshot silently drops the other writers' entries.
    """

    def __init__(self, path: str, *, timeout: float = 30.0,
                 poll: float = 0.005):
        self.path = path
        self.timeout = timeout
        self.poll = poll
        self._fd: Optional[int] = None
        self._excl = False

    def acquire(self) -> "FileLock":
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        deadline = time.monotonic() + self.timeout
        if fcntl is not None:
            self._fd = os.open(self.path, os.O_CREAT | os.O_RDWR, 0o644)
            while True:
                try:
                    fcntl.flock(self._fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                    return self
                except OSError:
                    if time.monotonic() >= deadline:
                        os.close(self._fd)
                        self._fd = None
                        raise TimeoutError(
                            f"could not acquire lock {self.path} within "
                            f"{self.timeout:g}s")
                    time.sleep(self.poll)
        while True:
            try:
                self._fd = os.open(self.path,
                                   os.O_CREAT | os.O_EXCL | os.O_RDWR, 0o644)
                self._excl = True
                return self
            except FileExistsError:
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"could not acquire lock {self.path} within "
                        f"{self.timeout:g}s")
                time.sleep(self.poll)

    def release(self) -> None:
        if self._fd is None:
            return
        try:
            if fcntl is not None and not self._excl:
                fcntl.flock(self._fd, fcntl.LOCK_UN)
            os.close(self._fd)
        finally:
            self._fd = None
            if self._excl:
                self._excl = False
                try:
                    os.unlink(self.path)
                except OSError:
                    pass

    def __enter__(self) -> "FileLock":
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


def _file_stamp(path: str) -> Optional[Tuple[int, int, int]]:
    """Freshness stamp of an on-disk JSON file (None when absent)."""
    try:
        st = os.stat(path)
    except OSError:
        return None
    return (st.st_mtime_ns, st.st_size, st.st_ino)


def _read_json(path: str) -> Dict[str, Any]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _write_json(path: str, data: Dict[str, Any]) -> None:
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


@dataclass
class IncrementalTimings:
    cold_total_s: float          # trace + extract + instrument + compile + run
    retarget_total_s: float      # instrument + compile + run (trace reused)
    trace_s: float
    extract_s: float
    base_compile_reused: bool    # unprobed executable survived the retarget
    reuse_fraction: float        # analogue of "99% of cells reused"

    def table(self) -> str:
        return (f"cold setup     : {self.cold_total_s * 1e3:9.1f} ms "
                f"(trace {self.trace_s * 1e3:.1f} ms, "
                f"extract {self.extract_s * 1e3:.1f} ms)\n"
                f"retarget       : {self.retarget_total_s * 1e3:9.1f} ms "
                f"({100 * self.retarget_total_s / max(self.cold_total_s, 1e-12):.1f}% of cold)\n"
                f"base executable: {'reused (untouched)' if self.base_compile_reused else 'RECOMPILED'}\n"
                f"artifact reuse : {self.reuse_fraction * 100:.1f}%")


# --------------------------------------------------- evaluation cache

DEFAULT_CACHE_DIR = os.path.join(".repro_cache", "dse")


def fingerprint_closed(closed) -> str:
    """Hash an already-traced closed jaxpr (the single definition of
    the cache-key fingerprint scheme)."""
    return hashlib.sha256(str(closed).encode()).hexdigest()[:16]


def lowered_fingerprint(fn: Callable, args: Sequence[Any]) -> str:
    """Content hash of the candidate's lowered IR (the traced jaxpr,
    avals included). Any edit to the kernel body, the wrapper, or the
    input shapes changes this hash; unrelated repo edits do not — the
    cache-key analogue of hashing the post-synthesis checkpoint."""
    return fingerprint_closed(jax.make_jaxpr(fn)(*args))


def device_kind() -> str:
    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', '?')}"


class EvalCache:
    """On-disk memo of DSE measurements (the incremental-synthesis
    analogue: unchanged candidates are never re-measured).

    One JSON file maps entry keys — sha256 over (kernel id, canonical
    config, lowered-IR hash, device kind) — to the best measurement so
    far: ``{config, cycles_per_step, steps, ...}``. A lookup hits only
    when the cached run covered at least as many steps as requested, so
    successive-halving finalists are always backed by long-enough runs.

    Safe to share across processes: every mutation is a read-merge-write
    of the on-disk file under a :class:`FileLock` (concurrent writers
    merge instead of clobbering each other), a ``put`` never replaces an
    entry backed by a longer run, and reads reload whenever the file
    changed on disk.
    """

    def __init__(self, cache_dir: Optional[str] = None):
        root = (cache_dir or os.environ.get("REPRO_DSE_CACHE")
                or DEFAULT_CACHE_DIR)
        self.root = os.path.expanduser(root)
        self.path = os.path.join(self.root, "evals.json")
        self.winners_path = os.path.join(self.root, "winners.json")
        self._data: Optional[Dict[str, Dict[str, Any]]] = None
        self._winners: Optional[Dict[str, Dict[str, Any]]] = None
        self._stamp: Optional[Tuple[int, int, int]] = None
        self._winners_stamp: Optional[Tuple[int, int, int]] = None

    # -- storage -------------------------------------------------------
    def _load(self) -> Dict[str, Dict[str, Any]]:
        stamp = _file_stamp(self.path)
        if self._data is None or stamp != self._stamp:
            self._data = _read_json(self.path)
            self._stamp = stamp
        return self._data

    def _mutate(self, path: str,
                mutator: Callable[[Dict[str, Any]], None]
                ) -> Tuple[Dict[str, Any], Optional[Tuple[int, int, int]]]:
        """Locked read-merge-write: re-read the CURRENT on-disk state,
        apply ``mutator`` to it, atomically write it back. Other
        processes' entries written since our last load survive."""
        os.makedirs(self.root, exist_ok=True)
        with FileLock(path + ".lock"):
            data = _read_json(path)
            mutator(data)
            _write_json(path, data)
            stamp = _file_stamp(path)
        return data, stamp

    @staticmethod
    def entry_key(kernel_id: str, config: Dict[str, Any],
                  fingerprint: str, device: str) -> str:
        # the probe-state layout version is part of the key: measurements
        # recorded under the legacy dict layout can never serve a run
        # instrumented with the packed layout (and vice versa)
        from repro.core.instrument import STATE_LAYOUT_VERSION
        blob = json.dumps([kernel_id, config, fingerprint, device,
                           STATE_LAYOUT_VERSION], sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:24]

    # -- API -----------------------------------------------------------
    def get(self, kernel_id: str, config: Dict[str, Any], fingerprint: str,
            device: str, min_steps: int = 1) -> Optional[Dict[str, Any]]:
        e = self._load().get(self.entry_key(kernel_id, config, fingerprint,
                                            device))
        if e is not None and e["steps"] >= min_steps:
            return e
        return None

    def put(self, kernel_id: str, config: Dict[str, Any], fingerprint: str,
            device: str, *, cycles_per_step: float,
            steps: int) -> Dict[str, Any]:
        """Record a measurement; returns the entry now stored under the
        key. "Best measurement so far" means an entry is only replaced
        by a run of at least as many steps — a short re-measure (an
        ``r0``-step halving rung) can never downgrade a cached long-run
        finalist measurement."""
        key = self.entry_key(kernel_id, config, fingerprint, device)
        entry = {
            "kernel": kernel_id, "config": dict(config),
            "fingerprint": fingerprint, "device": device,
            "cycles_per_step": float(cycles_per_step), "steps": int(steps),
        }

        def merge(data: Dict[str, Any]) -> None:
            cur = data.get(key)
            if cur is not None and int(cur.get("steps", 0)) > int(steps):
                return
            data[key] = entry

        self._data, self._stamp = self._mutate(self.path, merge)
        return dict(self._data[key])

    def entries(self, kernel_id: Optional[str] = None,
                device: Optional[str] = None) -> list:
        out = []
        for e in self._load().values():
            if kernel_id is not None and e.get("kernel") != kernel_id:
                continue
            if device is not None and e.get("device") != device:
                continue
            out.append(dict(e))
        return out

    # -- winners (the DSE outcome record) -------------------------------
    def _load_winners(self) -> Dict[str, Dict[str, Any]]:
        stamp = _file_stamp(self.winners_path)
        if self._winners is None or stamp != self._winners_stamp:
            self._winners = _read_json(self.winners_path)
            self._winners_stamp = stamp
        return self._winners

    def set_winner(self, kernel_id: str, device: str,
                   config: Dict[str, Any], *, cycles_per_step: float,
                   shape: str = "") -> None:
        """Record the outcome of the LATEST tuning run for this kernel
        on this device. Raw eval entries are not mutually comparable —
        cycles scale with problem shape and stale-fingerprint entries
        survive kernel edits — so the engine declares its winner
        explicitly and ``best_config`` serves that."""
        rec = {
            "kernel": kernel_id, "device": device, "config": dict(config),
            "cycles_per_step": float(cycles_per_step), "shape": shape,
        }

        def merge(w: Dict[str, Any]) -> None:
            w[f"{kernel_id}@{device}"] = rec

        self._winners, self._winners_stamp = \
            self._mutate(self.winners_path, merge)

    def best_config(self, kernel_id: str,
                    device: Optional[str] = None) -> Optional[Dict[str, Any]]:
        """Config chosen by the most recent tuning run for this kernel
        on this device (falls back, for hand-written caches with no
        winner record, to the raw lowest-cycles eval entry)."""
        dev = device if device is not None else device_kind()
        w = self._load_winners().get(f"{kernel_id}@{dev}")
        if w is not None:
            return dict(w["config"])
        es = self.entries(kernel_id, dev)
        if not es:
            return None
        best = min(es, key=lambda e: (e["cycles_per_step"], -e["steps"]))
        return dict(best["config"])

    def clear(self, kernel_id: Optional[str] = None) -> int:
        dropped = [0]

        def drop_entries(data: Dict[str, Any]) -> None:
            keys = [k for k, e in data.items()
                    if kernel_id is None or e.get("kernel") == kernel_id]
            dropped[0] = len(keys)
            for k in keys:
                del data[k]

        def drop_winners(w: Dict[str, Any]) -> None:
            for k in [k for k, e in w.items()
                      if kernel_id is None or e.get("kernel") == kernel_id]:
                del w[k]

        self._data, self._stamp = self._mutate(self.path, drop_entries)
        self._winners, self._winners_stamp = \
            self._mutate(self.winners_path, drop_winners)
        return dropped[0]

    def __len__(self) -> int:
        return len(self._load())


def measure_incremental(fn: Callable, args: Sequence[Any],
                        cfg_a: ProbeConfig, cfg_b: ProbeConfig
                        ) -> IncrementalTimings:
    # the unprobed model executable (must stay untouched)
    base = jax.jit(fn)
    base(*args)
    misses_before = base._cache_size()

    pf = probe(fn, cfg_a)
    t0 = time.perf_counter()
    out, _ = pf(*args)
    jax.block_until_ready(out)
    cold = time.perf_counter() - t0

    t0 = time.perf_counter()
    pf.retarget(cfg_b)
    out, _ = pf(*args)
    jax.block_until_ready(out)
    retarget = time.perf_counter() - t0

    base(*args)
    reused = base._cache_size() == misses_before

    # reuse fraction: cached artifacts (trace + hierarchy) over total
    # setup stages {trace, extract, instrument}; retarget redoes only the
    # instrument stage.
    t_trace = pf.timings.get("trace_s", 0.0)
    t_extract = pf.timings.get("extract_s", 0.0)
    t_instr = pf.timings.get("instrument_s", 1e-12)
    reuse = (t_trace + t_extract) / max(t_trace + t_extract + t_instr, 1e-12)
    return IncrementalTimings(
        cold_total_s=cold, retarget_total_s=retarget,
        trace_s=t_trace, extract_s=t_extract,
        base_compile_reused=reused, reuse_fraction=reuse)
