"""Incremental re-instrumentation + evaluation caching (paper §IV-C.2).

Vivado's incremental synthesis preserves 99% of cells when RealProbe
retargets; the XLA analogue has three layers:

1. the traced jaxpr + hierarchy are extracted ONCE per function/shape
   (``ProbedFunction.trace``) and reused verbatim across retargets;
2. the *unprobed* model executable is compiled under its own jit cache
   key and is never invalidated by probe changes (decoupling);
3. DSE measurements persist in an on-disk :class:`EvalCache` keyed by
   (kernel id, candidate config, lowered-IR hash, device kind), so
   re-running the autotuner after an unrelated edit re-measures nothing
   — and an edit to the kernel itself changes the IR hash and naturally
   invalidates exactly the stale entries.

``measure_incremental`` quantifies the first two — full cold setup vs
retarget cost vs the untouched base executable — for bench_incremental.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence

import jax

from repro.core.pragma import ProbeConfig, probe


@dataclass
class IncrementalTimings:
    cold_total_s: float          # trace + extract + instrument + compile + run
    retarget_total_s: float      # instrument + compile + run (trace reused)
    trace_s: float
    extract_s: float
    base_compile_reused: bool    # unprobed executable survived the retarget
    reuse_fraction: float        # analogue of "99% of cells reused"

    def table(self) -> str:
        return (f"cold setup     : {self.cold_total_s * 1e3:9.1f} ms "
                f"(trace {self.trace_s * 1e3:.1f} ms, "
                f"extract {self.extract_s * 1e3:.1f} ms)\n"
                f"retarget       : {self.retarget_total_s * 1e3:9.1f} ms "
                f"({100 * self.retarget_total_s / max(self.cold_total_s, 1e-12):.1f}% of cold)\n"
                f"base executable: {'reused (untouched)' if self.base_compile_reused else 'RECOMPILED'}\n"
                f"artifact reuse : {self.reuse_fraction * 100:.1f}%")


# --------------------------------------------------- evaluation cache

DEFAULT_CACHE_DIR = os.path.join(".repro_cache", "dse")


def fingerprint_closed(closed) -> str:
    """Hash an already-traced closed jaxpr (the single definition of
    the cache-key fingerprint scheme)."""
    return hashlib.sha256(str(closed).encode()).hexdigest()[:16]


def lowered_fingerprint(fn: Callable, args: Sequence[Any]) -> str:
    """Content hash of the candidate's lowered IR (the traced jaxpr,
    avals included). Any edit to the kernel body, the wrapper, or the
    input shapes changes this hash; unrelated repo edits do not — the
    cache-key analogue of hashing the post-synthesis checkpoint."""
    return fingerprint_closed(jax.make_jaxpr(fn)(*args))


def device_kind() -> str:
    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', '?')}"


class EvalCache:
    """On-disk memo of DSE measurements (the incremental-synthesis
    analogue: unchanged candidates are never re-measured).

    One JSON file maps entry keys — sha256 over (kernel id, canonical
    config, lowered-IR hash, device kind) — to the best measurement so
    far: ``{config, cycles_per_step, steps, ...}``. A lookup hits only
    when the cached run covered at least as many steps as requested, so
    successive-halving finalists are always backed by long-enough runs.
    """

    def __init__(self, cache_dir: Optional[str] = None):
        root = (cache_dir or os.environ.get("REPRO_DSE_CACHE")
                or DEFAULT_CACHE_DIR)
        self.root = os.path.expanduser(root)
        self.path = os.path.join(self.root, "evals.json")
        self.winners_path = os.path.join(self.root, "winners.json")
        self._data: Optional[Dict[str, Dict[str, Any]]] = None
        self._winners: Optional[Dict[str, Dict[str, Any]]] = None

    # -- storage -------------------------------------------------------
    def _load(self) -> Dict[str, Dict[str, Any]]:
        if self._data is None:
            try:
                with open(self.path) as f:
                    self._data = json.load(f)
            except (OSError, ValueError):
                self._data = {}
        return self._data

    def _save(self) -> None:
        os.makedirs(self.root, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._load(), f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    @staticmethod
    def entry_key(kernel_id: str, config: Dict[str, Any],
                  fingerprint: str, device: str) -> str:
        # the probe-state layout version is part of the key: measurements
        # recorded under the legacy dict layout can never serve a run
        # instrumented with the packed layout (and vice versa)
        from repro.core.instrument import STATE_LAYOUT_VERSION
        blob = json.dumps([kernel_id, config, fingerprint, device,
                           STATE_LAYOUT_VERSION], sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:24]

    # -- API -----------------------------------------------------------
    def get(self, kernel_id: str, config: Dict[str, Any], fingerprint: str,
            device: str, min_steps: int = 1) -> Optional[Dict[str, Any]]:
        e = self._load().get(self.entry_key(kernel_id, config, fingerprint,
                                            device))
        if e is not None and e["steps"] >= min_steps:
            return e
        return None

    def put(self, kernel_id: str, config: Dict[str, Any], fingerprint: str,
            device: str, *, cycles_per_step: float, steps: int) -> None:
        data = self._load()
        data[self.entry_key(kernel_id, config, fingerprint, device)] = {
            "kernel": kernel_id, "config": dict(config),
            "fingerprint": fingerprint, "device": device,
            "cycles_per_step": float(cycles_per_step), "steps": int(steps),
        }
        self._save()

    def entries(self, kernel_id: Optional[str] = None,
                device: Optional[str] = None) -> list:
        out = []
        for e in self._load().values():
            if kernel_id is not None and e.get("kernel") != kernel_id:
                continue
            if device is not None and e.get("device") != device:
                continue
            out.append(dict(e))
        return out

    # -- winners (the DSE outcome record) -------------------------------
    def _load_winners(self) -> Dict[str, Dict[str, Any]]:
        if self._winners is None:
            try:
                with open(self.winners_path) as f:
                    self._winners = json.load(f)
            except (OSError, ValueError):
                self._winners = {}
        return self._winners

    def set_winner(self, kernel_id: str, device: str,
                   config: Dict[str, Any], *, cycles_per_step: float,
                   shape: str = "") -> None:
        """Record the outcome of the LATEST tuning run for this kernel
        on this device. Raw eval entries are not mutually comparable —
        cycles scale with problem shape and stale-fingerprint entries
        survive kernel edits — so the engine declares its winner
        explicitly and ``best_config`` serves that."""
        w = self._load_winners()
        w[f"{kernel_id}@{device}"] = {
            "kernel": kernel_id, "device": device, "config": dict(config),
            "cycles_per_step": float(cycles_per_step), "shape": shape,
        }
        os.makedirs(self.root, exist_ok=True)
        tmp = self.winners_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(w, f, indent=1, sort_keys=True)
        os.replace(tmp, self.winners_path)

    def best_config(self, kernel_id: str,
                    device: Optional[str] = None) -> Optional[Dict[str, Any]]:
        """Config chosen by the most recent tuning run for this kernel
        on this device (falls back, for hand-written caches with no
        winner record, to the raw lowest-cycles eval entry)."""
        dev = device if device is not None else device_kind()
        w = self._load_winners().get(f"{kernel_id}@{dev}")
        if w is not None:
            return dict(w["config"])
        es = self.entries(kernel_id, dev)
        if not es:
            return None
        best = min(es, key=lambda e: (e["cycles_per_step"], -e["steps"]))
        return dict(best["config"])

    def clear(self, kernel_id: Optional[str] = None) -> int:
        data = self._load()
        if kernel_id is None:
            n = len(data)
            data.clear()
        else:
            drop = [k for k, e in data.items()
                    if e.get("kernel") == kernel_id]
            n = len(drop)
            for k in drop:
                del data[k]
        self._save()
        w = self._load_winners()
        for k in [k for k, e in w.items()
                  if kernel_id is None or e.get("kernel") == kernel_id]:
            del w[k]
        if os.path.exists(self.winners_path) or w:
            os.makedirs(self.root, exist_ok=True)
            with open(self.winners_path, "w") as f:
                json.dump(w, f, indent=1, sort_keys=True)
        return n

    def __len__(self) -> int:
        return len(self._load())


def measure_incremental(fn: Callable, args: Sequence[Any],
                        cfg_a: ProbeConfig, cfg_b: ProbeConfig
                        ) -> IncrementalTimings:
    # the unprobed model executable (must stay untouched)
    base = jax.jit(fn)
    base(*args)
    misses_before = base._cache_size()

    pf = probe(fn, cfg_a)
    t0 = time.perf_counter()
    out, _ = pf(*args)
    jax.block_until_ready(out)
    cold = time.perf_counter() - t0

    t0 = time.perf_counter()
    pf.retarget(cfg_b)
    out, _ = pf(*args)
    jax.block_until_ready(out)
    retarget = time.perf_counter() - t0

    base(*args)
    reused = base._cache_size() == misses_before

    # reuse fraction: cached artifacts (trace + hierarchy) over total
    # setup stages {trace, extract, instrument}; retarget redoes only the
    # instrument stage.
    t_trace = pf.timings.get("trace_s", 0.0)
    t_extract = pf.timings.get("extract_s", 0.0)
    t_instr = pf.timings.get("instrument_s", 1e-12)
    reuse = (t_trace + t_extract) / max(t_trace + t_extract + t_instr, 1e-12)
    return IncrementalTimings(
        cold_total_s=cold, retarget_total_s=retarget,
        trace_s=t_trace, extract_s=t_extract,
        base_compile_reused=reused, reuse_fraction=reuse)
