"""Incremental re-instrumentation (paper §IV-C.2, Fig 7/11).

Vivado's incremental synthesis preserves 99% of cells when RealProbe
retargets; the XLA analogue has two layers:

1. the traced jaxpr + hierarchy are extracted ONCE per function/shape
   (``ProbedFunction.trace``) and reused verbatim across retargets;
2. the *unprobed* model executable is compiled under its own jit cache
   key and is never invalidated by probe changes (decoupling).

``measure_incremental`` quantifies both — full cold setup vs retarget
cost vs the untouched base executable — for bench_incremental (Fig 11).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Sequence

import jax

from repro.core.pragma import ProbeConfig, ProbedFunction, probe


@dataclass
class IncrementalTimings:
    cold_total_s: float          # trace + extract + instrument + compile + run
    retarget_total_s: float      # instrument + compile + run (trace reused)
    trace_s: float
    extract_s: float
    base_compile_reused: bool    # unprobed executable survived the retarget
    reuse_fraction: float        # analogue of "99% of cells reused"

    def table(self) -> str:
        return (f"cold setup     : {self.cold_total_s * 1e3:9.1f} ms "
                f"(trace {self.trace_s * 1e3:.1f} ms, "
                f"extract {self.extract_s * 1e3:.1f} ms)\n"
                f"retarget       : {self.retarget_total_s * 1e3:9.1f} ms "
                f"({100 * self.retarget_total_s / max(self.cold_total_s, 1e-12):.1f}% of cold)\n"
                f"base executable: {'reused (untouched)' if self.base_compile_reused else 'RECOMPILED'}\n"
                f"artifact reuse : {self.reuse_fraction * 100:.1f}%")


def measure_incremental(fn: Callable, args: Sequence[Any],
                        cfg_a: ProbeConfig, cfg_b: ProbeConfig
                        ) -> IncrementalTimings:
    # the unprobed model executable (must stay untouched)
    base = jax.jit(fn)
    base(*args)
    misses_before = base._cache_size()

    pf = probe(fn, cfg_a)
    t0 = time.perf_counter()
    out, _ = pf(*args)
    jax.block_until_ready(out)
    cold = time.perf_counter() - t0

    t0 = time.perf_counter()
    pf.retarget(cfg_b)
    out, _ = pf(*args)
    jax.block_until_ready(out)
    retarget = time.perf_counter() - t0

    base(*args)
    reused = base._cache_size() == misses_before

    # reuse fraction: cached artifacts (trace + hierarchy) over total
    # setup stages {trace, extract, instrument}; retarget redoes only the
    # instrument stage.
    t_trace = pf.timings.get("trace_s", 0.0)
    t_extract = pf.timings.get("extract_s", 0.0)
    t_instr = pf.timings.get("instrument_s", 1e-12)
    reuse = (t_trace + t_extract) / max(t_trace + t_extract + t_instr, 1e-12)
    return IncrementalTimings(
        cold_total_s=cold, retarget_total_s=retarget,
        trace_s=t_trace, extract_s=t_extract,
        base_compile_reused=reused, reuse_fraction=reuse)
