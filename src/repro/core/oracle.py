"""Independent oracle interpreter — the paper's ILA cross-check.

Re-derives every probe's counters by *concretely* interpreting the same
jaxpr in Python (eager per-equation evaluation, Python loops for
scan/while, Python branch selection for cond) with the same cost table.
It shares no code path with the on-device instrumented program beyond
the hierarchy annotations, so exact integer equality of the two is a
meaningful 100%-accuracy check (Table II analogue).

It also doubles as the "Co-sim" column: cycle-faithful to the model,
oblivious to real machine dynamics (wallclock mode diverges from it the
way the board diverges from co-simulation in Fig 1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
from jax.extend import core
from jax._src.core import eval_jaxpr as _eval_jaxpr

from repro.core import costmodel as cm
from repro.core import kernelprobe
from repro.core.hierarchy import Hierarchy
from repro.core.instrument import ProbeAssignment

_as_jaxpr = cm._as_jaxpr


@dataclass
class OracleCounters:
    n: int
    depth: int
    cycle: int = 0
    starts: List[int] = field(default_factory=list)
    ends: List[int] = field(default_factory=list)
    totals: List[int] = field(default_factory=list)
    last: List[int] = field(default_factory=list)
    calls: List[int] = field(default_factory=list)
    ring: List[List[Tuple[int, int]]] = field(default_factory=list)
    history: List[List[Tuple[int, int]]] = field(default_factory=list)

    def __post_init__(self):
        z = [0] * self.n
        self.starts, self.ends, self.totals = list(z), list(z), list(z)
        self.last, self.calls = list(z), list(z)
        self.ring = [[(0, 0)] * self.depth for _ in range(self.n)]
        self.history = [[] for _ in range(self.n)]


class Oracle:
    def __init__(self, hierarchy: Hierarchy, assignment: ProbeAssignment):
        self.h = hierarchy
        self.asg = assignment
        self._chains: Dict[str, Tuple[int, ...]] = {}

    def _chain(self, path: str) -> Tuple[int, ...]:
        if path in self._chains:
            return self._chains[path]
        ids = []
        cur = ""
        for s in (path.split("/") if path else []):
            cur = f"{cur}/{s}" if cur else s
            pid = self.asg.id_of(cur)
            if pid is not None:
                ids.append(pid)
        self._chains[path] = tuple(ids)
        return tuple(ids)

    # -- events ---------------------------------------------------------
    def _enter(self, st: OracleCounters, pid: int, spill: bool):
        t = st.cycle
        if st.calls[pid] == 0:
            st.starts[pid] = t
        st.last[pid] = t
        depth = self.asg.depth
        slot = st.calls[pid] % depth if spill else min(st.calls[pid], depth - 1)
        if spill or st.calls[pid] < depth:
            s_, e_ = st.ring[pid][slot]
            st.ring[pid][slot] = (t, e_)
        st.history[pid].append((t, -1))

    def _exit(self, st: OracleCounters, pid: int, spill: bool):
        t = st.cycle
        st.ends[pid] = t
        st.totals[pid] += t - st.last[pid]
        depth = self.asg.depth
        slot = st.calls[pid] % depth if spill else min(st.calls[pid], depth - 1)
        if spill or st.calls[pid] < depth:
            s_, _ = st.ring[pid][slot]
            st.ring[pid][slot] = (s_, t)
        s0, _ = st.history[pid][-1]
        st.history[pid][-1] = (s0, t)
        st.calls[pid] += 1

    def _transition(self, st: OracleCounters, old: str, new: str):
        a, b = self._chain(old), self._chain(new)
        i = 0
        while i < len(a) and i < len(b) and a[i] == b[i]:
            i += 1
        for pid in reversed(a[i:]):
            self._exit(st, pid, self.asg.spill[pid])
        for pid in b[i:]:
            self._enter(st, pid, self.asg.spill[pid])

    def _bind(self, eqn, invals):
        """Concrete evaluation of one first-order equation. Subclasses
        may substitute primitives that cannot run outside their original
        context (``meshprobe.ShardOracle`` stubs collectives — cycle
        advances use the precomputed ``info.cycles`` either way)."""
        return eqn.primitive.bind(*invals, **eqn.params)

    # -- evaluation -------------------------------------------------------
    def run(self, closed_jaxpr, args) -> OracleCounters:
        st = OracleCounters(n=self.asg.n, depth=self.asg.depth)
        self._eval(closed_jaxpr.jaxpr, closed_jaxpr.consts, list(args), st, "")
        return st

    def _eval(self, jaxpr, consts, args, st: OracleCounters,
              entry_path: str):
        env: Dict[Any, Any] = {}

        def read(v):
            return v.val if isinstance(v, core.Literal) else env[v]

        def write(v, val):
            env[v] = val

        list(map(write, jaxpr.constvars, consts))
        list(map(write, jaxpr.invars, args))
        cur = entry_path

        for eqn in jaxpr.eqns:
            info = self.h.info_at(eqn, entry_path)
            path = info.path if info else cur
            if path != cur:
                self._transition(st, cur, path)
                cur = path
            name = eqn.primitive.name
            invals = [read(v) for v in eqn.invars]
            if name == "scan":
                outs = self._scan(eqn, invals, st, info)
            elif name == "while":
                outs = self._while(eqn, invals, st, info)
            elif name == "cond":
                outs = self._cond(eqn, invals, st, info)
            elif (name == "pallas_call" and
                  kernelprobe.probed_kernel_path(self, eqn, info)):
                # descended kernel: replay grid steps with Python ints
                outs = kernelprobe.oracle_pallas(self, eqn, invals, st,
                                                 info, cur)
            elif name in ("pjit", "jit", "closed_call", "core_call",
                          "custom_jvp_call", "custom_vjp_call",
                          "custom_vjp_call_jaxpr", "remat", "remat2",
                          "checkpoint"):
                sub = next(iter(cm._sub_jaxprs(eqn)), None)
                if sub is None:
                    outs = eqn.primitive.bind(*invals, **eqn.params)
                else:
                    sub_consts = sub.consts if hasattr(sub, "consts") else []
                    outs = self._eval(_as_jaxpr(sub), sub_consts, invals,
                                      st, cur)
            else:
                outs = self._bind(eqn, invals)
                if not isinstance(outs, (list, tuple)):
                    outs = [outs]
                st.cycle += info.cycles if info else cm.eqn_cost(eqn).cycles
            list(map(write, eqn.outvars, list(outs)))

        self._transition(st, cur, entry_path)
        return [read(v) for v in jaxpr.outvars]

    # -- control flow ------------------------------------------------------
    def _scan(self, eqn, invals, st, info):
        p = eqn.params
        body = p["jaxpr"]
        nc, ncar = p["num_consts"], p["num_carry"]
        length = int(p["length"])
        loop_path = info.sub_path
        loop_pid = self.asg.id_of(loop_path) if loop_path else None
        consts = invals[:nc]
        carry = list(invals[nc:nc + ncar])
        xs = invals[nc + ncar:]
        idxs = range(length - 1, -1, -1) if p["reverse"] else range(length)
        ys_acc: Optional[List[List[Any]]] = None
        for it in idxs:
            x_t = [np.asarray(x)[it] for x in xs]
            if loop_pid is not None:
                self._enter(st, loop_pid, self.asg.spill[loop_pid])
            outs = self._eval(body.jaxpr, body.consts,
                              list(consts) + carry + x_t, st,
                              loop_path or "")
            if loop_pid is not None:
                self._exit(st, loop_pid, self.asg.spill[loop_pid])
            carry = list(outs[:ncar])
            ys_t = outs[ncar:]
            if ys_acc is None:
                ys_acc = [[] for _ in ys_t]
            for acc, y in zip(ys_acc, ys_t):
                acc.append(np.asarray(y))
        ys = []
        if ys_acc is not None:
            for acc in ys_acc:
                arr = np.stack(acc[::-1] if p["reverse"] else acc)
                ys.append(arr)
        return carry + ys

    def _while(self, eqn, invals, st, info):
        p = eqn.params
        cnc, bnc = p["cond_nconsts"], p["body_nconsts"]
        cond_j, body_j = p["cond_jaxpr"], p["body_jaxpr"]
        cond_cycles = cm.static_jaxpr_cycles(cond_j.jaxpr)
        cconsts = invals[:cnc]
        bconsts = invals[cnc:cnc + bnc]
        carry = list(invals[cnc + bnc:])
        loop_path = info.sub_path
        body_path = f"{loop_path}/body" if loop_path else ""
        loop_pid = self.asg.id_of(loop_path) if loop_path else None
        while True:
            pred = _eval_jaxpr(cond_j.jaxpr, cond_j.consts,
                                   *(list(cconsts) + carry))[0]
            st.cycle += cond_cycles
            if not bool(np.asarray(pred)):
                break
            if loop_pid is not None:
                self._enter(st, loop_pid, self.asg.spill[loop_pid])
            carry = list(self._eval(body_j.jaxpr, body_j.consts,
                                    list(bconsts) + carry, st, body_path))
            if loop_pid is not None:
                self._exit(st, loop_pid, self.asg.spill[loop_pid])
        return carry

    def _cond(self, eqn, invals, st, info):
        branches = eqn.params["branches"]
        index, *ops = invals
        bi = int(np.clip(int(np.asarray(index)), 0, len(branches) - 1))
        br = branches[bi]
        cond_path = info.sub_path
        return self._eval(br.jaxpr, br.consts, list(ops), st,
                          f"{cond_path}/branch{bi}" if cond_path else "")


class KernelOracle(Oracle):
    """Interpret-mode grid-step replay oracle.

    The base :class:`Oracle` already replays descended ``pallas_call``
    equations grid step by grid step (``kernelprobe.oracle_pallas``)
    whenever the hierarchy was extracted with ``kernel_probes``; this
    alias names that capability for kernel-level validation and adds a
    direct per-kernel replay helper used by the conformance tests.
    """

    def grid_totals(self, counters: OracleCounters,
                    paths: Tuple[str, ...]) -> Dict[str, int]:
        """Per-grid-probe total cycles from a replay (paths ending in
        ``/grid``), keyed by path — convenience for asserting the
        sum-of-grid-steps == kernel-scope invariant."""
        out: Dict[str, int] = {}
        for pid, p in enumerate(paths):
            if p.endswith("/" + kernelprobe.GRID_SEG):
                out[p] = counters.totals[pid]
        return out
