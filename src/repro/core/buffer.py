"""Bounded on-device ring buffers + host ("DRAM") offload sink.

The paper's performance counters buffer (start, end) timestamps in shift
registers / BRAM and assert a dump signal to spill to DRAM when full.
Here the ring lives in the on-device ProbeState; when a spill-enabled
probe's ring fills, an *ordered* ``io_callback`` ships the full row to
the host sink below, which reassembles the complete per-iteration
history. Equality tests run with spills on AND off — the totals must be
identical (offload must never lose cycles).
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

from repro.core.counters import c64_to_int


def row_bounds(row: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Decode a ring row ((depth, 2, 2) uint32) into (starts, ends)
    int64 arrays — the whole-array form the vectorized consumers use."""
    return (np.atleast_1d(c64_to_int(np.asarray(row)[:, 0])),
            np.atleast_1d(c64_to_int(np.asarray(row)[:, 1])))


def row_spans(row: np.ndarray) -> List[Tuple[int, int]]:
    """Decode a ring row ((depth, 2, 2) uint32) into (start, end) pairs."""
    starts, ends = row_bounds(row)
    return list(zip(starts.tolist(), ends.tolist()))


def row_durations(row: np.ndarray) -> np.ndarray:
    """Decode a ring row into per-call cycle durations (int64)."""
    starts, ends = row_bounds(row)
    return ends - starts


class HostSink:
    """Host-side store for offloaded probe records.

    ``dump`` is the ``io_callback`` target; it validates/copies the ring
    row and hands it to ``_store``, which subclasses override to consume
    rows differently (e.g. ``streaming.StreamingSink`` aggregates them
    in constant memory instead of retaining the raw history).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._rows: Dict[int, List[Tuple[int, np.ndarray]]] = defaultdict(list)
        self.dumps = 0
        self.bytes_received = 0

    def reset(self):
        with self._lock:
            self._rows.clear()
            self.dumps = 0
            self.bytes_received = 0

    def dump(self, probe_id: int, should_dump, base_count, ring_row):
        """io_callback target. ring_row: (depth, 2, 2) uint32."""
        if not bool(np.asarray(should_dump)):
            return
        row = np.asarray(ring_row).copy()
        with self._lock:
            self.dumps += 1
            self.bytes_received += row.nbytes
        self._store(int(probe_id), int(np.asarray(base_count)), row)

    def _store(self, probe_id: int, base_count: int, row: np.ndarray):
        with self._lock:
            self._rows[probe_id].append((base_count, row))

    def records(self, probe_id: int) -> List[Tuple[int, int]]:
        """All offloaded (start_cycle, end_cycle) records, in order."""
        out: List[Tuple[int, int]] = []
        with self._lock:
            rows = sorted(self._rows.get(probe_id, []), key=lambda r: r[0])
        for _base, row in rows:
            out.extend(row_spans(row))
        return out


def state_bytes(n_probes: int, depth: int, layout: str = "packed") -> int:
    """On-device profiler state footprint (the resource-model 'FF' term).

    The packed SoA layout carries three c64 planes (starts/totals/ends)
    — the legacy dict layout adds a fourth (``last``) that the packed
    enter-subtract/exit-add trick eliminates."""
    planes = 4 if layout == "legacy" else 3
    per_probe = planes * 8 + 4       # c64 counter planes + calls (u32)
    ring = depth * 2 * 2 * 4         # (depth, start/end, hi/lo) u32
    return 8 + n_probes * (per_probe + ring)
