"""Analytical instrumentation-overhead model (paper §IV-D).

The paper budgets LUT/FF as

    C_axi + C_pc + C_decode*log2(N) + Σ_i (C_1 + C_2 * D_i)

TPU programs spend "resource" as extra HLO equations and on-device state
bytes instead; the model keeps the same functional form:

    extra_eqns(N, D, E, ...)  ~  fitted linear model (see OverheadModel)
    state_bytes(N, D)    =   8 + N*(28 + 16*D)   (packed; legacy 36 + 16D)

where N = probes, D = ring depth, E = static event sites. The constants
are fitted once against measured instrumented-jaxpr deltas
(``bench_overhead`` reproduces the paper's Fig 9 predicted-vs-measured
plot), then drive the adaptive allocation in ``dse.py``: if predicted
state exceeds the budget, depth shrinks / probe count is capped — the
paper's "adjusts the number of profiling modules and queue depths".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.core import kernelprobe
from repro.core.buffer import state_bytes
from repro.core.pragma import ProbeConfig, ProbedFunction, probe


def count_sites(pf: ProbedFunction) -> Dict[str, int]:
    """Static structure of the instrumented program: ``event_sites``
    (enter/exit emissions), ``transitions`` (batched scope-delta update
    sites — what the packed layout pays per), and ``cf_sites``
    (threaded control-flow constructs: while/cond always, scans whose
    bodies carry probe state) — the branch/loop feature that makes the
    overhead model price control-flow-heavy configs correctly."""
    h = pf.hierarchy
    asg = pf.assignment
    from repro.core.instrument import Instrumenter
    interp = Instrumenter(h, asg)
    sites = 0
    transitions = 0
    cf_sites = 0

    def delta(old, new):
        nonlocal sites, transitions
        a, b = interp._chain(old), interp._chain(new)
        i = 0
        while i < len(a) and i < len(b) and a[i] == b[i]:
            i += 1
        if len(a[i:]) + len(b[i:]):
            sites += len(a[i:]) + len(b[i:])
            transitions += 1

    def walk(jaxpr, entry_path, site=None):
        # ``site`` overrides the info-lookup key inside kernel subtrees,
        # whose rows are all registered under the grid node path
        nonlocal sites, transitions, cf_sites
        cur = entry_path
        for eqn in jaxpr.eqns:
            info = h.info_at(eqn, site or entry_path)
            path = info.path if info else cur
            if path != cur:
                delta(cur, path)
                cur = path
            name = eqn.primitive.name
            if name == "scan":
                body = eqn.params["jaxpr"].jaxpr
                looped = (info and info.sub_path and
                          asg.id_of(info.sub_path) is not None)
                if interp._needs_threading(body) or looped:
                    cf_sites += 1
                    if looped:
                        sites += 2
                        transitions += 2
                    walk(body, info.sub_path if info and info.sub_path
                         else "")
            elif name == "while":
                cf_sites += 1
                if info and info.sub_path and \
                        asg.id_of(info.sub_path) is not None:
                    sites += 2
                    transitions += 2
                walk(eqn.params["body_jaxpr"].jaxpr,
                     (info.sub_path + "/body") if info and info.sub_path
                     else "")
            elif name == "cond":
                cf_sites += 1
                for bi, br in enumerate(eqn.params["branches"]):
                    walk(br.jaxpr,
                         f"{info.sub_path}/branch{bi}"
                         if info and info.sub_path else "")
            elif name == "pallas_call" and \
                    kernelprobe.probed_kernel_path(interp, eqn, info):
                # descended kernel: the grid-replay scan is a threaded
                # loop whose body emits at inner-scope deltas, plus the
                # per-step grid enter/exit when the grid node is probed
                from repro.core.hierarchy import _as_jaxpr
                gpath = f"{info.sub_path}/{kernelprobe.GRID_SEG}"
                cf_sites += 1
                if asg.id_of(gpath) is not None:
                    sites += 2
                    transitions += 2
                walk(_as_jaxpr(eqn.params["jaxpr"]), gpath, site=gpath)
            else:
                import repro.core.costmodel as cm
                for sub in cm._sub_jaxprs(eqn):
                    walk(cm._as_jaxpr(sub), cur, site=site)
                    break
        delta(cur, entry_path)

    walk(h.closed_jaxpr.jaxpr, "")
    return dict(event_sites=sites, transitions=transitions,
                cf_sites=cf_sites)


def count_event_sites(pf: ProbedFunction) -> int:
    """Static enter/exit emission sites in the instrumented program."""
    return count_sites(pf)["event_sites"]


def measure_overhead(fn, args, cfg: ProbeConfig) -> Dict[str, Any]:
    """Measured instrumentation cost: extra jaxpr eqns + state bytes."""
    base = jax.make_jaxpr(fn)(*args)
    base_eqns = _total_eqns(base.jaxpr)
    pf = probe(fn, cfg)
    pf.trace(*args)
    pf._build(*args)
    inst = jax.make_jaxpr(lambda *a: pf._jitted.__wrapped__(*a))(*args)
    inst_eqns = _total_eqns(inst.jaxpr)
    n = pf.assignment.n
    sites = count_sites(pf)
    return dict(
        base_eqns=base_eqns,
        inst_eqns=inst_eqns,
        extra_eqns=inst_eqns - base_eqns,
        n_probes=n,
        depth=cfg.buffer_depth,
        event_sites=sites["event_sites"],
        transitions=sites["transitions"],
        cf_sites=sites["cf_sites"],
        state_bytes=state_bytes(n, cfg.buffer_depth, layout=cfg.layout),
    )


def _total_eqns(jaxpr) -> int:
    import repro.core.costmodel as cm
    total = len(jaxpr.eqns)
    for eqn in jaxpr.eqns:
        for sub in cm._sub_jaxprs(eqn):
            total += _total_eqns(cm._as_jaxpr(sub))
    return total


@dataclass
class OverheadModel:
    """extra_eqns ~ c0 + c1*n_probes + c2*event_sites + c3*transitions
    + c4*cf_sites.

    ``cf_sites`` (threaded while/cond/scan constructs) is what makes
    control-flow-heavy configs price correctly: a threaded loop pays
    carry plumbing and per-iteration emission the flat event count
    cannot see (the seed model mispriced the while-loop config by 28%).
    ``n_probes`` is the paper's per-probe term (Σ_i C_1 + C_2·D_i):
    state init/decode plumbing scales with the probe count even when
    extra probes land on scopes whose transition deltas coincide — the
    conformance sweep found configs with identical site counts but
    40-eqn-per-probe measured spreads (seed 33).
    """
    coefs: Tuple[float, ...] = (0.0, 0.0, 0.0, 0.0, 0.0)

    @staticmethod
    def features(sample: Dict[str, Any]) -> List[float]:
        return [1.0, float(sample.get("n_probes", 0)),
                float(sample["event_sites"]),
                float(sample.get("transitions",
                                 sample["event_sites"])),
                float(sample.get("cf_sites", 0))]

    @classmethod
    def fit(cls, samples: Sequence[Dict[str, Any]]) -> "OverheadModel":
        X = np.array([cls.features(s) for s in samples])
        y = np.array([s["extra_eqns"] for s in samples], dtype=float)
        coefs, *_ = np.linalg.lstsq(X, y, rcond=None)
        return cls(coefs=tuple(float(c) for c in coefs))

    def predict_eqns(self, sample: Dict[str, Any]) -> float:
        return float(np.dot(self.coefs, self.features(sample)))

    @staticmethod
    def predict_state_bytes(n_probes: int, depth: int,
                            layout: str = "packed") -> int:
        return state_bytes(n_probes, depth, layout=layout)


def adapt_allocation(n_candidates: int, depth: int, budget_bytes: int
                     ) -> Tuple[int, int]:
    """Paper §IV-D resource-allocation adaptation: fit (N, D) under a
    state-byte budget, preferring to keep probes and shrink depth."""
    d = depth
    while d > 1 and state_bytes(n_candidates, d) > budget_bytes:
        d //= 2
    n = n_candidates
    while n > 1 and state_bytes(n, d) > budget_bytes:
        n -= 1
    return n, d
