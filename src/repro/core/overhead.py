"""Analytical instrumentation-overhead model (paper §IV-D).

The paper budgets LUT/FF as

    C_axi + C_pc + C_decode*log2(N) + Σ_i (C_1 + C_2 * D_i)

TPU programs spend "resource" as extra HLO equations and on-device state
bytes instead; the model keeps the same functional form:

    extra_eqns(N, D, E)  ~=  c0 + c1*E + c2*log2(N+1)
    state_bytes(N, D)    =   8 + N*(36 + 16*D)          (exact, by layout)

where N = probes, D = ring depth, E = static event sites. The constants
are fitted once against measured instrumented-jaxpr deltas
(``bench_overhead`` reproduces the paper's Fig 9 predicted-vs-measured
plot), then drive the adaptive allocation in ``dse.py``: if predicted
state exceeds the budget, depth shrinks / probe count is capped — the
paper's "adjusts the number of profiling modules and queue depths".
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.core.buffer import state_bytes
from repro.core.pragma import ProbeConfig, ProbedFunction, probe


def count_event_sites(pf: ProbedFunction) -> int:
    """Static enter/exit emission sites in the instrumented program."""
    h = pf.hierarchy
    asg = pf.assignment
    from repro.core.instrument import Instrumenter
    interp = Instrumenter(h, asg)
    sites = 0

    def walk(jaxpr, entry_path):
        nonlocal sites
        cur = entry_path
        for eqn in jaxpr.eqns:
            info = h.eqn_info.get(id(eqn))
            path = info.path if info else cur
            if path != cur:
                a, b = interp._chain(cur), interp._chain(path)
                i = 0
                while i < len(a) and i < len(b) and a[i] == b[i]:
                    i += 1
                sites += len(a[i:]) + len(b[i:])
                cur = path
            name = eqn.primitive.name
            if name == "scan":
                body = eqn.params["jaxpr"].jaxpr
                if interp._needs_threading(body) or (
                        info and info.sub_path and
                        asg.id_of(info.sub_path) is not None):
                    if info and info.sub_path and \
                            asg.id_of(info.sub_path) is not None:
                        sites += 2
                    walk(body, info.sub_path or "")
            elif name == "while":
                if info and info.sub_path and \
                        asg.id_of(info.sub_path) is not None:
                    sites += 2
                walk(eqn.params["body_jaxpr"].jaxpr,
                     (info.sub_path + "/body") if info and info.sub_path
                     else "")
            elif name == "cond":
                for bi, br in enumerate(eqn.params["branches"]):
                    walk(br.jaxpr,
                         f"{info.sub_path}/branch{bi}"
                         if info and info.sub_path else "")
            else:
                import repro.core.costmodel as cm
                for sub in cm._sub_jaxprs(eqn):
                    walk(cm._as_jaxpr(sub), cur)
                    break
        a, b = interp._chain(cur), interp._chain(entry_path)
        i = 0
        while i < len(a) and i < len(b) and a[i] == b[i]:
            i += 1
        sites += len(a[i:]) + len(b[i:])

    walk(h.closed_jaxpr.jaxpr, "")
    return sites


def measure_overhead(fn, args, cfg: ProbeConfig) -> Dict[str, Any]:
    """Measured instrumentation cost: extra jaxpr eqns + state bytes."""
    base = jax.make_jaxpr(fn)(*args)
    base_eqns = _total_eqns(base.jaxpr)
    pf = probe(fn, cfg)
    pf.trace(*args)
    pf._build(*args)
    inst = jax.make_jaxpr(lambda *a: pf._jitted.__wrapped__(*a))(*args)
    inst_eqns = _total_eqns(inst.jaxpr)
    n = pf.assignment.n
    return dict(
        base_eqns=base_eqns,
        inst_eqns=inst_eqns,
        extra_eqns=inst_eqns - base_eqns,
        n_probes=n,
        depth=cfg.buffer_depth,
        event_sites=count_event_sites(pf),
        state_bytes=state_bytes(n, cfg.buffer_depth),
    )


def _total_eqns(jaxpr) -> int:
    import repro.core.costmodel as cm
    total = len(jaxpr.eqns)
    for eqn in jaxpr.eqns:
        for sub in cm._sub_jaxprs(eqn):
            total += _total_eqns(cm._as_jaxpr(sub))
    return total


@dataclass
class OverheadModel:
    """extra_eqns ~ c0 + c1*event_sites + c2*log2(N+1)."""
    coefs: Tuple[float, float, float] = (0.0, 0.0, 0.0)

    @staticmethod
    def features(sample: Dict[str, Any]) -> List[float]:
        return [1.0, float(sample["event_sites"]),
                math.log2(sample["n_probes"] + 1.0)]

    @classmethod
    def fit(cls, samples: Sequence[Dict[str, Any]]) -> "OverheadModel":
        X = np.array([cls.features(s) for s in samples])
        y = np.array([s["extra_eqns"] for s in samples], dtype=float)
        coefs, *_ = np.linalg.lstsq(X, y, rcond=None)
        return cls(coefs=tuple(float(c) for c in coefs))

    def predict_eqns(self, sample: Dict[str, Any]) -> float:
        return float(np.dot(self.coefs, self.features(sample)))

    @staticmethod
    def predict_state_bytes(n_probes: int, depth: int) -> int:
        return state_bytes(n_probes, depth)


def adapt_allocation(n_candidates: int, depth: int, budget_bytes: int
                     ) -> Tuple[int, int]:
    """Paper §IV-D resource-allocation adaptation: fit (N, D) under a
    state-byte budget, preferring to keep probes and shrink depth."""
    d = depth
    while d > 1 and state_bytes(n_candidates, d) > budget_bytes:
        d //= 2
    n = n_candidates
    while n > 1 and state_bytes(n, d) > budget_bytes:
        n -= 1
    return n, d
