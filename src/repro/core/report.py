"""Result collection + visualization (paper stage 5, Fig 4 / Fig 14).

Builds per-probe rows (calls, total cycles, start/end, first-N iteration
spans) from the device record, merges DRAM-offloaded history from the
host sink, and renders:

- a tabular report (calls / cycles / % of span / source location),
- an ASCII execution timeline (the Fig 4 waveform),
- a bottleneck bump chart across {C-synth-static, oracle, measured}
  (the Fig 14 ranking-shift view).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.buffer import HostSink
from repro.core.hierarchy import Hierarchy
from repro.core.instrument import ProbeAssignment, decode_record


@dataclass
class ProbeRow:
    path: str
    calls: int
    total_cycles: int
    start: int
    end: int
    iters: List[Tuple[int, int]]
    source: str = ""
    static_cycles: Optional[int] = None
    dynamic: bool = False


@dataclass
class Report:
    rows: List[ProbeRow]
    span: int
    cycle_source: str

    def row(self, path: str) -> Optional[ProbeRow]:
        for r in self.rows:
            if r.path == path:
                return r
        return None

    def bottleneck(self, prefix: str = "") -> Optional[ProbeRow]:
        cands = [r for r in self.rows
                 if r.path.startswith(prefix) and r.path != prefix]
        leaf = [r for r in cands
                if not any(o.path.startswith(r.path + "/") for o in cands)]
        pool = leaf or cands
        return max(pool, key=lambda r: r.total_cycles, default=None)

    # ---------------------------------------------------------- rendering
    def table(self) -> str:
        w = max((len(r.path) for r in self.rows), default=4) + 2
        lines = [f"{'module':<{w}}{'calls':>7}{'cycles':>14}{'%span':>7}"
                 f"{'start':>12}{'end':>12}  {'static(C-synth)':>16}  source"]
        for r in self.rows:
            pct = 100.0 * r.total_cycles / self.span if self.span else 0.0
            stat = ("?" if r.dynamic else str(r.static_cycles)
                    ) if r.static_cycles is not None else ""
            lines.append(f"{r.path:<{w}}{r.calls:>7}{r.total_cycles:>14}"
                         f"{pct:>6.1f}%{r.start:>12}{r.end:>12}"
                         f"  {stat:>16}  {r.source}")
        return "\n".join(lines)

    def timeline(self, width: int = 72) -> str:
        """ASCII waveform: one lane per probe, bars over the global span."""
        if not self.rows or self.span <= 0:
            return "(empty)"
        w = max(len(r.path) for r in self.rows) + 2
        lines = []
        for r in self.rows:
            lane = [" "] * width
            spans = r.iters if r.iters else [(r.start, r.end)]
            for (s, e) in spans:
                i0 = int(width * s / self.span)
                i1 = max(i0 + 1, int(width * e / self.span))
                for i in range(i0, min(i1, width)):
                    lane[i] = "█"
            # totals bar may exceed the recorded iters (truncated rings)
            lines.append(f"{r.path:<{w}}|{''.join(lane)}|")
        scale = f"{'':<{w}} 0{'cycles':^{width - 10}}{self.span}"
        return "\n".join(lines + [scale])

    def to_dict(self) -> Dict[str, Any]:
        return {
            "span": self.span,
            "cycle_source": self.cycle_source,
            "rows": [r.__dict__ for r in self.rows],
        }


def build_report(h: Hierarchy, asg: ProbeAssignment, record: Dict[str, Any],
                 sink: Optional[HostSink], cycle_source: str) -> Report:
    rec = decode_record(record)
    starts, ends = rec["starts"], rec["ends"]
    totals, calls, ring = rec["totals"], rec["calls"], rec["ring"]
    span = rec["cycle"]
    rows: List[ProbeRow] = []
    for pid, path in enumerate(asg.paths):
        node = h.node(path)
        n_calls = int(calls[pid])
        iters: List[Tuple[int, int]] = []
        if sink is not None and asg.spill[pid]:
            iters.extend(sink.records(pid))
        # ring holds the first `depth` iterations, or — with spill — the
        # most recent partial window beyond the dumps
        kept = (n_calls % asg.depth) if asg.spill[pid] \
            else min(n_calls, asg.depth)
        iters.extend((int(ring[pid, s, 0]), int(ring[pid, s, 1]))
                     for s in range(kept))
        static = None
        dynamic = False
        if node is not None:
            # C-synth-style TOTAL estimate: per-visit static cycles times
            # the product of ancestor (and own) static loop trip counts;
            # any while/cond on the path makes the estimate unknowable.
            mult = 1
            cur = ""
            for seg in path.split("/"):
                cur = f"{cur}/{seg}" if cur else seg
                anc = h.node(cur)
                if anc is None:
                    continue
                if anc.kind == "loop" and anc.trip_count:
                    mult *= anc.trip_count
                if anc.kind in ("while", "cond"):
                    dynamic = True
            static = node.static_cycles * mult
            dynamic = dynamic or node.dynamic
        rows.append(ProbeRow(path=path, calls=n_calls,
                             total_cycles=int(totals[pid]),
                             start=int(starts[pid]), end=int(ends[pid]),
                             iters=iters,
                             source=node.source if node else "",
                             static_cycles=static, dynamic=dynamic))
    return Report(rows=rows, span=span, cycle_source=cycle_source)


def streaming_table(snapshot) -> str:
    """Running table for a live ``ProbeSession`` snapshot.

    ``snapshot`` is a ``streaming.StreamSnapshot`` (duck-typed: ``rows``
    with per-probe running stats, ``steps``, ``span``). Shows the
    constant-memory aggregates — counts, totals, EMA and the
    log-bucket-derived p50/p99 — instead of raw per-iteration spans.
    """
    rows = snapshot.rows
    w = max((len(r.path) for r in rows), default=6) + 2
    head = (f"{'module':<{w}}{'calls':>9}{'cycles':>14}{'%span':>7}"
            f"{'mean':>10}{'ema':>10}{'min':>9}{'p50':>9}{'p99':>9}"
            f"{'max':>9}")
    lines = [f"# session: {snapshot.steps} steps, span={snapshot.span} "
             f"cycles", head]
    for r in rows:
        pct = 100.0 * r.total_cycles / snapshot.span if snapshot.span else 0.0
        lines.append(
            f"{r.path:<{w}}{r.calls:>9}{r.total_cycles:>14}{pct:>6.1f}%"
            f"{r.mean:>10.1f}{r.ema:>10.1f}{r.min:>9}{r.p50:>9}{r.p99:>9}"
            f"{r.max:>9}")
    return "\n".join(lines)


def streaming_bump_chart(snapshot, top: int = 5, width: int = 18) -> str:
    """Fig-14-style ranking shifts across the session's time windows.

    Each retained window (bounded deque — constant memory) becomes one
    bump-chart stage ranking probes by cycles spent *inside that
    window*, so hot-spot drift over a long-running session is visible.
    """
    if not snapshot.windows:
        return "(no complete windows yet)"
    rankings: Dict[str, List[str]] = {}
    for wdw in snapshot.windows:
        order = np.argsort(-np.asarray(wdw.totals, dtype=np.int64),
                           kind="stable")[:top]
        rankings[wdw.label] = [snapshot.paths[i] for i in order
                               if wdw.totals[i] > 0]
    return bump_chart(rankings, width=width)


def dse_leaderboard(result, top: int = 10) -> str:
    """Ranked table for a ``dse.TuneResult``: measured candidates by
    probed cycles/step (speedup vs the untuned default), then the
    statically pruned ones with their rejection reason."""
    def cfg_s(cfg):
        return ",".join(f"{k}={v}" for k, v in sorted(cfg.items()))

    measured = sorted((t for t in result.trials if t.measured),
                      key=lambda t: t.cycles_per_step)
    pruned = [t for t in result.trials if t.pruned is not None]
    base = (result.default.cycles_per_step
            if result.default is not None and result.default.measured
            else None)
    w = max([len(cfg_s(t.config)) for t in result.trials] + [6]) + 2
    lines = [f"# DSE leaderboard: {result.kernel_id} on {result.device} — "
             f"{result.n_candidates} candidates, {result.n_pruned} pruned, "
             f"{result.n_measurements} measured "
             f"({result.measured_steps} probed steps), "
             f"{result.n_cache_hits} cache hits",
             f"{'config':<{w}}{'cyc/step':>12}{'steps':>7}{'speedup':>9}"
             f"{'vmem_B':>9}  flags"]
    for rank, t in enumerate(measured[:top]):
        su = f"{base / t.cycles_per_step:8.2f}x" if base else f"{'-':>9}"
        flags = []
        if result.best is t:
            flags.append("BEST")
        if t.is_default:
            flags.append("default")
        if t.cache_hits:
            flags.append("cached")
        lines.append(
            f"{cfg_s(t.config):<{w}}{t.cycles_per_step:>12.1f}"
            f"{t.steps:>7}{su}"
            f"{t.resources.vmem_bytes if t.resources else 0:>9}"
            f"  {' '.join(flags)}")
    for t in pruned[:top]:
        lines.append(f"{cfg_s(t.config):<{w}}{'pruned':>12}{'':>7}{'':>9}"
                     f"{t.resources.vmem_bytes if t.resources else 0:>9}"
                     f"  [{t.pruned}]")
    return "\n".join(lines)


def bump_chart(rankings: Dict[str, List[str]], width: int = 18) -> str:
    """Fig-14-style bottleneck ranking shifts across profiling stages.

    rankings: stage name -> module paths ordered worst-first.
    """
    stages = list(rankings)
    mods = []
    for s in stages:
        for m in rankings[s]:
            if m not in mods:
                mods.append(m)
    lines = ["  ".join(f"{s:<{width}}" for s in stages)]
    depth = max(len(v) for v in rankings.values())
    for rank in range(depth):
        cells = []
        for s in stages:
            v = rankings[s]
            cells.append(f"#{rank + 1} {v[rank] if rank < len(v) else '':<{width - 3}}")
        lines.append("  ".join(cells))
    return "\n".join(lines)
