"""Result collection + visualization (paper stage 5, Fig 4 / Fig 14).

Builds per-probe rows (calls, total cycles, start/end, first-N iteration
spans) from the device record, merges DRAM-offloaded history from the
host sink, and renders:

- a tabular report (calls / cycles / % of span / source location),
- an ASCII execution timeline (the Fig 4 waveform),
- a bottleneck bump chart across {C-synth-static, oracle, measured}
  (the Fig 14 ranking-shift view).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.buffer import HostSink
from repro.core.hierarchy import Hierarchy
from repro.core.instrument import ProbeAssignment, decode_record


@dataclass
class ProbeRow:
    path: str
    calls: int
    total_cycles: int
    start: int
    end: int
    iters: List[Tuple[int, int]]
    source: str = ""
    static_cycles: Optional[int] = None
    dynamic: bool = False


@dataclass
class Report:
    rows: List[ProbeRow]
    span: int
    cycle_source: str

    def row(self, path: str) -> Optional[ProbeRow]:
        for r in self.rows:
            if r.path == path:
                return r
        return None

    def bottleneck(self, prefix: str = "") -> Optional[ProbeRow]:
        cands = [r for r in self.rows
                 if r.path.startswith(prefix) and r.path != prefix]
        leaf = [r for r in cands
                if not any(o.path.startswith(r.path + "/") for o in cands)]
        pool = leaf or cands
        return max(pool, key=lambda r: r.total_cycles, default=None)

    # ---------------------------------------------------------- rendering
    def table(self) -> str:
        w = max((len(r.path) for r in self.rows), default=4) + 2
        lines = [f"{'module':<{w}}{'calls':>7}{'cycles':>14}{'%span':>7}"
                 f"{'start':>12}{'end':>12}  {'static(C-synth)':>16}  source"]
        for r in self.rows:
            pct = 100.0 * r.total_cycles / self.span if self.span else 0.0
            stat = ("?" if r.dynamic else str(r.static_cycles)
                    ) if r.static_cycles is not None else ""
            lines.append(f"{r.path:<{w}}{r.calls:>7}{r.total_cycles:>14}"
                         f"{pct:>6.1f}%{r.start:>12}{r.end:>12}"
                         f"  {stat:>16}  {r.source}")
        return "\n".join(lines)

    def timeline(self, width: int = 72) -> str:
        """ASCII waveform: one lane per probe, bars over the global span."""
        if not self.rows or self.span <= 0:
            return "(empty)"
        w = max(len(r.path) for r in self.rows) + 2
        lines = []
        for r in self.rows:
            lane = [" "] * width
            spans = r.iters if r.iters else [(r.start, r.end)]
            for (s, e) in spans:
                i0 = int(width * s / self.span)
                i1 = max(i0 + 1, int(width * e / self.span))
                for i in range(i0, min(i1, width)):
                    lane[i] = "█"
            # totals bar may exceed the recorded iters (truncated rings)
            lines.append(f"{r.path:<{w}}|{''.join(lane)}|")
        scale = f"{'':<{w}} 0{'cycles':^{width - 10}}{self.span}"
        return "\n".join(lines + [scale])

    def to_dict(self) -> Dict[str, Any]:
        return {
            "span": self.span,
            "cycle_source": self.cycle_source,
            "rows": [r.__dict__ for r in self.rows],
        }


def build_report(h: Hierarchy, asg: ProbeAssignment, record: Dict[str, Any],
                 sink: Optional[HostSink], cycle_source: str) -> Report:
    rec = decode_record(record)
    starts, ends = rec["starts"], rec["ends"]
    totals, calls, ring = rec["totals"], rec["calls"], rec["ring"]
    span = rec["cycle"]
    rows: List[ProbeRow] = []
    for pid, path in enumerate(asg.paths):
        node = h.node(path)
        n_calls = int(calls[pid])
        iters: List[Tuple[int, int]] = []
        if sink is not None and asg.spill[pid]:
            iters.extend(sink.records(pid))
        # ring holds the first `depth` iterations, or — with spill — the
        # most recent partial window beyond the dumps
        kept = (n_calls % asg.depth) if asg.spill[pid] \
            else min(n_calls, asg.depth)
        iters.extend((int(ring[pid, s, 0]), int(ring[pid, s, 1]))
                     for s in range(kept))
        static = None
        dynamic = False
        if node is not None:
            # C-synth-style TOTAL estimate: per-visit static cycles times
            # the product of ancestor (and own) static loop trip counts;
            # any while/cond on the path makes the estimate unknowable.
            mult = 1
            cur = ""
            for seg in path.split("/"):
                cur = f"{cur}/{seg}" if cur else seg
                anc = h.node(cur)
                if anc is None:
                    continue
                if anc.kind == "loop" and anc.trip_count:
                    mult *= anc.trip_count
                if anc.kind in ("while", "cond"):
                    dynamic = True
            static = node.static_cycles * mult
            dynamic = dynamic or node.dynamic
        rows.append(ProbeRow(path=path, calls=n_calls,
                             total_cycles=int(totals[pid]),
                             start=int(starts[pid]), end=int(ends[pid]),
                             iters=iters,
                             source=node.source if node else "",
                             static_cycles=static, dynamic=dynamic))
    return Report(rows=rows, span=span, cycle_source=cycle_source)


def streaming_table(snapshot) -> str:
    """Running table for a live ``ProbeSession`` snapshot.

    ``snapshot`` is a ``streaming.StreamSnapshot`` (duck-typed: ``rows``
    with per-probe running stats, ``steps``, ``span``). Shows the
    constant-memory aggregates — counts, totals, EMA and the
    log-bucket-derived p50/p99 — instead of raw per-iteration spans.
    """
    rows = snapshot.rows
    w = max((len(r.path) for r in rows), default=6) + 2
    head = (f"{'module':<{w}}{'calls':>9}{'cycles':>14}{'%span':>7}"
            f"{'mean':>10}{'ema':>10}{'min':>9}{'p50':>9}{'p99':>9}"
            f"{'max':>9}")
    lines = [f"# session: {snapshot.steps} steps, span={snapshot.span} "
             f"cycles", head]
    for r in rows:
        pct = 100.0 * r.total_cycles / snapshot.span if snapshot.span else 0.0
        lines.append(
            f"{r.path:<{w}}{r.calls:>9}{r.total_cycles:>14}{pct:>6.1f}%"
            f"{r.mean:>10.1f}{r.ema:>10.1f}{r.min:>9}{r.p50:>9}{r.p99:>9}"
            f"{r.max:>9}")
    return "\n".join(lines)


def streaming_bump_chart(snapshot, top: int = 5, width: int = 18) -> str:
    """Fig-14-style ranking shifts across the session's time windows.

    Each retained window (bounded deque — constant memory) becomes one
    bump-chart stage ranking probes by cycles spent *inside that
    window*, so hot-spot drift over a long-running session is visible.
    """
    if not snapshot.windows:
        return "(no complete windows yet)"
    rankings: Dict[str, List[str]] = {}
    for wdw in snapshot.windows:
        order = np.argsort(-np.asarray(wdw.totals, dtype=np.int64),
                           kind="stable")[:top]
        rankings[wdw.label] = [snapshot.paths[i] for i in order
                               if wdw.totals[i] > 0]
    return bump_chart(rankings, width=width)


def dse_leaderboard(result, top: int = 10) -> str:
    """Ranked table for a ``dse.TuneResult``: measured candidates by
    probed cycles/step (speedup vs the untuned default), then the
    statically pruned ones with their rejection reason."""
    def cfg_s(cfg):
        return ",".join(f"{k}={v}" for k, v in sorted(cfg.items()))

    measured = sorted((t for t in result.trials if t.measured),
                      key=lambda t: t.cycles_per_step)
    pruned = [t for t in result.trials if t.pruned is not None]
    base = (result.default.cycles_per_step
            if result.default is not None and result.default.measured
            else None)
    w = max([len(cfg_s(t.config)) for t in result.trials] + [6]) + 2
    lines = [f"# DSE leaderboard: {result.kernel_id} on {result.device} — "
             f"{result.n_candidates} candidates, {result.n_pruned} pruned, "
             f"{result.n_measurements} measured "
             f"({result.measured_steps} probed steps), "
             f"{result.n_cache_hits} cache hits",
             f"{'config':<{w}}{'cyc/step':>12}{'steps':>7}{'speedup':>9}"
             f"{'vmem_B':>9}  flags"]
    for rank, t in enumerate(measured[:top]):
        su = f"{base / t.cycles_per_step:8.2f}x" if base else f"{'-':>9}"
        flags = []
        if result.best is t:
            flags.append("BEST")
        if t.is_default:
            flags.append("default")
        if t.cache_hits:
            flags.append("cached")
        lines.append(
            f"{cfg_s(t.config):<{w}}{t.cycles_per_step:>12.1f}"
            f"{t.steps:>7}{su}"
            f"{t.resources.vmem_bytes if t.resources else 0:>9}"
            f"  {' '.join(flags)}")
    for t in pruned[:top]:
        lines.append(f"{cfg_s(t.config):<{w}}{'pruned':>12}{'':>7}{'':>9}"
                     f"{t.resources.vmem_bytes if t.resources else 0:>9}"
                     f"  [{t.pruned}]")
    return "\n".join(lines)


# ------------------------------------------- kernel grid-step rendering

def _grid_rows(h: Hierarchy, report: Report):
    """(row, ScopeNode) pairs for kernel grid probes in a report."""
    out = []
    for r in report.rows:
        node = h.node(r.path)
        if node is not None and node.kind == "loop" and node.grid:
            out.append((r, node))
    return out


def kernel_grid_table(h: Hierarchy, report: Report) -> str:
    """Per-kernel grid-step imbalance summary.

    One row per probed ``kernel/<name>/grid`` scope: grid shape, steps
    executed, recorded per-step durations (ring depth, or all steps
    with offload) with min/mean/max and the step skew (max−min — the
    causal-skip / tile-imbalance signal), plus the static per-step
    estimate for the measured-vs-modeled gap the DSE calibrator closes.
    """
    rows = _grid_rows(h, report)
    if not rows:
        return "(no kernel grid probes in this report)"
    w = max(len(r.path) for r, _ in rows) + 2
    lines = [f"{'kernel grid':<{w}}{'grid':>14}{'steps':>7}{'rec':>5}"
             f"{'min':>8}{'mean':>9}{'max':>8}{'skew':>8}{'static/step':>12}"]
    for r, node in rows:
        durs = [e - s for s, e in r.iters]
        per_visit = node.static_cycles
        if durs:
            lines.append(
                f"{r.path:<{w}}{'x'.join(map(str, node.grid)):>14}"
                f"{r.calls:>7}{len(durs):>5}{min(durs):>8}"
                f"{sum(durs) / len(durs):>9.1f}{max(durs):>8}"
                f"{max(durs) - min(durs):>8}{per_visit:>12}")
        else:
            lines.append(f"{r.path:<{w}}{'x'.join(map(str, node.grid)):>14}"
                         f"{r.calls:>7}{0:>5}{'-':>8}{'-':>9}{'-':>8}"
                         f"{'-':>8}{per_visit:>12}")
    return "\n".join(lines)


def kernel_grid_heat(h: Hierarchy, report: Report,
                     path: Optional[str] = None,
                     chars: str = " .:-=+*#%@") -> str:
    """ASCII heat map of per-grid-step cycles for one kernel.

    Rows/columns follow the grid (leading axes flattened into rows,
    last — the sequential pallas axis — across). Renders every recorded
    step (all of them when the probe offloads, the first ``depth``
    otherwise); dark cells are expensive tiles, so a causal flash
    kernel shows its triangle. Defaults to the grid probe with the
    largest step skew."""
    rows = _grid_rows(h, report)
    if not rows:
        return "(no kernel grid probes in this report)"
    if path is None:
        def skew(r):
            d = [e - s for s, e in r.iters]
            return (max(d) - min(d)) if d else -1
        row, node = max(rows, key=lambda rn: skew(rn[0]))
    else:
        match = [(r, n) for r, n in rows if r.path == path]
        if not match:
            raise ValueError(f"no grid probe at {path!r}; have "
                             f"{[r.path for r, _ in rows]}")
        row, node = match[0]
    durs = np.asarray([e - s for s, e in row.iters], np.int64)
    if durs.size == 0:
        return f"# heat: {row.path} — no recorded steps"
    lo, hi = int(durs.min()), int(durs.max())
    span = (hi - lo) or 1
    last = node.grid[-1]
    full = durs.size % last == 0
    grid2d = durs.reshape(-1, last) if full else durs.reshape(1, -1)
    cell = len(str(hi)) + 1
    lines = [f"# heat: {row.path} grid={'x'.join(map(str, node.grid))} "
             f"recorded={durs.size}/{row.calls} steps "
             f"(min={lo} max={hi} skew={hi - lo})"]
    for r in range(grid2d.shape[0]):
        cells = []
        for c in range(grid2d.shape[1]):
            v = int(grid2d[r, c])
            shade = chars[int((v - lo) / span * (len(chars) - 1))]
            cells.append(f"{shade}{v:>{cell}}")
        lines.append(" ".join(cells))
    return "\n".join(lines)


# ------------------------------------------------------ mesh rendering

_HEAT_CHARS = " .:-=+*#%@"


def mesh_device_table(rec, top: int = 0) -> str:
    """Per-device cycle table for a ``meshprobe.CycleRecord``: one row
    per probe, one column per device, plus the cross-device reductions
    (max / mean) and the skew straggler signal."""
    D = rec.n_devices
    w = max((len(p) for p in rec.paths), default=6) + 2
    dev_w = max(10, len(str(int(rec.totals.max(initial=0)))) + 2)
    head = (f"{'module':<{w}}" +
            "".join(f"{'dev' + str(d):>{dev_w}}" for d in range(D)) +
            f"{'max':>{dev_w}}{'mean':>{dev_w}}{'skew':>{dev_w}}")
    coord = (f"{'(mesh coord)':<{w}}" +
             "".join(f"{str(rec.coords(d)):>{dev_w}}" for d in range(D)))
    lines = [f"# mesh {dict(zip(rec.mesh_axes, rec.mesh_shape))} — "
             f"{D} devices, span max={int(rec.cycle.max(initial=0))} cycles",
             head, coord]
    order = np.argsort(-rec.totals.max(axis=0), kind="stable")
    if top:
        order = order[:top]
    for pid in order:
        t = rec.totals[:, pid]
        lines.append(
            f"{rec.paths[pid]:<{w}}" +
            "".join(f"{int(t[d]):>{dev_w}}" for d in range(D)) +
            f"{int(t.max()):>{dev_w}}{t.mean():>{dev_w}.1f}"
            f"{int(t.max() - t.min()):>{dev_w}}")
    return "\n".join(lines)


def mesh_heat(rec, path: Optional[str] = None, chars: str = _HEAT_CHARS
              ) -> str:
    """ASCII heat map of one probe's cycles over the mesh grid — the
    per-device view at a glance (dark cell = straggler). 1D meshes
    render as a row; >2D meshes flatten their leading axes into rows."""
    if not rec.paths:
        return "(no probes selected)"
    if path is None:
        _, path = rec.straggler()
    pid = rec.paths.index(path)
    t = rec.totals[:, pid].astype(np.float64)
    lo, hi = float(t.min()), float(t.max())
    span = (hi - lo) or 1.0
    shape = rec.mesh_shape if len(rec.mesh_shape) > 1 else \
        (1,) + tuple(rec.mesh_shape)
    grid = t.reshape((-1, shape[-1]))
    cell = max((len(str(int(x))) for x in t), default=1) + 1
    lines = [f"# heat: {path} over mesh "
             f"{dict(zip(rec.mesh_axes, rec.mesh_shape))} "
             f"(min={int(lo)} max={int(hi)} skew={int(hi - lo)})"]
    for r in range(grid.shape[0]):
        cells = []
        for c in range(grid.shape[1]):
            v = grid[r, c]
            shade = chars[int((v - lo) / span * (len(chars) - 1))]
            cells.append(f"{shade}{int(v):>{cell}}")
        lines.append(" ".join(cells))
    return "\n".join(lines)


def mesh_comm_table(rec, hierarchy, sites) -> str:
    """Compute vs. communication per module: measured cycles (max over
    devices) against the ring-model collective cycles attributed to the
    same scope path (static per program run, ancestor loop trips
    folded in)."""
    from repro.core.costmodel import ICI_BYTES_PER_CYCLE

    def trip_mult(path: str) -> int:
        mult, cur = 1, ""
        for seg in (path.split("/") if path else []):
            cur = f"{cur}/{seg}" if cur else seg
            node = hierarchy.node(cur)
            if node is not None and node.kind == "loop" and node.trip_count:
                mult *= node.trip_count
        return mult

    per_path: Dict[str, Dict[str, float]] = {}
    for s in sites:
        d = per_path.setdefault(s.path, {"count": 0, "wire": 0.0,
                                         "kinds": set()})
        m = trip_mult(s.path)
        d["count"] += m
        d["wire"] += s.wire_bytes * m
        d["kinds"].add(s.kind)
    if not per_path:
        return "(no collectives in the probed program)"
    probed = {p: int(rec.totals[:, i].max())
              for i, p in enumerate(rec.paths)}

    def nearest_probe_cycles(path: str) -> Optional[int]:
        cur = path
        while True:
            if cur in probed:
                return probed[cur]
            if "/" not in cur:
                return probed.get("", None)
            cur = cur.rsplit("/", 1)[0]

    w = max(len(p) for p in per_path) + 2
    lines = [f"{'module':<{w}}{'collectives':>12}{'wire_B':>12}"
             f"{'comm_cyc':>10}{'probed_cyc':>11}{'comm%':>7}  kinds"]
    for path in sorted(per_path, key=lambda p: -per_path[p]["wire"]):
        d = per_path[path]
        comm_cyc = int(np.ceil(d["wire"] / ICI_BYTES_PER_CYCLE))
        total = nearest_probe_cycles(path)
        pct = (f"{100.0 * comm_cyc / total:6.1f}%" if total else f"{'-':>7}")
        lines.append(f"{path or '/':<{w}}{int(d['count']):>12}"
                     f"{int(d['wire']):>12}{comm_cyc:>10}"
                     f"{total if total is not None else '-':>11}{pct}"
                     f"  {','.join(sorted(d['kinds']))}")
    return "\n".join(lines)


def mesh_session_table(snap, reduce: str = "max") -> str:
    """Running table for a live ``MeshProbeSession`` snapshot, reduced
    across devices (or expanded per device via ``reduce='per-device'``,
    which falls through to the full device table)."""
    rec = snap.record
    if reduce == "per-device":
        return mesh_device_table(rec)
    red = rec.reduce(reduce)
    skew = rec.skew()
    calls = rec.calls.max(axis=0)
    span = int(rec.cycle.max(initial=0))
    w = max((len(p) for p in rec.paths), default=6) + 2
    lines = [f"# mesh session: {snap.steps} steps, {rec.n_devices} devices, "
             f"span(max)={span} cycles, state={snap.state_nbytes}B",
             f"{'module':<{w}}{'calls':>9}{f'cycles({reduce})':>16}"
             f"{'%span':>7}{'skew':>12}"]
    for pid in np.argsort(-np.asarray(red), kind="stable"):
        pct = 100.0 * float(red[pid]) / span if span else 0.0
        lines.append(f"{rec.paths[pid]:<{w}}{int(calls[pid]):>9}"
                     f"{float(red[pid]):>16.1f}{pct:>6.1f}%"
                     f"{int(skew[pid]):>12}")
    return "\n".join(lines)


def bump_chart(rankings: Dict[str, List[str]], width: int = 18) -> str:
    """Fig-14-style bottleneck ranking shifts across profiling stages.

    rankings: stage name -> module paths ordered worst-first.
    """
    stages = list(rankings)
    mods = []
    for s in stages:
        for m in rankings[s]:
            if m not in mods:
                mods.append(m)
    lines = ["  ".join(f"{s:<{width}}" for s in stages)]
    depth = max(len(v) for v in rankings.values())
    for rank in range(depth):
        cells = []
        for s in stages:
            v = rankings[s]
            cells.append(f"#{rank + 1} {v[rank] if rank < len(v) else '':<{width - 3}}")
        lines.append("  ".join(cells))
    return "\n".join(lines)


# ------------------------------------------------- serving engine views

def engine_phase_table(phase_totals: Dict[str, Dict[str, int]]) -> str:
    """Per-phase cycle attribution for a serving-engine run.

    ``phase_totals``: phase name -> {"cycles": total model-clock cycles,
    "steps": step-function invocations} as produced by
    ``repro.engine.InferenceEngine.stats()``. Shows where the engine's
    device time goes: prompt prefill vs token decode vs paged-cache
    management (page scatter).
    """
    total = sum(v.get("cycles", 0) for v in phase_totals.values())
    lines = [f"{'phase':<16}{'steps':>8}{'cycles':>14}{'%':>7}"
             f"{'cycles/step':>13}"]
    for phase, v in phase_totals.items():
        cyc, steps = v.get("cycles", 0), v.get("steps", 0)
        pct = 100.0 * cyc / total if total else 0.0
        per = cyc / steps if steps else 0.0
        lines.append(f"{phase:<16}{steps:>8}{cyc:>14}{pct:>6.1f}%"
                     f"{per:>13.1f}")
    lines.append(f"{'total':<16}{'':>8}{total:>14}{100.0 if total else 0.0:>6.1f}%")
    return "\n".join(lines)


def engine_chunk_table(chunk_stats: Dict[tuple, Dict[str, int]]) -> str:
    """Per-(ctx pages, chunk pages) attribution for chunked-prefill
    continuation steps (``InferenceEngine.chunk_stats``). Each row is
    one pinned chunkpf trace shape; cycles include the paired cache
    scatter, so rows sum to the chunked share of prefill+cache time."""
    lines = [f"{'ctx pages':>10}{'chunk pages':>13}{'steps':>8}"
             f"{'cycles':>14}{'cycles/step':>13}"]
    for (cs, n) in sorted(chunk_stats):
        v = chunk_stats[(cs, n)]
        cyc, steps = v.get("cycles", 0), v.get("steps", 0)
        per = cyc / steps if steps else 0.0
        lines.append(f"{cs:>10}{n:>13}{steps:>8}{cyc:>14}{per:>13.1f}")
    return "\n".join(lines)


def engine_request_table(requests) -> str:
    """Per-request phase attribution rows for finished engine requests.

    Each request carries exact integer cycle deltas per phase (prefill
    and cache-scatter run exclusively at batch 1; decode cycles are the
    shared batched-step totals the request participated in, shown with
    the mean batch size so a fair per-request share can be read off).
    """
    lines = [f"{'req':>5}{'prompt':>8}{'new':>6}{'prefill':>12}"
             f"{'cache':>10}{'decode(shared)':>16}{'avg B':>7}"
             f"{'shared pages':>14}"]
    for r in requests:
        nd = len(r.decode_batches)
        avg_b = sum(r.decode_batches) / nd if nd else 0.0
        lines.append(
            f"{r.rid:>5}{len(r.prompt):>8}{len(r.out_tokens):>6}"
            f"{r.phase_cycles.get('prefill', 0):>12}"
            f"{r.phase_cycles.get('cache', 0):>10}"
            f"{r.phase_cycles.get('decode', 0):>16}{avg_b:>7.2f}"
            f"{r.shared_pages:>14}")
    return "\n".join(lines)


# ------------------------------------------------- telemetry sentinel

def telemetry_alert_table(events) -> str:
    """Fired :class:`~repro.telemetry.sentinel.DriftEvent` rows, most
    recent last — the on-exit summary serve/train print when a drift
    sentinel ran (``--status-port``)."""
    if not events:
        return "# sentinel: no drift events"
    lines = [f"{'window':>7}  {'kind':<16}{'stream':<18}{'probe':<22}"
             f"{'dev':>4}{'severity':>10}{'trip':>7}"]
    for e in events:
        dev = "-" if e.device is None else str(e.device)
        lines.append(f"{e.window:>7}  {e.kind:<16}{e.stream:<18}"
                     f"{e.path:<22}{dev:>4}{e.severity:>10.3f}"
                     f"{e.threshold:>7.2f}")
    return "\n".join(lines)


def sentinel_table(sentinel) -> str:
    """Per-(stream, probe) detector state of a live
    :class:`~repro.telemetry.sentinel.DriftSentinel`: warmup progress,
    reference sample count, and current consecutive-breach counters."""
    rows = sorted(sentinel._rows.items())
    if not rows:
        return "# sentinel: no windows observed yet"
    warm = sentinel.cfg.warmup_windows
    lines = [f"{'stream':<18}{'row':>5}{'windows':>9}{'ref_n':>8}"
             f"{'state':<10}{'breaches':<24}"]
    for (stream, row), st in rows:
        state = "warmup" if st.windows_seen < warm else "armed"
        br = ",".join(f"{k}:{v}" for k, v in st.breaches.items() if v)
        lines.append(f"{stream:<18}{row:>5}{st.windows_seen:>9}"
                     f"{st.ref_count:>8}  {state:<10}{br or '-':<24}")
    lines.append(f"# {len(sentinel.events)} event(s) fired")
    return "\n".join(lines)
