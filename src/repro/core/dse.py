"""Automated design-space exploration (paper §IV-E, Fig 13).

Explores profiling configurations — storage class (register-like shallow
rings, BRAM-like deep rings, hybrid) x DRAM dump ratio (0/25/50/75%) —
and scores each on the paper's three metrics:

  1) resource overhead      on-device state bytes + extra HLO equations
                            (weighted, relative to the base program),
  2) DRAM bandwidth         measured offloaded bytes / profiled span,
  3) latency impact         measured wall-time of the instrumented step
                            relative to the unprobed step (Fmax analogue).

Returns all points plus the Pareto-optimal subset. Incremental
re-instrumentation (cached trace/hierarchy) is what makes the sweep
cheap — each point only rebuilds the probe layer, like the paper's
incremental synthesis.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.buffer import state_bytes
from repro.core.costmodel import CLOCK_HZ
from repro.core.counters import c64_to_int
from repro.core.pragma import ProbeConfig, ProbedFunction, probe

STORAGE_DEPTH = {"registers": 4, "hybrid": 16, "bram": 64}


@dataclass
class DSEPoint:
    storage: str
    depth: int
    offload_ratio: float
    n_probes: int
    state_bytes: int
    extra_eqns: int
    dram_bytes: int
    dram_bandwidth_bps: float        # modeled at the TPU clock
    latency_overhead: float          # measured wall-time ratio - 1
    weighted_resource: float

    def dominates(self, o: "DSEPoint") -> bool:
        a = (self.weighted_resource, self.dram_bandwidth_bps,
             self.latency_overhead)
        b = (o.weighted_resource, o.dram_bandwidth_bps, o.latency_overhead)
        return all(x <= y for x, y in zip(a, b)) and a != b


@dataclass
class DSEResult:
    points: List[DSEPoint]
    pareto: List[DSEPoint]

    def best(self) -> Optional[DSEPoint]:
        return min(self.pareto,
                   key=lambda p: p.weighted_resource + p.latency_overhead,
                   default=None)

    def table(self) -> str:
        hdr = (f"{'storage':<10}{'depth':>6}{'dump%':>7}{'probes':>8}"
               f"{'state_B':>9}{'xeqns':>7}{'dram_B':>8}{'bw_MBps':>9}"
               f"{'lat_ovh':>9}  pareto")
        lines = [hdr]
        ps = {id(p) for p in self.pareto}
        for p in self.points:
            lines.append(
                f"{p.storage:<10}{p.depth:>6}{p.offload_ratio * 100:>6.0f}%"
                f"{p.n_probes:>8}{p.state_bytes:>9}{p.extra_eqns:>7}"
                f"{p.dram_bytes:>8}{p.dram_bandwidth_bps / 1e6:>9.3f}"
                f"{p.latency_overhead * 100:>8.2f}%"
                f"  {'*' if id(p) in ps else ''}")
        return "\n".join(lines)


def _timeit(f, *args, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = f(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run_dse(fn: Callable, args: Sequence[Any],
            base_cfg: ProbeConfig = ProbeConfig(),
            storages: Sequence[str] = ("registers", "hybrid", "bram"),
            offload_ratios: Sequence[float] = (0.0, 0.25, 0.5, 0.75),
            resource_weights: Tuple[float, float] = (1.0, 1.0),
            repeats: int = 3) -> DSEResult:
    from repro.core.overhead import measure_overhead

    base_jit = jax.jit(fn)
    base_jit(*args)                       # compile
    t_base = _timeit(base_jit, *args, repeats=repeats)
    base_eqns = None

    pf = probe(fn, base_cfg)              # shared trace across the sweep
    pf.trace(*args)

    points: List[DSEPoint] = []
    for storage in storages:
        depth = STORAGE_DEPTH[storage]
        for ratio in offload_ratios:
            cfg = base_cfg.replace(buffer_depth=depth, offload=ratio)
            pf.retarget(cfg)
            pf.sink.reset()
            out, rec = pf(*args)          # compile + run
            t_inst = _timeit(pf, *args, repeats=repeats)
            span = int(c64_to_int(np.asarray(rec["cycle"])))
            span_s = max(span / CLOCK_HZ, 1e-12)
            ov = measure_overhead(fn, args, cfg)
            if base_eqns is None:
                base_eqns = ov["base_eqns"]
            sbytes = state_bytes(pf.assignment.n, depth)
            wres = (resource_weights[0] * sbytes / 1024.0 +
                    resource_weights[1] * ov["extra_eqns"] /
                    max(ov["base_eqns"], 1))
            points.append(DSEPoint(
                storage=storage, depth=depth, offload_ratio=ratio,
                n_probes=pf.assignment.n, state_bytes=sbytes,
                extra_eqns=ov["extra_eqns"],
                dram_bytes=pf.sink.bytes_received,
                dram_bandwidth_bps=pf.sink.bytes_received / span_s,
                latency_overhead=max(t_inst / max(t_base, 1e-12) - 1.0, 0.0),
                weighted_resource=wres))
    pareto = [p for p in points
              if not any(o.dominates(p) for o in points)]
    return DSEResult(points=points, pareto=pareto)
