"""Automated design-space exploration (paper §IV-E, Fig 13).

Two DSE loops live here:

**run_dse** explores profiling configurations — storage class
(register-like shallow rings, BRAM-like deep rings, hybrid) x DRAM dump
ratio (0/25/50/75%) — and scores each on the paper's three metrics:

  1) resource overhead      on-device state bytes + extra HLO equations
                            (weighted, relative to the base program),
  2) DRAM bandwidth         measured offloaded bytes / profiled span,
  3) latency impact         measured wall-time of the instrumented step
                            relative to the unprobed step (Fmax analogue).

It returns all points plus the Pareto-optimal subset. Incremental
re-instrumentation (cached trace/hierarchy) is what makes the sweep
cheap — each point only rebuilds the probe layer, like the paper's
incremental synthesis.

**DSEEngine** closes the paper's second loop: probe telemetry driving
*kernel-configuration* search under device resource budgets. Given a
:class:`SearchSpace` (tile sizes / pipeline depth per Pallas kernel) it

  1) enumerates candidate configs,
  2) prunes statically with the cost model against a
     :class:`~repro.core.costmodel.DeviceBudget` (VMEM bytes, HBM
     traffic, FLOPs — the LUT/FF/BRAM-constraint analogue),
  3) measures survivors with ``ProbeSession`` cycle telemetry under
     successive halving (cheap configs get few steps, finalists many),
  4) memoizes every measurement in the on-disk
     :class:`~repro.core.incremental.EvalCache` keyed by (kernel id,
     config, lowered-IR hash, device kind) — re-running after an
     unrelated edit re-measures nothing.
"""
from __future__ import annotations

import itertools
import math
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.buffer import state_bytes
from repro.core.costmodel import (CLOCK_HZ, DeviceBudget, KernelResources,
                                  jaxpr_kernel_resources)
from repro.core.incremental import (EvalCache, device_kind,
                                    fingerprint_closed)
from repro.core.instrument import decode_record
from repro.core.pragma import ProbeConfig, probe

STORAGE_DEPTH = {"registers": 4, "hybrid": 16, "bram": 64}


@dataclass
class DSEPoint:
    storage: str
    depth: int
    offload_ratio: float
    n_probes: int
    state_bytes: int
    extra_eqns: int
    dram_bytes: int
    dram_bandwidth_bps: float        # modeled at the TPU clock
    latency_overhead: float          # measured wall-time ratio - 1
    weighted_resource: float

    def dominates(self, o: "DSEPoint") -> bool:
        a = (self.weighted_resource, self.dram_bandwidth_bps,
             self.latency_overhead)
        b = (o.weighted_resource, o.dram_bandwidth_bps, o.latency_overhead)
        return all(x <= y for x, y in zip(a, b)) and a != b


@dataclass
class DSEResult:
    points: List[DSEPoint]
    pareto: List[DSEPoint]

    def best(self) -> Optional[DSEPoint]:
        return min(self.pareto,
                   key=lambda p: p.weighted_resource + p.latency_overhead,
                   default=None)

    def table(self) -> str:
        hdr = (f"{'storage':<10}{'depth':>6}{'dump%':>7}{'probes':>8}"
               f"{'state_B':>9}{'xeqns':>7}{'dram_B':>8}{'bw_MBps':>9}"
               f"{'lat_ovh':>9}  pareto")
        lines = [hdr]
        ps = {id(p) for p in self.pareto}
        for p in self.points:
            lines.append(
                f"{p.storage:<10}{p.depth:>6}{p.offload_ratio * 100:>6.0f}%"
                f"{p.n_probes:>8}{p.state_bytes:>9}{p.extra_eqns:>7}"
                f"{p.dram_bytes:>8}{p.dram_bandwidth_bps / 1e6:>9.3f}"
                f"{p.latency_overhead * 100:>8.2f}%"
                f"  {'*' if id(p) in ps else ''}")
        return "\n".join(lines)


def _timeit(f, *args, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = f(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run_dse(fn: Callable, args: Sequence[Any],
            base_cfg: ProbeConfig = ProbeConfig(),
            storages: Sequence[str] = ("registers", "hybrid", "bram"),
            offload_ratios: Sequence[float] = (0.0, 0.25, 0.5, 0.75),
            resource_weights: Tuple[float, float] = (1.0, 1.0),
            repeats: int = 3) -> DSEResult:
    from repro.core.overhead import measure_overhead

    base_jit = jax.jit(fn)
    base_jit(*args)                       # compile
    t_base = _timeit(base_jit, *args, repeats=repeats)
    base_eqns = None

    pf = probe(fn, base_cfg)              # shared trace across the sweep
    pf.trace(*args)

    points: List[DSEPoint] = []
    for storage in storages:
        depth = STORAGE_DEPTH[storage]
        for ratio in offload_ratios:
            cfg = base_cfg.replace(buffer_depth=depth, offload=ratio)
            pf.retarget(cfg)
            pf.sink.reset()
            out, rec = pf(*args)          # compile + run
            t_inst = _timeit(pf, *args, repeats=repeats)
            span = decode_record(jax.device_get(rec))["cycle"]
            span_s = max(span / CLOCK_HZ, 1e-12)
            ov = measure_overhead(fn, args, cfg)
            if base_eqns is None:
                base_eqns = ov["base_eqns"]
            sbytes = state_bytes(pf.assignment.n, depth)
            wres = (resource_weights[0] * sbytes / 1024.0 +
                    resource_weights[1] * ov["extra_eqns"] /
                    max(ov["base_eqns"], 1))
            points.append(DSEPoint(
                storage=storage, depth=depth, offload_ratio=ratio,
                n_probes=pf.assignment.n, state_bytes=sbytes,
                extra_eqns=ov["extra_eqns"],
                dram_bytes=pf.sink.bytes_received,
                dram_bandwidth_bps=pf.sink.bytes_received / span_s,
                latency_overhead=max(t_inst / max(t_base, 1e-12) - 1.0, 0.0),
                weighted_resource=wres))
    pareto = [p for p in points
              if not any(o.dominates(p) for o in points)]
    return DSEResult(points=points, pareto=pareto)


# ===================================================================
# Kernel-configuration autotuning (probe-guided, budget-constrained)
# ===================================================================

@dataclass
class SearchSpace:
    """Declarative candidate space for one kernel.

    ``axes`` maps axis name -> allowed values; candidates are the
    cartesian product filtered through ``is_valid``. ``bind(config)``
    returns a callable taking ``args`` (example inputs at the shapes
    being tuned) that executes the kernel under that config.
    ``default`` is the untuned baseline the leaderboard compares
    against.
    """
    kernel_id: str
    axes: Dict[str, Tuple[Any, ...]]
    bind: Callable[[Dict[str, Any]], Callable]
    args: Tuple[Any, ...]
    default: Dict[str, Any]
    is_valid: Optional[Callable[[Dict[str, Any]], bool]] = None

    def candidates(self) -> List[Dict[str, Any]]:
        names = sorted(self.axes)
        out = []
        for combo in itertools.product(*(self.axes[n] for n in names)):
            cfg = dict(zip(names, combo))
            if self.is_valid is None or self.is_valid(cfg):
                out.append(cfg)
        return out


@dataclass
class Trial:
    """One candidate's journey through the engine."""
    config: Dict[str, Any]
    resources: Optional[KernelResources] = None
    fingerprint: str = ""
    pruned: Optional[str] = None          # reason, when statically rejected
    cycles_per_step: Optional[float] = None
    steps: int = 0                        # largest rung this trial ran at
    cache_hits: int = 0
    measurements: int = 0
    is_default: bool = False
    # grid-step calibration (``DSEEngine.measure_tiles``): per-tile
    # cycles from the kernel-probed counters vs the cost model's static
    # per-tile estimate; residual = static − measured (positive = the
    # model over-prices tiles, e.g. causal skips it cannot see).
    # tile_dma is the per-step block-DMA term, identical in both, so
    # the calibration ratio is taken over the body term alone.
    tile_static: Optional[float] = None
    tile_measured: Optional[float] = None
    tile_residual: Optional[float] = None
    tile_dma: Optional[float] = None

    @property
    def measured(self) -> bool:
        return self.cycles_per_step is not None


@dataclass
class TuneResult:
    kernel_id: str
    trials: List[Trial]
    best: Optional[Trial]
    default: Optional[Trial]
    n_candidates: int
    n_pruned: int
    n_measurements: int                   # ProbeSession runs performed
    n_cache_hits: int
    measured_steps: int                   # total steps across measurements
    wall_s: float
    device: str = ""

    @property
    def speedup(self) -> float:
        """Default cycles/step over best cycles/step (>1 = tuned wins)."""
        if (self.best is None or self.default is None
                or not self.default.measured or not self.best.measured):
            return 1.0
        return self.default.cycles_per_step / max(self.best.cycles_per_step,
                                                  1e-12)

    def leaderboard(self, top: int = 10) -> str:
        from repro.core import report as report_mod
        return report_mod.dse_leaderboard(self, top=top)

    def to_dict(self) -> Dict[str, Any]:
        def trial(t: Optional[Trial]):
            if t is None:
                return None
            return {"config": t.config, "pruned": t.pruned,
                    "cycles_per_step": t.cycles_per_step, "steps": t.steps,
                    "cache_hits": t.cache_hits,
                    "measurements": t.measurements,
                    "is_default": t.is_default,
                    "tile_residual": t.tile_residual}
        return {
            "kernel": self.kernel_id, "device": self.device,
            "n_candidates": self.n_candidates, "n_pruned": self.n_pruned,
            "n_measurements": self.n_measurements,
            "n_cache_hits": self.n_cache_hits,
            "measured_steps": self.measured_steps,
            "speedup": round(self.speedup, 4),
            "best": trial(self.best), "default": trial(self.default),
            "trials": [trial(t) for t in self.trials],
        }


class DSEEngine:
    """Probe-guided autotuner for Pallas kernel configurations.

    ``tune()`` runs enumerate -> static-prune -> successive-halving
    measurement -> cache, and returns a :class:`TuneResult`. The
    baseline (``space.default``) is always measured alongside the
    survivors so the leaderboard's speedup is honest.

    Successive halving: every surviving candidate runs ``r0`` probed
    steps; the best ``1/eta`` fraction advances with ``eta``x the steps,
    until one remains or ``max_steps`` is reached. All measurements go
    through the :class:`EvalCache`, so a warm re-run performs zero new
    measurements.
    """

    def __init__(self, space: SearchSpace, *,
                 budget: Optional[DeviceBudget] = DeviceBudget(),
                 cache: Optional[EvalCache] = None,
                 cache_dir: Optional[str] = None,
                 cycle_source: str = "model",
                 r0: int = 1, eta: int = 2, max_steps: int = 4,
                 static_prune_ratio: Optional[float] = None):
        if r0 < 1 or eta < 2 or max_steps < r0:
            raise ValueError(f"bad halving schedule r0={r0} eta={eta} "
                             f"max_steps={max_steps}")
        self.space = space
        self.budget = budget
        self.cache = cache if cache is not None else EvalCache(cache_dir)
        self.cycle_source = cycle_source
        self.r0, self.eta, self.max_steps = r0, eta, max_steps
        self.static_prune_ratio = static_prune_ratio
        self.device = device_kind()
        # kernel body names observed by measure_tiles (calibrate targets)
        self._tile_kernels: set = set()
        # run accounting (reset per tune())
        self.n_measurements = 0
        self.n_cache_hits = 0
        self.measured_steps = 0

    # -- stage 1+2: enumerate & statically analyze ----------------------
    def analyze(self, config: Dict[str, Any]) -> Trial:
        """Trace one candidate; attach its IR hash and the cost-model
        resource footprint (no execution)."""
        fn = self.space.bind(config)
        closed = jax.make_jaxpr(fn)(*self.space.args)
        fp = fingerprint_closed(closed)
        res = jaxpr_kernel_resources(closed.jaxpr)
        return Trial(config=dict(config), resources=res, fingerprint=fp)

    def prune(self, trials: Sequence[Trial]) -> List[Trial]:
        """Static rejection against the device budget; optionally also
        drop candidates whose cost-model estimate exceeds
        ``static_prune_ratio`` x the best static estimate. Hard budget
        checks can never discard a config that actually fits the device,
        so the measured-best always survives default pruning."""
        alive = []
        for t in trials:
            if self.budget is not None and t.resources is not None:
                v = self.budget.violations(t.resources)
                if v:
                    t.pruned = "; ".join(v)
                    continue
            alive.append(t)
        if self.static_prune_ratio is not None and alive:
            floor = min(t.resources.static_cycles for t in alive
                        if t.resources is not None)
            kept = []
            for t in alive:
                if (t.resources is not None and floor > 0 and
                        t.resources.static_cycles >
                        self.static_prune_ratio * floor):
                    t.pruned = (f"static {t.resources.static_cycles} cyc > "
                                f"{self.static_prune_ratio:g}x floor {floor}")
                else:
                    kept.append(t)
            alive = kept
        return alive

    # -- stage 3: probed measurement ------------------------------------
    def _measure(self, config: Dict[str, Any], steps: int) -> float:
        """Run ``steps`` probed steps of the candidate under a
        ``ProbeSession``; returns mean cycles/step from the session's
        device span counter."""
        from repro.core.streaming import ProbeSession
        fn = self.space.bind(config)
        cfg = ProbeConfig(targets=("",), max_probes=4, buffer_depth=2,
                          cycle_source=self.cycle_source)
        with ProbeSession(fn, cfg, window_steps=steps + 1) as s:
            for _ in range(steps):
                jax.block_until_ready(s.step(*self.space.args))
            snap = s.snapshot()
        self.n_measurements += 1
        self.measured_steps += steps
        return snap.span / max(steps, 1)

    def _eval_fingerprint(self, t: Trial) -> str:
        """Trial fingerprint extended with the installed kernel-
        calibration state: measured cycles come from the model clock,
        whose pallas pricing is scaled by ``costmodel``'s process-
        global calibration — cycles measured under different
        calibrations must never collide under one cache key. The
        uncalibrated state leaves the key unchanged (existing caches
        stay warm)."""
        from repro.core.costmodel import kernel_calibration_state
        state = kernel_calibration_state()
        if not state:
            return t.fingerprint
        tag = ";".join(f"{k}={v:.6f}" for k, v in state)
        return f"{t.fingerprint}|calib[{tag}]"

    def evaluate(self, t: Trial, steps: int) -> float:
        """Cache-through evaluation at a rung of ``steps`` steps."""
        fp = self._eval_fingerprint(t)
        hit = self.cache.get(self.space.kernel_id, t.config, fp,
                             self.device, min_steps=steps)
        if hit is not None:
            t.cache_hits += 1
            self.n_cache_hits += 1
            t.cycles_per_step = float(hit["cycles_per_step"])
            t.steps = max(t.steps, int(hit["steps"]))
            return t.cycles_per_step
        cps = self._measure(t.config, steps)
        t.measurements += 1
        t.cycles_per_step = cps
        t.steps = steps
        self.cache.put(self.space.kernel_id, t.config, fp,
                       self.device, cycles_per_step=cps, steps=steps)
        return cps

    # -- grid-step calibration (measured per-tile cycles) ----------------
    def measure_tiles(self, t: Trial) -> Trial:
        """Probe the candidate with intra-kernel grid-step counters and
        record per-tile cycles on the trial.

        ``tile_measured`` is the mean measured cycles per grid step
        (sum of grid-probe totals over grid-probe calls — exact model-
        clock counters that see ``pl.when`` skips), ``tile_static`` the
        cost model's flat per-step estimate, ``tile_residual`` their
        gap. The kernel body names observed are remembered as
        ``calibrate()`` targets."""
        from repro.core.pragma import probe as _probe

        from repro.core import costmodel as _cm
        from repro.core import kernelprobe as _kp

        fn = self.space.bind(t.config)
        cfg = ProbeConfig(targets=("",), max_probes=16, buffer_depth=2,
                          cycle_source=self.cycle_source,
                          kernel_probes=("*",), inline="off_all")
        pf = _probe(fn, cfg)
        # retarget onto the kernel subtrees so deep grid probes can
        # never be crowded out of the probe budget by shallow wrapper
        # scopes (selection is preorder/shallow-first)
        h = pf.trace(*self.space.args)
        kpaths = tuple(n.path for n in h.root.walk() if n.kind == "kernel")
        if not kpaths:
            raise ValueError(
                f"measure_tiles({t.config}): the bound function has no "
                f"statically-gridded pallas kernels to probe")
        pf.retarget(cfg.replace(targets=kpaths))
        _, rec = pf(*self.space.args)
        dec = decode_record(jax.device_get(rec))
        grid_total = grid_calls = 0
        for i, path in enumerate(pf.probe_paths()):
            if path.endswith("/grid"):
                grid_total += int(dec["totals"][i])
                grid_calls += int(dec["calls"][i])
                # <scope>/kernel/<name>#i/grid -> <name>
                self._tile_kernels.add(
                    path.rsplit("/", 2)[-2].split("#")[0])
        if grid_calls:
            t.tile_measured = grid_total / grid_calls
        # per-step DMA term (shared by measured and static tiles): from
        # the traced pallas equations, steps-weighted across kernels
        dma_total = steps_total = 0
        for pe in _cm._walk_pallas_eqns(pf.hierarchy.closed_jaxpr.jaxpr):
            g = _kp.static_grid(pe)
            if g is None:
                continue
            s = int(np.prod(g))
            dma_total += _kp.dma_cycles(pe) * s
            steps_total += s
        if steps_total:
            t.tile_dma = dma_total / steps_total
        if t.resources is not None and t.resources.grid_steps:
            t.tile_static = (t.resources.static_cycles /
                             t.resources.grid_steps)
        if t.tile_measured is not None and t.tile_static is not None:
            t.tile_residual = t.tile_static - t.tile_measured
        return t

    def calibration(self, trials: Optional[Sequence[Trial]] = None
                    ) -> Optional[float]:
        """measured/static ratio of the per-tile BODY term (the DMA
        term is identical on both sides and is not scaled by
        ``costmodel._pallas_cost``, so it is subtracted before the
        ratio — otherwise calibration could not converge even on the
        trial it was measured from)."""
        ratios = []
        for t in (trials if trials is not None else []):
            if t.tile_measured is None or not t.tile_static:
                continue
            dma = t.tile_dma or 0.0
            body_static = t.tile_static - dma
            if body_static <= 0:
                continue
            ratios.append(max(t.tile_measured - dma, 0.0) / body_static)
        if not ratios:
            return None
        return float(np.mean(ratios))

    def calibrate(self, trials: Sequence[Trial]) -> Optional[float]:
        """Install the measured per-tile ratio into the cost model's
        block-level body term (``costmodel.set_kernel_calibration``)
        for every kernel body seen by ``measure_tiles``. Subsequent
        ``analyze()`` / prune passes then price tiles with measured
        grid-step cycles. Returns the scale (None without tile data);
        undo with ``costmodel.clear_kernel_calibration()``."""
        from repro.core import costmodel as _cm

        scale = self.calibration(trials)
        if scale is None:
            return None
        for kname in sorted(self._tile_kernels):
            _cm.set_kernel_calibration(kname, scale)
        return scale

    def successive_halving(self, trials: List[Trial]) -> Optional[Trial]:
        active = list(trials)
        r = self.r0
        while active:
            for t in active:
                self.evaluate(t, r)
            active.sort(key=lambda t: t.cycles_per_step)
            if len(active) == 1 or r >= self.max_steps:
                return active[0]
            keep = max(1, math.ceil(len(active) / self.eta))
            active = active[:keep]
            r = min(r * self.eta, self.max_steps)
        return None

    # -- the whole loop --------------------------------------------------
    def tune(self) -> TuneResult:
        self.n_measurements = self.n_cache_hits = self.measured_steps = 0
        t0 = time.perf_counter()
        configs = self.space.candidates()
        trials = [self.analyze(c) for c in configs]
        default_trial = None
        for t in trials:
            if t.config == self.space.default:
                t.is_default = True
                default_trial = t
        survivors = self.prune(trials)
        best = self.successive_halving(survivors)
        # always measure the baseline (even if pruned / not in the space),
        # at the SAME rung as the finalist — comparing a 1-step sample
        # against a max_steps mean is meaningless under wallclock noise
        if default_trial is None:
            default_trial = self.analyze(self.space.default)
            default_trial.is_default = True
            trials.append(default_trial)
        base_steps = best.steps if (best is not None and best.measured) \
            else self.r0
        if not default_trial.measured or default_trial.steps < base_steps:
            self.evaluate(default_trial, base_steps)
        if best is None or (default_trial.measured and best.measured and
                            default_trial.cycles_per_step
                            <= best.cycles_per_step):
            best = default_trial
        if best is not None and best.measured:
            shape = str([(tuple(getattr(a, "shape", ())),
                          str(getattr(a, "dtype", "?")))
                         for a in jax.tree_util.tree_leaves(self.space.args)])
            self.cache.set_winner(self.space.kernel_id, self.device,
                                  best.config,
                                  cycles_per_step=best.cycles_per_step,
                                  shape=shape)
        return TuneResult(
            kernel_id=self.space.kernel_id, trials=trials, best=best,
            default=default_trial, n_candidates=len(configs),
            n_pruned=sum(1 for t in trials if t.pruned is not None),
            n_measurements=self.n_measurements,
            n_cache_hits=self.n_cache_hits,
            measured_steps=self.measured_steps,
            wall_s=time.perf_counter() - t0, device=self.device)


# ===================================================================
# Trace-once sweep farm (simulator-first, multi-process, shared cache)
# ===================================================================
#
# Successive halving measures tens of candidates; the sweep farm covers
# thousands. The phases:
#
#   1. capture  — workers trace each missing (config, shape) once and
#                 merge the KernelTrace artifacts into the shared
#                 TraceStore (no device execution);
#   2. calibrate — one kernel-probed device run on the first shape
#                 installs the measured/static body ratio
#                 (``DSEEngine.measure_tiles`` + ``calibrate``), which
#                 transfers to every other shape through the artifacts;
#   3. simulate — the parent re-prices EVERY candidate from the
#                 artifacts in microseconds (flat mode: the same clock
#                 device measurement produces), prunes against the
#                 budget, and ranks;
#   4. measure  — only the per-shape finalists (default + top priced)
#                 run on the device, in workers sharing one EvalCache.
#
# Workers run in *spawned* processes: tasks carry only plain data,
# spaces are rebuilt by name via ``search_spaces.sweep_space`` (bind
# closures don't pickle), and the installed calibration state is
# re-applied inside the worker.

@dataclass
class SweepShapeOutcome:
    shape: Dict[str, Any]
    n_candidates: int
    n_pruned: int
    best_config: Optional[Dict[str, Any]] = None
    best_cycles: Optional[float] = None
    default_config: Optional[Dict[str, Any]] = None
    default_cycles: Optional[float] = None

    @property
    def speedup(self) -> float:
        if not self.best_cycles or not self.default_cycles:
            return 1.0
        return self.default_cycles / max(self.best_cycles, 1e-12)


@dataclass
class SweepResult:
    kernel_id: str
    device: str
    shapes: List[SweepShapeOutcome]
    n_candidates: int             # configs x shapes enumerated
    n_captured: int               # traces captured this run (rest reused)
    n_pruned: int
    n_priced: int                 # simulator-priced candidates
    n_finalists: int
    n_measured: int               # ProbeSession device runs performed
    n_cache_hits: int
    n_calibration_runs: int
    calibration_scale: Optional[float]
    workers: int
    top_k: int
    price_wall_s: float           # capture phase
    sim_wall_s: float             # pure artifact re-pricing
    measure_wall_s: float
    wall_s: float

    @property
    def sim_us_per_config(self) -> float:
        return 1e6 * self.sim_wall_s / max(self.n_candidates, 1)

    def summary(self) -> str:
        lines = [
            f"sweep {self.kernel_id} on {self.device}: "
            f"{self.n_candidates} candidates over {len(self.shapes)} "
            f"shapes, {self.n_pruned} pruned, {self.n_finalists} "
            f"finalists, {self.n_measured} device measurements "
            f"({self.n_cache_hits} cache hits)",
            f"  capture {self.price_wall_s:.2f}s "
            f"({self.n_captured} traced, rest reused) | simulate "
            f"{self.sim_wall_s * 1e3:.1f}ms "
            f"({self.sim_us_per_config:.1f}us/config) | measure "
            f"{self.measure_wall_s:.2f}s",
        ]
        if self.calibration_scale is not None:
            lines.append(f"  calibration scale {self.calibration_scale:.4f} "
                         f"(transferred to all shapes)")
        for o in self.shapes:
            lines.append(
                f"  {o.shape}: best {o.best_config} "
                f"{o.best_cycles if o.best_cycles is not None else float('nan'):.0f} cyc/step, "
                f"{o.speedup:.2f}x vs default")
        return "\n".join(lines)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kernel": self.kernel_id, "device": self.device,
            "n_candidates": self.n_candidates,
            "n_captured": self.n_captured, "n_pruned": self.n_pruned,
            "n_priced": self.n_priced, "n_finalists": self.n_finalists,
            "n_measured": self.n_measured,
            "n_cache_hits": self.n_cache_hits,
            "n_calibration_runs": self.n_calibration_runs,
            "calibration_scale": self.calibration_scale,
            "workers": self.workers, "top_k": self.top_k,
            "sim_us_per_config": round(self.sim_us_per_config, 3),
            "shapes": [{
                "shape": o.shape, "n_candidates": o.n_candidates,
                "n_pruned": o.n_pruned, "best": o.best_config,
                "best_cycles": o.best_cycles, "default": o.default_config,
                "default_cycles": o.default_cycles,
                "speedup": round(o.speedup, 4)} for o in self.shapes],
        }


def _sweep_worker(task: Dict[str, Any]) -> Dict[str, Any]:
    """One farm work unit; must stay module-level and take/return plain
    data only (it crosses the spawn pickle boundary)."""
    from repro.core import costmodel as _cm
    from repro.core import tracesim as _ts
    from repro.kernels import search_spaces as _ss

    _cm.clear_kernel_calibration()
    for kname, scale in task.get("calibration", ()):
        _cm.set_kernel_calibration(kname, float(scale))
    space = _ss.sweep_space(task["kernel"], **task["shape"])
    out: Dict[str, Any] = {"shape_idx": task["shape_idx"], "rows": [],
                           "measurements": 0, "cache_hits": 0}
    if task["phase"] == "capture":
        trace = _ts.KernelTrace(kernel_id=space.kernel_id,
                                shape=_ts.shape_signature(space.args),
                                space_fingerprint=task["space_fp"])
        for cfg in task["configs"]:
            trace.entries[_ts.config_key(cfg)] = _ts.capture_entry(
                space, cfg, walk=task.get("walk", False))
        _ts.TraceStore(task["cache_dir"]).merge(trace)
        out["captured"] = len(task["configs"])
        return out
    # phase == "measure": probed device runs through the shared cache
    engine = DSEEngine(space, budget=None,
                       cache=EvalCache(task["cache_dir"]),
                       cycle_source=task.get("cycle_source", "model"),
                       r0=task["steps"], max_steps=task["steps"])
    for cfg in task["configs"]:
        t = engine.analyze(cfg)
        cps = engine.evaluate(t, task["steps"])
        out["rows"].append({"config": cfg, "cycles": float(cps),
                            "steps": int(t.steps)})
    out["measurements"] = engine.n_measurements
    out["cache_hits"] = engine.n_cache_hits
    return out


def _run_tasks(tasks: List[Dict[str, Any]], workers: int) -> List[Dict]:
    if workers > 1 and len(tasks) > 1:
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor
        ctx = mp.get_context("spawn")
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=ctx) as ex:
            return list(ex.map(_sweep_worker, tasks))
    return [_sweep_worker(t) for t in tasks]


def _chunked(seq: List[Any], size: int) -> List[List[Any]]:
    return [seq[i:i + size] for i in range(0, len(seq), max(size, 1))]


def run_sweep(kernel_id: str,
              shapes: Optional[Sequence[Dict[str, Any]]] = None, *,
              workers: int = 2, top_k: int = 16, steps: int = 4,
              budget: Optional[DeviceBudget] = DeviceBudget(),
              cache: Optional[EvalCache] = None,
              cache_dir: Optional[str] = None,
              calibrate: bool = False, walk: bool = False,
              chunk: int = 64, cycle_source: str = "model",
              reuse_traces: bool = True) -> SweepResult:
    """Simulator-first DSE over configs x shapes (see the phase map
    above). Device measurement is reserved for at most
    ``max(2, top_k // n_shapes)`` finalists per shape — the default
    config plus the top simulator-priced survivors — no matter how many
    candidates the sweep enumerates."""
    from repro.core import costmodel as _cm
    from repro.core import tracesim as ts
    from repro.kernels import search_spaces as ss

    t_start = time.perf_counter()
    shape_list = [dict(s) for s in
                  (shapes if shapes is not None
                   else ss.sweep_shapes(kernel_id))]
    cache = cache if cache is not None else EvalCache(cache_dir)
    store = ts.TraceStore(cache.root)
    device = device_kind()

    spaces = [ss.sweep_space(kernel_id, **sh) for sh in shape_list]
    space_fps = [ts.space_fingerprint(sp) for sp in spaces]
    shape_sigs = [ts.shape_signature(sp.args) for sp in spaces]
    cand_lists = [sp.candidates() for sp in spaces]
    for sp, cands in zip(spaces, cand_lists):
        if sp.default not in cands:
            cands.append(sp.default)
    n_candidates = sum(len(c) for c in cand_lists)

    # -- phase 1: capture missing traces (workers, no device) ----------
    t0 = time.perf_counter()
    tasks = []
    for i, (sh, sig, sfp, cands) in enumerate(
            zip(shape_list, shape_sigs, space_fps, cand_lists)):
        stored = (store.load(kernel_id, sig, sfp)
                  if reuse_traces else None)
        have = set(stored.entries) if stored is not None else set()
        missing = [c for c in cands if ts.config_key(c) not in have]
        for part in _chunked(missing, chunk):
            tasks.append({"phase": "capture", "kernel": kernel_id,
                          "shape": sh, "shape_idx": i, "configs": part,
                          "walk": walk, "cache_dir": cache.root,
                          "space_fp": sfp, "calibration": ()})
    n_captured = sum(r.get("captured", 0)
                     for r in _run_tasks(tasks, workers))
    price_wall = time.perf_counter() - t0
    traces = [store.load(kernel_id, sig, sfp)
              for sig, sfp in zip(shape_sigs, space_fps)]
    for i, tr in enumerate(traces):
        if tr is None:
            raise RuntimeError(
                f"sweep capture produced no trace for shape "
                f"{shape_list[i]} (store {store.root})")

    # -- phase 2: one calibration run, transferred to every shape ------
    scale = None
    calib_runs = 0
    if calibrate:
        sp0, tr0 = spaces[0], traces[0]
        # calibrate on the unpruned candidate with the MOST grid steps:
        # fine tiles see the most pl.when causal-skip structure, which
        # is exactly the signal the flat estimate cannot price
        pick = min(
            (c for c in cand_lists[0]
             if budget is None or not budget.violations(
                 ts.entry_resources(tr0.entries[ts.config_key(c)]))),
            key=lambda c: (-tr0.entries[ts.config_key(c)].grid_steps,
                           ts.price(tr0, c, mode="flat"),
                           ts.config_key(c)),
            default=sp0.default)
        engine = DSEEngine(sp0, budget=None, cache=cache,
                           cycle_source=cycle_source, r0=steps,
                           max_steps=steps)
        trial = engine.analyze(pick)
        engine.measure_tiles(trial)
        calib_runs = 1
        scale = engine.calibrate([trial])

    # -- phase 3: simulate every candidate from the artifacts ----------
    t0 = time.perf_counter()
    ranked: List[List[Tuple[int, Dict[str, Any]]]] = []
    outcomes: List[SweepShapeOutcome] = []
    n_pruned = n_priced = 0
    for sh, sp, tr, cands in zip(shape_list, spaces, traces, cand_lists):
        rows = []
        pruned_here = 0
        for cfg in cands:
            entry = tr.entries[ts.config_key(cfg)]
            if budget is not None and budget.violations(
                    ts.entry_resources(entry)):
                pruned_here += 1
                continue
            rows.append((ts.price(entry, mode="flat"), cfg))
        rows.sort(key=lambda rc: (rc[0], ts.config_key(rc[1])))
        ranked.append(rows)
        n_pruned += pruned_here
        n_priced += len(rows)
        outcomes.append(SweepShapeOutcome(
            shape=sh, n_candidates=len(cands), n_pruned=pruned_here,
            default_config=dict(sp.default)))
    sim_wall = time.perf_counter() - t0

    # -- phase 4: measure only the finalists (workers, shared cache) ---
    per_shape = max(2, top_k // max(len(shape_list), 1))
    t0 = time.perf_counter()
    tasks = []
    finalists_per_shape: List[List[Dict[str, Any]]] = []
    calib_state = [(k, v) for k, v in _cm.kernel_calibration_state()]
    for i, (sp, rows) in enumerate(zip(spaces, ranked)):
        finalists = [dict(sp.default)]
        for _, cfg in rows:
            if len(finalists) >= per_shape:
                break
            if cfg != sp.default:
                finalists.append(cfg)
        finalists_per_shape.append(finalists)
        # split each shape's finalists across (up to) two tasks so
        # concurrent workers genuinely interleave on the shared cache
        parts = (_chunked(finalists, max(1, (len(finalists) + 1) // 2))
                 if workers > 1 else [finalists])
        for part in parts:
            tasks.append({"phase": "measure", "kernel": kernel_id,
                          "shape": shape_list[i], "shape_idx": i,
                          "configs": part, "steps": steps,
                          "cache_dir": cache.root,
                          "cycle_source": cycle_source,
                          "calibration": calib_state})
    n_measured = n_cache_hits = 0
    measured: List[Dict[str, List]] = [{"rows": []} for _ in shape_list]
    for res in _run_tasks(tasks, workers):
        n_measured += res["measurements"]
        n_cache_hits += res["cache_hits"]
        measured[res["shape_idx"]]["rows"].extend(res["rows"])
    measure_wall = time.perf_counter() - t0

    for i, (sp, o) in enumerate(zip(spaces, outcomes)):
        rows = measured[i]["rows"]
        if not rows:
            continue
        best = min(rows, key=lambda r: (r["cycles"],
                                        ts.config_key(r["config"])))
        o.best_config, o.best_cycles = dict(best["config"]), best["cycles"]
        for r in rows:
            if r["config"] == sp.default:
                o.default_cycles = r["cycles"]
                break
    # the primary (first) shape declares the kernel@device winner
    o0 = outcomes[0]
    if o0.best_config is not None and o0.best_cycles is not None:
        cache.set_winner(kernel_id, device, o0.best_config,
                         cycles_per_step=o0.best_cycles,
                         shape=shape_sigs[0])

    return SweepResult(
        kernel_id=kernel_id, device=device, shapes=outcomes,
        n_candidates=n_candidates, n_captured=n_captured,
        n_pruned=n_pruned, n_priced=n_priced,
        n_finalists=sum(len(f) for f in finalists_per_shape),
        n_measured=n_measured, n_cache_hits=n_cache_hits,
        n_calibration_runs=calib_runs, calibration_scale=scale,
        workers=workers, top_k=top_k, price_wall_s=price_wall,
        sim_wall_s=sim_wall, measure_wall_s=measure_wall,
        wall_s=time.perf_counter() - t_start)
